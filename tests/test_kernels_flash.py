"""flash_attention Pallas kernel vs the direct-softmax oracle (shape/dtype
sweep, window/softcap/causal variants, GQA group factors)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import reference_attention


def _case(b, hq, hkv, s, d, *, window=None, cap=None, causal=True,
          dtype=jnp.float32, tol=2e-5):
    rng = np.random.default_rng(hash((b, hq, hkv, s, d)) % 2**31)
    q = jnp.asarray(rng.standard_normal((b, hq, s, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          interpret=True)
    ref = reference_attention(q, k, v, causal=causal, window=window, softcap=cap)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("s", [128, 256, 512])
@pytest.mark.parametrize("d", [64, 128])
def test_shape_sweep(s, d):
    _case(2, 4, 2, s, d)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (8, 1)])
def test_gqa_groups(hq, hkv):
    _case(1, hq, hkv, 256, 64)


@pytest.mark.parametrize("window", [64, 128, 1000])
def test_sliding_window(window):
    _case(1, 2, 2, 256, 64, window=window)


@pytest.mark.parametrize("cap", [20.0, 50.0])
def test_softcap(cap):
    _case(1, 2, 1, 256, 64, cap=cap)


def test_non_causal():
    _case(1, 2, 2, 128, 64, causal=False)


def test_combined_gemma2_style():
    # gemma2 local layer: window + softcap + GQA
    _case(2, 8, 4, 512, 128, window=128, cap=50.0)


def test_bfloat16():
    _case(1, 4, 2, 256, 64, dtype=jnp.bfloat16, tol=2e-2)

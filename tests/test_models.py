"""Model-zoo tests: per-arch smoke (reduced configs), decode/prefill
consistency, MoE/SSM unit behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, all_configs, get_config
from repro.models import build_model, param_count
from repro.models.api import MoESpec

CONFIGS = all_configs()


def _batch(cfg, b=2, s=24, seed=1):
    rng = jax.random.PRNGKey(seed)
    toks = jax.random.randint(rng, (b, s + 1), 1, cfg.vocab)
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.frontend == "audio":
        out["frames"] = jnp.ones((b, cfg.frontend_len, cfg.d_model), jnp.float32) * 0.1
    if cfg.frontend == "vision":
        out["prefix_embeds"] = jnp.ones((b, cfg.frontend_len, cfg.d_model), jnp.float32) * 0.1
    return out


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_forward_backward(name):
    cfg = CONFIGS[name].reduced()
    spec = build_model(cfg)
    params = spec.init(jax.random.PRNGKey(0))
    assert param_count(params) > 0
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(spec.loss_fn, has_aux=True)(
        params, batch
    )
    assert jnp.isfinite(loss)
    assert np.isfinite(
        sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    )


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_decode(name):
    cfg = CONFIGS[name].reduced()
    spec = build_model(cfg)
    params = spec.init(jax.random.PRNGKey(0))
    b, s = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 1, cfg.vocab)
    if cfg.family == "audio":
        batch = {"frames": jnp.ones((b, cfg.frontend_len, cfg.d_model), jnp.float32),
                 "tokens": toks}
        logits, caches = spec.prefill(params, batch, 24)
    else:
        logits, caches = spec.prefill(params, toks, 24)
    assert logits.shape == (b, cfg.vocab)
    tok = jnp.argmax(logits, -1)[:, None]
    base = s + cfg.num_meta_tokens + (cfg.frontend_len if cfg.family == "vlm" else 0)
    for i in range(2):
        logits, caches = spec.decode_step(params, tok, caches, jnp.int32(base + i))
        assert logits.shape == (b, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits, -1)[:, None]


@pytest.mark.parametrize("name", ["qwen3-0.6b", "gemma2-9b", "deepseek-v3-671b",
                                  "xlstm-125m", "hymba-1.5b"])
def test_decode_matches_teacher_forcing(name):
    """Cache-based decode must reproduce the parallel forward's logits."""
    cfg = CONFIGS[name].reduced()
    spec = build_model(cfg)
    params = spec.init(jax.random.PRNGKey(0))
    b, s = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 1, cfg.vocab)

    # parallel logits over the prompt
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models.build import _unembed, lm_forward

        x, _, _ = lm_forward(params, cfg, toks)
        strip = x.shape[1] - s
        full_logits = _unembed(params, cfg, x[:, strip:] if strip else x)
    elif cfg.family == "ssm":
        from repro.models.xlstm import _forward
        from repro.models.layers import rms_norm

        x, _ = _forward(params, cfg, toks)
        full_logits = rms_norm(x, params["final_norm"]) @ params["lm_head"]
    else:  # hybrid
        from repro.models.hymba import _forward
        from repro.models.layers import rms_norm

        x, _ = _forward(params, cfg, toks)
        x = x[:, cfg.num_meta_tokens:]
        full_logits = rms_norm(x, params["final_norm"]) @ params["lm_head"]

    # prefill on the first s-1 tokens, then decode token s-1
    logits_pre, caches = spec.prefill(params, toks[:, : s - 1], s + 8)
    np.testing.assert_allclose(
        np.asarray(logits_pre, np.float32),
        np.asarray(full_logits[:, s - 2], np.float32),
        atol=2e-3, rtol=2e-3,
    )
    pos = (s - 1) + cfg.num_meta_tokens
    logits_dec, _ = spec.decode_step(params, toks[:, s - 1 :], caches, jnp.int32(pos))
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(full_logits[:, s - 1], np.float32),
        atol=2e-3, rtol=2e-3,
    )


def test_moe_routing_topk_and_capacity():
    from repro.models.moe import _dispatch_slots, _routing, moe_ffn, moe_init

    cfg = CONFIGS["deepseek-moe-16b"].reduced()
    params = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    top_idx, gates, aux = _routing(params, x.reshape(-1, cfg.d_model), cfg)
    assert top_idx.shape == (32, cfg.moe.top_k)
    assert float(aux) >= 0
    # slots: unique (expert, slot) pairs
    slots, in_cap = _dispatch_slots(top_idx.reshape(-1), capacity=1000)
    pairs = list(zip(np.asarray(top_idx).reshape(-1).tolist(), np.asarray(slots).tolist()))
    assert len(set(pairs)) == len(pairs)
    out, aux = moe_ffn(params, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())


def test_moe_capacity_drops_tokens():
    """With a tiny capacity factor some tokens must be dropped (output changes
    vs a generous capacity), while shapes stay fixed."""
    import dataclasses

    base = CONFIGS["deepseek-moe-16b"].reduced()
    cfg_small = dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, capacity_factor=0.05)
    )
    cfg_big = dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, capacity_factor=8.0)
    )
    from repro.models.moe import moe_ffn, moe_init

    params = moe_init(jax.random.PRNGKey(0), cfg_small, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, base.d_model), jnp.float32)
    out_small, _ = moe_ffn(params, x, cfg_small)
    out_big, _ = moe_ffn(params, x, cfg_big)
    assert not np.allclose(np.asarray(out_small), np.asarray(out_big))


def test_ssm_chunked_matches_sequential():
    from repro.models.ssm import chunked_linear_recurrence, linear_recurrence_step

    rng = np.random.default_rng(0)
    b, h, t, dk, dv = 2, 3, 64, 8, 5
    q = jnp.asarray(rng.standard_normal((b, h, t, dk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, t, dk)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, t, dv)), jnp.float32)
    log_a = jnp.asarray(-np.abs(rng.standard_normal((b, h, t))) * 0.1, jnp.float32)

    y_chunk, state_chunk = chunked_linear_recurrence(q, k, v, log_a, chunk=16)
    # sequential reference
    state = jnp.zeros((b, h, dk, dv))
    ys = []
    for i in range(t):
        y, state = linear_recurrence_step(
            q[:, :, i], k[:, :, i], v[:, :, i], log_a[:, :, i], state
        )
        ys.append(y)
    y_seq = jnp.stack(ys, axis=2)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq), atol=1e-4)
    np.testing.assert_allclose(np.asarray(state_chunk), np.asarray(state), atol=1e-4)


def test_ssm_chunk_padding():
    from repro.models.ssm import chunked_linear_recurrence

    rng = np.random.default_rng(1)
    b, h, t, dk, dv = 1, 2, 25, 4, 4  # 25 % 16 != 0: exercises padding
    args = [
        jnp.asarray(rng.standard_normal((b, h, t, dk)), jnp.float32),
        jnp.asarray(rng.standard_normal((b, h, t, dk)), jnp.float32),
        jnp.asarray(rng.standard_normal((b, h, t, dv)), jnp.float32),
    ]
    log_a = jnp.asarray(-np.abs(rng.standard_normal((b, h, t))) * 0.1, jnp.float32)
    y16, s16 = chunked_linear_recurrence(*args, log_a, chunk=16)
    y25, s25 = chunked_linear_recurrence(*args, log_a, chunk=25)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y25), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s16), np.asarray(s25), atol=1e-4)


def test_gemma2_window_pattern():
    from repro.models.build import layer_windows

    cfg = CONFIGS["gemma2-9b"]
    w = layer_windows(cfg, cfg.num_layers)
    assert (w[0::2] == cfg.sliding_window).all()
    assert (w[1::2] == 0).all()


def test_hymba_window_pattern():
    from repro.models.build import layer_windows

    cfg = CONFIGS["hymba-1.5b"]
    w = layer_windows(cfg, cfg.num_layers)
    assert w[0] == 0 and w[cfg.num_layers // 2] == 0 and w[-1] == 0
    assert (w != 0).sum() == cfg.num_layers - 3

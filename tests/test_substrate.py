"""Substrate tests: optimizer, compression, checkpoint, fault runner, data,
sharding rules."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.data import SyntheticLM
from repro.optim import (
    AdamWConfig, adamw_init, adamw_update, compress, compress_grads_with_feedback,
    decompress, init_residual, lr_at,
)
from repro.runtime import FaultConfig, best_mesh_shape, run_training
from repro.sharding.rules import spec_for_param


# ------------------------------------------------------------------ optim

def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=200)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, m = adamw_update(g, opt, params, cfg)
    assert float(loss(params)) < 1e-3
    assert int(opt["step"]) == 150


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
    assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0, rel=0.01)
    assert float(lr_at(cfg, jnp.asarray(100))) == pytest.approx(0.1, rel=0.01)


def test_grad_clip_bounds_update_norm():
    cfg = AdamWConfig(lr=1e-2, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params, cfg)
    huge = {"w": jnp.full(4, 1e9)}
    _, _, metrics = adamw_update(huge, opt, params, cfg)
    assert float(metrics["grad_norm"]) > 1e8  # raw norm reported


def test_compression_roundtrip_and_error_feedback():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = compress(x)
    deq = decompress(q, s, x.shape)
    assert float(jnp.max(jnp.abs(deq - x))) < float(jnp.max(jnp.abs(x))) / 100
    # error feedback: accumulated error stays bounded over repeated steps
    grads = {"w": x}
    residual = init_residual(grads)
    for _ in range(10):
        compressed, residual = compress_grads_with_feedback(grads, residual)
    assert float(jnp.max(jnp.abs(residual["w"]))) < 0.1


def test_compressed_pod_reduction_numerics_and_wire():
    """Hierarchical compressed reduction: numerics within quant tolerance AND
    the compiled HLO must carry the cross-'pod' payload as int8."""
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import shard_map
        from repro.optim.compression import compressed_psum_mean

        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        P = jax.sharding.PartitionSpec

        def reduce_fn(g):
            g = jax.lax.pmean(g, "data")            # fast ICI hop, full precision
            return compressed_psum_mean(g, "pod")   # slow DCI hop, int8 wire

        f = jax.jit(
            shard_map(reduce_fn, mesh=mesh, in_specs=P(("pod", "data")),
                      out_specs=P(("pod", "data")))
        )
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((8, 1024)), jnp.float32)
        got = f(x)
        # expected: mean over the 8 shards, broadcast back per shard
        expect = jnp.broadcast_to(x.reshape(8, 1, 1024).mean(0), (1, 1, 1024))
        err = float(jnp.max(jnp.abs(got[0] - expect[0, 0])))
        assert err < 0.05, err
        hlo = f.lower(x).compile().as_text()
        assert "s8[" in hlo and "all-gather" in hlo, "int8 wire not found"
        print("OK", err)
    """)
    env = {**os.environ, "PYTHONPATH": "src"}
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=300, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout


# -------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32), "b": {"c": jnp.ones((3, 3))}}
    save(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    got = restore(str(tmp_path), 7, tree)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(10, dtype=np.float32))
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]), np.ones((3, 3)))


def test_checkpoint_gc_keeps_last(tmp_path):
    tree = {"a": jnp.zeros(2)}
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, tree, keep=2)
    steps = sorted(os.listdir(tmp_path))
    assert steps == ["step_00000004", "step_00000005"]


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    ck.save_async(3, {"x": jnp.ones(5)})
    ck.wait()
    assert latest_step(str(tmp_path)) == 3


# ------------------------------------------------------------------ fault

def test_fault_runner_restarts_from_checkpoint(tmp_path):
    calls = {"n": 0}

    def step(state, batch):
        return {"w": state["w"] + 1}, {"loss": float(state["w"])}

    boom = {"armed": True}

    def injector(step_i):
        if step_i == 12 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("simulated node failure")

    cfg = FaultConfig(ckpt_dir=str(tmp_path), ckpt_every=5, max_restarts=3)
    state, report = run_training(
        step, {"w": jnp.zeros(())}, lambda s: None, 20, cfg, fail_injector=injector
    )
    assert report.restarts == 1
    assert float(state["w"]) == 20  # replay restores exact step count


def test_fault_runner_straggler_accounting(tmp_path):
    import time as _t

    def step(state, batch):
        if int(state["i"]) == 15:
            _t.sleep(0.25)
        else:
            _t.sleep(0.002)
        return {"i": state["i"] + 1}, {"loss": 0.0}

    cfg = FaultConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                      straggler_factor=3.0, straggler_grace_steps=5)
    _, report = run_training(step, {"i": jnp.zeros((), jnp.int32)},
                             lambda s: None, 20, cfg)
    assert report.straggler_events >= 1


def test_elastic_mesh_shapes():
    assert best_mesh_shape(256, model_parallel=16) == (16, 16)
    assert best_mesh_shape(192, model_parallel=16) == (12, 16)
    assert best_mesh_shape(7, model_parallel=16) == (1, 7)


# ------------------------------------------------------------------- data

def test_synthetic_data_is_deterministic_and_shifted():
    from repro.configs import get_config

    cfg = get_config("qwen3-0.6b").reduced()
    pipe = SyntheticLM(cfg, batch=4, seq=16, seed=3)
    a = pipe.host_batch(5)
    b = pipe.host_batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    c = pipe.host_batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_memmap_pipeline(tmp_path):
    from repro.configs import get_config
    from repro.data import MemmapLM

    cfg = get_config("qwen3-0.6b").reduced()
    path = tmp_path / "tokens.bin"
    np.arange(10_000, dtype=np.int32).tofile(path)
    pipe = MemmapLM(str(path), cfg, batch=4, seq=16)
    b0 = pipe.host_batch(0)
    assert b0["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b0["tokens"][:, 1:], b0["labels"][:, :-1])


# --------------------------------------------------------------- sharding

def test_param_sharding_rules():
    from jax.sharding import PartitionSpec as P

    assert spec_for_param("embed", (151936, 1024), model_size=16) == P("model", None)
    assert spec_for_param("layers/w_q", (1024, 2048), model_size=16) == P(None, "model")
    assert spec_for_param("x/w_down", (4096, 1024), model_size=16) == P("model", None)
    # stacked layer dim gets a leading None
    assert spec_for_param("stack/w_up", (28, 1024, 3072), model_size=16) == P(None, None, "model")
    assert spec_for_param("moe/expert_up", (64, 2048, 1408), model_size=16) == P("model", None, None)
    # divisibility gate: 8 kv heads * 64 = 512 not divisible by 13 -> replicated
    assert spec_for_param("w_k", (1024, 512), model_size=13) == P(None, None)
    # small-tensor gate
    assert spec_for_param("w_if", (768, 8), model_size=16) == P(None, None)
    # norms replicate
    assert spec_for_param("attn_norm", (1024,), model_size=16) == P()

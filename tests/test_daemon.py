"""Compile-daemon tests (DESIGN.md §16): admission control, stampede
coalescing, per-tenant deadlines, the unix-socket NDJSON protocol,
speculative-premapping attribution, and trace rotation.

The deterministic concurrency tests exploit one lifecycle property of
:class:`CompileDaemon`: requests submitted before ``start()`` are admitted
(queued / coalesced / shed by exactly the production code paths) but nothing
runs until the workers spawn — so a test can build any queue state it wants,
race-free, then release it."""

import json
import os
import threading
import time

import pytest

from repro.core import CGRA, running_example
from repro.core.benchsuite import load_suite
from repro.core.daemon import (
    CompileDaemon,
    DaemonClient,
    DaemonError,
    DaemonServer,
    neighbor_options,
)
from repro.core.mapper import clear_mapping_cache


@pytest.fixture(autouse=True)
def _fresh_memory_cache():
    clear_mapping_cache()
    yield
    clear_mapping_cache()


def _daemon(tmp_path=None, **kw):
    kw.setdefault("workers", 2)
    cache_dir = str(tmp_path / "cache") if tmp_path is not None else None
    return CompileDaemon(CGRA(4, 4), "fast", cache_dir=cache_dir, **kw)


# ------------------------------------------------------------ basic serving

def test_daemon_compiles_and_stamps_service_block(tmp_path):
    with _daemon(tmp_path) as d:
        row = d.submit(running_example(), tenant="t0").wait(timeout=60)
    assert row["ok"] and row["failure"] is None
    svc = row["service"]
    assert svc["tenant"] == "t0" and svc["coalesced"] is False
    assert svc["queue_s"] >= 0
    # provenance also lands next to the cache layer hit rates (§16.4)
    assert row["metrics"]["cache"]["speculative"] is False


def test_daemon_warm_path_is_memory_hit(tmp_path):
    with _daemon(tmp_path) as d:
        cold = d.submit(running_example()).wait(timeout=60)
        warm = d.submit(running_example()).wait(timeout=60)
    assert cold["source"] == "solve" and warm["source"] == "memory"
    assert warm["ii"] == cold["ii"]
    assert d.stats.solves == 1 and d.stats.warm_memory == 1


def test_stop_cancels_queued_requests():
    d = _daemon()            # never started: requests stay queued
    t1 = d.submit(running_example())
    d.stop()
    row = t1.wait(timeout=5)
    assert row is not None and row["failure"] == "cancelled"
    # a daemon that is stopping sheds new submits rather than hanging them
    t2 = d.submit(running_example())
    assert t2.wait(timeout=5)["failure"] == "overloaded"


# ------------------------------------------------------- stampede coalescing

def test_identical_concurrent_submits_coalesce_to_one_solve(tmp_path):
    n = 6
    d = _daemon(tmp_path)
    tickets = [d.submit(running_example(), tenant=f"t{i}") for i in range(n)]
    assert d.stats.coalesced == n - 1      # one leader, n-1 followers
    d.start()
    try:
        rows = [t.wait(timeout=60) for t in tickets]
    finally:
        d.stop()
    assert all(r is not None and r["ok"] for r in rows)
    assert d.stats.solves == 1             # the stampede cost ONE solve
    assert [r["service"]["coalesced"] for r in rows].count(True) == n - 1
    # every follower keeps its own tenant attribution
    assert sorted(r["service"]["tenant"] for r in rows) == sorted(
        f"t{i}" for i in range(n))
    assert {r["ii"] for r in rows} == {rows[0]["ii"]}


def test_different_options_do_not_coalesce(tmp_path):
    d = _daemon(tmp_path)
    d.submit(running_example())
    d.submit(running_example(), max_route_hops=1)   # different mapper options
    assert d.stats.coalesced == 0
    d.stop()


# --------------------------------------------------------- admission control

def test_queue_full_sheds_with_overloaded_code():
    d = _daemon(queue_limit=2)   # never started: the queue cannot drain
    dfgs = load_suite(names=["bitcount", "fft", "crc32"])
    t1 = d.submit(dfgs["bitcount"])
    t2 = d.submit(dfgs["fft"])
    t3 = d.submit(dfgs["crc32"])           # queue full -> shed immediately
    assert not t1.done and not t2.done
    assert t3.done                          # sheds resolve without a worker
    row = t3.wait(timeout=1)
    assert row["ok"] is False
    assert row["failure"] == "overloaded"
    assert row["reason"].startswith("overloaded: queue full")
    assert d.stats.shed == 1
    d.stop()


def test_deadline_budget_admission_sheds_hopeless_requests():
    d = _daemon(queue_limit=100)
    d._ewma_service_s = 10.0               # pretend solves take 10s
    d.submit(running_example())            # one queued request ahead
    t = d.submit(running_example(), deadline_s=0.5, max_route_hops=2)
    row = t.wait(timeout=1)
    assert row["failure"] == "overloaded"
    assert "deadline budget exceeded" in row["reason"]
    d.stop()


def test_deadline_expired_in_queue_returns_cancelled_without_solving():
    d = _daemon(workers=1)
    t = d.submit(running_example(), deadline_s=0.05, tenant="late")
    time.sleep(0.15)                       # burn the deadline while queued
    d.start()
    try:
        row = t.wait(timeout=10)
    finally:
        d.stop()
    assert row["ok"] is False and row["cancelled"] is True
    assert row["failure"] == "cancelled"
    assert "deadline expired in queue" in row["reason"]
    # the mapper never ran: no solver work, no cache consultation
    assert row["trace"]["windows_opened"] == 0
    assert d.stats.cancelled_in_queue == 1 and d.stats.solves == 0


# ------------------------------------------------------ speculative premapping

def test_neighbor_options_variants():
    from repro.api import resolve_options

    opts = resolve_options("fast", max_route_hops=1,
                           max_register_pressure=2)
    variants = neighbor_options(opts)
    hops = sorted(v.max_route_hops for v in variants)
    assert hops == [0, 1, 2]       # hops-1, relaxed-pressure (hops=1), hops+1
    assert any(v.max_register_pressure is None for v in variants)
    # hops=0 has no hops-1 neighbor
    assert sorted(v.max_route_hops
                  for v in neighbor_options(resolve_options("fast"))) == [1]


def test_speculative_warm_is_attributed(tmp_path):
    with _daemon(tmp_path, workers=1) as d:
        first = d.submit(running_example()).wait(timeout=60)
        assert first["ok"] and first["service"]["speculative"] is False
        deadline = time.time() + 20
        while d.stats.speculative_warms < 1:    # idle thread premaps hops=1
            assert time.time() < deadline, "speculator never warmed"
            time.sleep(0.05)
        row = d.submit(running_example(), max_route_hops=1).wait(timeout=60)
    assert row["ok"]
    assert row["source"] in ("memory", "disk")  # served from a warmed layer
    assert row["service"]["speculative"] is True
    assert row["metrics"]["cache"]["speculative"] is True
    assert d.stats.speculative_hits == 1


def test_deterministic_options_disable_speculation(tmp_path):
    d = CompileDaemon(CGRA(4, 4), "deterministic-ci", workers=1)
    assert d.speculate is False     # deterministic mapper bypasses caches
    d.stop()


# ------------------------------------------------------------ socket protocol

def test_socket_round_trip_and_error_isolation(tmp_path):
    sock = str(tmp_path / "d.sock")
    daemon = _daemon(tmp_path)
    server = DaemonServer(daemon, sock)
    server.start()
    try:
        with DaemonClient(sock) as c:
            assert c.ping()
            row = c.compile(running_example(), tenant="sock",
                            deadline_s=30.0,
                            options={"max_route_hops": 1})
            assert row["ok"] and row["service"]["tenant"] == "sock"
            assert row["service"]["deadline_s"] == 30.0
            # a bad request errors this line only; the connection survives
            with pytest.raises(DaemonError):
                c.request({"op": "no-such-op"})
            with pytest.raises(DaemonError):
                c.request({"op": "compile", "dfg": {"bogus": True}})
            assert c.ping()
            stats = c.stats()
            assert stats["completed"] == 1 and stats["failed"] == 0
        with DaemonClient(sock) as c2:
            assert c2.shutdown()
        assert server._shutdown_requested.wait(timeout=5)
    finally:
        server.stop()
    assert not os.path.exists(sock)     # clean shutdown unlinks the socket


def test_socket_concurrent_clients_coalesce(tmp_path):
    sock = str(tmp_path / "d.sock")
    daemon = _daemon(tmp_path, workers=1)
    server = DaemonServer(daemon, sock)
    server.start()
    rows, lock = [], threading.Lock()

    def one(i):
        with DaemonClient(sock) as c:
            row = c.compile(running_example(), tenant=f"c{i}")
        with lock:
            rows.append(row)

    try:
        threads = [threading.Thread(target=one, args=(i,)) for i in range(5)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not any(t.is_alive() for t in threads)
    finally:
        server.stop()
    assert len(rows) == 5 and all(r["ok"] for r in rows)
    # identical concurrent requests through the socket still solve once
    assert daemon.stats.solves == 1


def test_stale_socket_file_is_reclaimed(tmp_path):
    sock = str(tmp_path / "stale.sock")
    with open(sock, "w"):
        pass                      # a crashed daemon's leftover path
    daemon = _daemon(tmp_path)
    server = DaemonServer(daemon, sock)
    server.start()
    try:
        with DaemonClient(sock) as c:
            assert c.ping()
    finally:
        server.stop()


# -------------------------------------------------------------- trace rotation

def test_trace_rotation_writes_loadable_segments(tmp_path):
    trace_dir = str(tmp_path / "traces")
    with _daemon(tmp_path, trace_dir=trace_dir, rotate_every=2) as d:
        for hops in (0, 1, 0, 1):
            assert d.submit(running_example(),
                            max_route_hops=hops).wait(timeout=60)["ok"]
    segments = sorted(os.listdir(trace_dir))
    assert len(segments) >= 2          # 4 requests / rotate_every=2, + final
    names = set()
    for fn in segments:
        with open(os.path.join(trace_dir, fn)) as f:
            doc = json.load(f)
        assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
        names |= {e["name"] for e in doc["traceEvents"]}
    assert "daemon.request" in names   # per-request spans (§16.5)
    assert "compile" in names          # nested pipeline spans rotated too


def test_trace_report_reads_daemon_segments(tmp_path):
    import subprocess
    import sys

    trace_dir = str(tmp_path / "traces")
    with _daemon(tmp_path, trace_dir=trace_dir, rotate_every=100) as d:
        assert d.submit(running_example()).wait(timeout=60)["ok"]
    segments = os.listdir(trace_dir)
    assert segments
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run(
        [sys.executable, os.path.join(repo, "tools", "trace_report.py"),
         "--check", os.path.join(trace_dir, sorted(segments)[0])],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


# ------------------------------------------------------------- cache pruning

def test_daemon_prunes_disk_cache_during_idle_maintenance(tmp_path):
    d = _daemon(tmp_path, workers=1, cache_max_bytes=1, prune_every=1)
    with d:
        assert d.submit(running_example()).wait(timeout=60)["ok"]
        deadline = time.time() + 20
        while d.stats.cache_prunes < 1:    # piggybacks on the speculator
            assert time.time() < deadline, "maintenance never ran"
            time.sleep(0.05)
    assert d.stats.cache_evictions >= 1    # 1-byte budget evicts everything

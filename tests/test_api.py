"""Public ``repro.api`` layer tests (DESIGN.md §11): options resolution +
JSON round-trip + unknown-key rejection, ``map_dfg``-shim ↔ ``Compiler``
parity (random kwarg subsets, mappings and telemetry bit-identical), the
pre-PR golden deterministic 4×4 suite, Compiler sessions (compile /
compile_batch / compile_racing), and the unified CompileResult schema."""

import hashlib
import inspect
import json
import os
import random

import pytest

from repro.api import (
    FAILURE_KINDS,
    MAPPER_FIELDS,
    PROFILES,
    Compiler,
    CompileOptions,
    classify_failure,
    options_from_args,
    resolve_options,
)
from repro.core import CGRA, map_dfg, running_example
from repro.core.arch import ArchSpec, get_preset
from repro.core.benchsuite import load_suite
from repro.core.dfg import DFG, Edge
from repro.core.mapper import _map_dfg_impl, clear_mapping_cache


@pytest.fixture(autouse=True)
def _fresh_memory_cache():
    clear_mapping_cache()
    yield
    clear_mapping_cache()


# ----------------------------------------------------------------- options

def test_options_defaults_match_mapper_signature():
    """The shim contract: every mapper field exists on ``_map_dfg_impl`` with
    the identical default, so CompileOptions() == a bare map_dfg call."""
    sig = inspect.signature(_map_dfg_impl)
    opts = CompileOptions()
    for f in MAPPER_FIELDS:
        assert f in sig.parameters, f
        assert sig.parameters[f].default == getattr(opts, f), f
    # and nothing mapper-side is missing from the options (should_stop is
    # the deliberate exception: a callable cannot be serialised)
    mapper_params = set(sig.parameters) - {"dfg", "cgra", "should_stop"}
    assert mapper_params == set(MAPPER_FIELDS)


def test_options_json_roundtrip():
    opts = resolve_options("fast", max_slack=1, cache_dir="/tmp/x", seed=7)
    again = CompileOptions.from_json(opts.to_json())
    assert again == opts
    assert again.profile == "fast" and again.max_slack == 1


def test_options_unknown_keys_rejected():
    with pytest.raises(ValueError, match="bogus"):
        CompileOptions.from_dict({"bogus": 1})
    with pytest.raises(ValueError, match="bogus"):
        CompileOptions.from_json('{"bogus": 1, "max_slack": 2}')
    with pytest.raises(TypeError):
        CompileOptions(bogus=1)
    with pytest.raises(TypeError):
        CompileOptions().replace(bogus=1)
    with pytest.raises(ValueError, match="malformed"):
        CompileOptions.from_json("[1, 2]")
    with pytest.raises(ValueError, match="malformed"):
        CompileOptions.from_json("{truncated")


def test_options_validation_rejects_garbage():
    with pytest.raises(ValueError, match="connectivity"):
        CompileOptions(connectivity="loose").validate()
    with pytest.raises(ValueError, match="backend"):
        CompileOptions(backend="gurobi").validate()
    with pytest.raises(ValueError, match="striping"):
        CompileOptions(window_offset=2, window_stride=2).validate()
    with pytest.raises(ValueError, match="time_budget_s"):
        CompileOptions(time_budget_s=0).validate()
    with pytest.raises(ValueError, match="jobs"):
        CompileOptions(jobs=0).validate()
    with pytest.raises(ValueError, match="profile"):
        CompileOptions(profile="warp-speed").validate()


def test_profiles_resolve_and_override():
    for name in PROFILES:
        assert resolve_options(name).profile == name
        PROFILES[name].validate()
    ci = resolve_options("deterministic-ci")
    assert ci.deterministic and not ci.use_cache and ci.jobs == 1
    fast = resolve_options("fast", time_budget_s=5.0)
    assert fast.time_budget_s == 5.0                      # override wins
    assert fast.max_slack == PROFILES["fast"].max_slack   # profile value kept
    with pytest.raises(ValueError, match="unknown profile"):
        resolve_options("turbo")


def test_cli_args_single_definition():
    """Every CLI resolves flags through the one add_cli_args/resolve_options
    path; unsupplied flags keep the profile's value."""
    import argparse

    from repro.api import add_cli_args

    ap = argparse.ArgumentParser()
    add_cli_args(ap)
    args = ap.parse_args(["--profile", "fast", "--max-slack", "1",
                          "--no-cache"])
    opts = options_from_args(args)
    assert opts.profile == "fast"
    assert opts.max_slack == 1                            # flag override
    assert opts.use_cache is False                        # --no-cache
    assert opts.time_budget_s == PROFILES["fast"].time_budget_s
    # no flags at all -> plain defaults
    opts2 = options_from_args(ap.parse_args([]))
    assert opts2 == CompileOptions()


# ------------------------------------------------------------ shim parity

#: kwarg pool for the random-subset parity trials; every value keeps the
#: search deterministic and sub-second on the small fixtures below.
_KWARG_POOL = {
    "max_slack": [0, 1, 2],
    "max_ii": [5, 8, 16],
    "connectivity": ["strict", "paper"],
    "seed": [1, 3],
    "max_register_pressure": [6, 8],
    "window_stride": [2, 3],
}


@pytest.mark.parametrize("trial", range(8))
def test_shim_and_compiler_parity_random_kwargs(trial):
    """Property test: ``map_dfg(**kw)`` and ``Compiler(...).compile(dfg)``
    produce identical mappings AND identical telemetry for random kwarg
    subsets (deterministic mode, so 'identical' means bit-identical)."""
    rng = random.Random(trial)
    kw = {"deterministic": True, "use_cache": False}
    for key, vals in _KWARG_POOL.items():
        if rng.random() < 0.5:
            kw[key] = rng.choice(vals)
    if trial % 2 == 0:
        dfg, cgra = running_example(), CGRA(2, 2)
    else:
        dfg, cgra = load_suite(names=["bitcount"])["bitcount"], CGRA(3, 3)

    a = map_dfg(dfg, cgra, **kw)
    b = Compiler(cgra, resolve_options(**kw)).compile(dfg)

    assert a.ok == b.ok, kw
    assert a.reason == b.reason, kw
    if a.ok:
        assert a.mapping.ii == b.ii
        assert a.mapping.t_abs == b.mapping.t_abs
        assert a.mapping.placement == b.mapping.placement
    s, t = a.stats, b.trace
    assert (s.m_ii, s.res_ii, s.rec_ii) == (b.m_ii, b.res_ii, b.rec_ii)
    assert s.rounds == t.rounds
    assert s.windows_opened == t.windows_opened
    assert s.time_solutions_tried == t.time_solutions_tried
    assert s.mono_failures == t.mono_failures
    assert s.space_nodes_visited == t.space_nodes_visited
    assert s.backend == b.backend


def test_shim_rejects_unknown_kwargs():
    with pytest.raises(TypeError):
        map_dfg(running_example(), CGRA(2, 2), warp_factor=9)
    # service-only CompileOptions fields are NOT mapper kwargs: accepting
    # them silently would drop the caller's budget/profile on the floor
    for bad in ({"jobs": 4}, {"deadline_s": 1.0}, {"profile": "fast"},
                {"racing_workers": 2}, {"arch": "paper_homogeneous_4x4"}):
        with pytest.raises(TypeError, match="unexpected keyword"):
            map_dfg(running_example(), CGRA(2, 2), **bad)


_GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data_golden_4x4.json")


def _mapping_sha(mapping) -> str:
    return hashlib.sha1(json.dumps(
        {"t_abs": mapping.t_abs, "placement": mapping.placement},
        separators=(",", ":")).encode()).hexdigest()


def test_deterministic_4x4_suite_bit_identical_to_pre_pr():
    """Acceptance gate: the shimmed ``map_dfg`` reproduces the pre-PR
    deterministic 4×4 suite mappings bit-for-bit (golden hashes were
    generated at the pre-refactor tree; deterministic mode is
    load-independent, so equality means the search path is untouched)."""
    with open(_GOLDEN_PATH) as f:
        golden = json.load(f)
    cgra = CGRA(4, 4)
    suite = load_suite()
    assert set(golden) == set(suite)
    for name, dfg in sorted(suite.items()):
        res = map_dfg(dfg, cgra, deterministic=True, use_cache=False)
        assert res.ok, f"{name}: {res.reason}"
        assert res.mapping.ii == golden[name]["ii"], name
        assert _mapping_sha(res.mapping) == golden[name]["sha1"], name


@pytest.mark.parametrize("name", ["bitcount", "gsm", "susan"])
def test_compiler_matches_golden(name):
    """The Compiler path lands on the same golden mappings as the shim."""
    with open(_GOLDEN_PATH) as f:
        golden = json.load(f)
    comp = Compiler(CGRA(4, 4), resolve_options("deterministic-ci"))
    res = comp.compile(load_suite(names=[name])[name])
    assert res.ok and res.ii == golden[name]["ii"]
    assert _mapping_sha(res.mapping) == golden[name]["sha1"]


# ---------------------------------------------------------------- Compiler

def test_compiler_target_resolution():
    spec = get_preset("paper_homogeneous_4x4")
    by_cgra = Compiler(CGRA(4, 4))
    by_spec = Compiler(spec)
    by_name = Compiler("paper_homogeneous_4x4")
    by_opts = Compiler(options=resolve_options(arch="paper_homogeneous_4x4"))
    assert by_cgra.cgra == by_spec.cgra == by_name.cgra == by_opts.cgra
    assert by_cgra.spec is None and by_name.spec == spec
    with pytest.raises(ValueError, match="no target machine"):
        Compiler()
    with pytest.raises(TypeError, match="target"):
        Compiler(42)
    with pytest.raises(TypeError, match="options"):
        Compiler(CGRA(2, 2), options=3.14)


def test_compiler_session_overrides_do_not_mutate():
    comp = Compiler(CGRA(2, 2), "deterministic-ci")
    res = comp.compile(running_example(), seed=5)
    assert res.ok
    assert comp.options.seed == 0          # per-call override, session intact
    with pytest.raises(TypeError):
        comp.compile(running_example(), bogus=1)


def test_compiler_validate_workload():
    spec = ArchSpec(name="alu_only", rows=2, cols=2,
                    pe_classes=(("alu",),) * 4)
    comp = Compiler(spec)
    mul = DFG(num_nodes=3, ops=["input", "input", "mul"],
              edges=[Edge(0, 2), Edge(1, 2)])
    assert comp.validate_workload([mul]) != []
    assert Compiler(CGRA(2, 2)).validate_workload([mul]) == []


def test_compile_batch_rejects_mismatched_names():
    suite = load_suite(names=["bitcount", "fft"])
    comp = Compiler(CGRA(4, 4), "deterministic-ci")
    with pytest.raises(ValueError, match="names"):
        comp.compile_batch(list(suite.values()), names=["just-one"])


def test_compile_batch_rows_and_mapping_reconstruction():
    suite = load_suite(names=["bitcount", "fft"])
    comp = Compiler(CGRA(4, 4), "deterministic-ci")
    batch = comp.compile_batch(list(suite.values()))
    assert batch.ok and len(batch) == 2
    for dfg, row in zip(suite.values(), batch):
        assert row.source == "solve" and row.failure is None
        # the mapping was reconstructed from the worker row, not re-solved
        assert row.mapping is not None
        assert row.mapping.validate() == []
        direct = comp.compile(dfg)
        assert row.ii == direct.ii
        assert row.mapping.t_abs == direct.mapping.t_abs
        assert row.mapping.placement == direct.mapping.placement
    d = batch.as_dict()
    assert d["ok"] and d["cache"]["solved"] == 2
    assert all(j["failure"] is None for j in d["jobs"])


def test_compile_batch_cache_provenance(tmp_path):
    suite = load_suite(names=["bitcount", "fft"])
    comp = Compiler(CGRA(4, 4), resolve_options(cache_dir=str(tmp_path),
                                                jobs=1, deadline_s=30.0))
    cold = comp.compile_batch(list(suite.values()))
    assert cold.cache_counters["solved"] == 2
    clear_mapping_cache()
    warm = comp.compile_batch(list(suite.values()))
    assert warm.cache_counters["disk_hits"] == 2
    assert [r.ii for r in warm] == [r.ii for r in cold]
    assert all(r.source == "disk" for r in warm)
    assert comp.cache is not None and len(comp.cache) == 2
    assert comp.cache is comp.cache       # one stable handle per session
    assert Compiler(CGRA(2, 2), "deterministic-ci").cache is None


def test_compile_racing_deterministic_falls_back():
    comp = Compiler(CGRA(2, 2), "deterministic-ci")
    res = comp.compile_racing(running_example(), workers=4)
    assert res.ok and res.ii == 4
    assert res.mapping.validate() == []


# ------------------------------------------------------------ result schema

def test_failure_code_infeasible():
    spec = ArchSpec(name="alu_only", rows=2, cols=2,
                    pe_classes=(("alu",),) * 4)
    mul = DFG(num_nodes=3, ops=["input", "input", "mul"],
              edges=[Edge(0, 2), Edge(1, 2)])
    res = Compiler(spec, "deterministic-ci").compile(mul)
    assert not res.ok and res.failure == "infeasible"
    assert res.source is None and res.ii is None
    assert res.as_dict()["failure"] == "infeasible"


def test_failure_code_exhausted_search():
    d = load_suite(names=["bitcount"])["bitcount"]
    res = Compiler(CGRA(1, 1), "deterministic-ci").compile(d, max_ii=4)
    assert not res.ok
    assert res.failure in ("search-exhausted", "budget-exhausted")
    assert res.failure in FAILURE_KINDS


def test_classify_failure_table():
    assert classify_failure(True, "") is None
    assert classify_failure(False, "infeasible by capability: x") == "infeasible"
    assert classify_failure(False, "time budget exhausted") == "budget-exhausted"
    assert classify_failure(False, "no mapping up to II=9 within budget") == "budget-exhausted"
    assert classify_failure(False, "search space exhausted up to II=9") == "search-exhausted"
    assert classify_failure(False, "anything", cancelled=True) == "cancelled"
    assert classify_failure(False, "ValueError: bad dfg") == "error"
    # worker-death rows (pool failures) are exception-typed too
    assert classify_failure(False, "BrokenProcessPool: a child died") == "error"
    assert classify_failure(False, "weird") == "unknown"


def test_result_phase_timings_cover_pipeline():
    res = Compiler(CGRA(3, 3), "deterministic-ci").compile(
        load_suite(names=["gsm"])["gsm"])
    assert res.ok
    p = res.phases
    assert p.time_s > 0 and p.space_s > 0 and p.validate_s > 0
    assert p.total_s >= p.validate_s
    row = res.as_dict()
    assert set(row["phases"]) == {"time_s", "space_s", "validate_s",
                                  "exact_s", "total_s"}
    assert row["source"] == "solve"
    assert row["trace"]["windows_opened"] >= 1

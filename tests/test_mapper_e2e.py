"""End-to-end mapper tests: paper running example, benchmark suite subset,
functional equivalence of mapped execution, joint-baseline agreement."""

import pytest

from repro.core import CGRA, map_dfg, running_example
from repro.core.baseline import HAVE_Z3, map_dfg_joint
from repro.core.benchsuite import TABLE3_BENCHMARKS, load_suite, make_benchmark_dfg
from repro.core.simulate import check_equivalence, check_register_pressure


def test_running_example_maps_at_paper_ii():
    res = map_dfg(running_example(), CGRA(2, 2), time_budget_s=30)
    assert res.ok
    assert res.mapping.ii == 4          # paper Fig. 2b: II = 4 = mII
    assert res.mapping.validate() == []


def test_running_example_functional_equivalence():
    res = map_dfg(running_example(), CGRA(2, 2), time_budget_s=30)
    rep = check_equivalence(res.mapping, num_iters=8)
    assert rep.cycles == res.mapping.schedule_length + 7 * res.mapping.ii
    assert check_register_pressure(res.mapping) <= CGRA(2, 2).registers_per_pe


def test_benchsuite_statistics_match_table3():
    suite = load_suite()
    assert len(suite) == 17
    for name, (n, rec) in TABLE3_BENCHMARKS.items():
        assert suite[name].num_nodes == n
        assert suite[name].rec_ii() == rec


@pytest.mark.parametrize("name", ["bitcount", "fft", "gsm", "lud", "susan"])
@pytest.mark.parametrize("size", [2, 5, 10])
def test_benchmarks_map_and_execute(name, size):
    d = load_suite()[name]
    res = map_dfg(d, CGRA(size, size), time_budget_s=30)
    assert res.ok, f"{name}@{size}: {res.reason}"
    assert res.mapping.ii >= res.stats.m_ii
    check_equivalence(res.mapping, num_iters=4)


@pytest.mark.skipif(not HAVE_Z3, reason="z3 unavailable")
@pytest.mark.parametrize("name", ["bitcount", "fft"])
def test_joint_baseline_agrees_on_ii(name):
    """The decoupled mapper must not lose II quality vs the joint search
    (paper: same II in 57/68; here we check small cases exactly)."""
    d = load_suite()[name]
    c = CGRA(3, 3)
    ours = map_dfg(d, c, time_budget_s=60)
    joint = map_dfg_joint(d, c, time_budget_s=120)
    assert ours.ok and joint.ok
    assert ours.mapping.validate() == []
    assert joint.mapping.validate() == []
    assert ours.mapping.ii <= joint.mapping.ii  # decoupling never worse here
    check_equivalence(joint.mapping, num_iters=4)


def test_mapping_pretty_and_kernel_table():
    res = map_dfg(running_example(), CGRA(2, 2), time_budget_s=30)
    table = res.mapping.kernel_table()
    assert len(table) == 4
    assert sum(len(r) for r in table) == 14
    assert "II=4" in res.mapping.pretty()


def test_register_pressure_aware_mapping():
    """Paper §V-3 future-work extension: mappings must fit the register file
    when max_register_pressure is given.

    Runs in deterministic mode: the search is budgeted in visited nodes /
    solver steps instead of wall-clock, so the result cannot depend on machine
    load or test order (this used to flake in full-suite runs only).
    """
    from repro.core.simulate import check_register_pressure

    d = load_suite()["fft"]
    c = CGRA(3, 3)
    res = map_dfg(d, c, deterministic=True, max_register_pressure=4,
                  use_cache=False)
    assert res.ok
    assert check_register_pressure(res.mapping) <= 4
    check_equivalence(res.mapping, num_iters=4)

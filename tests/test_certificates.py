"""Exact joint backend + certificate machinery (DESIGN.md §14).

Covers the joint solver's three verdicts (structural unsat, sat-with-witness,
budget unknown), the certificate life-cycle (free bound proof, refutation
sweep, better-found adoption, timeout), the independent verifier's rejection
of corrupted certificates — corruption must target something load-bearing:
a slack node's ``t_abs`` can legitimately move, so the fixtures break the
claimed II, the probe coverage, and the mapping payload instead — and the
``tools/check_certificates.py`` CLI including its regression gate.
"""

from __future__ import annotations

import importlib.util
import json
import os

import pytest

from repro.api import Compiler, resolve_options
from repro.core import CGRA, map_dfg, running_example
from repro.core.benchsuite import load_suite
from repro.core.exact_backends import (
    CERTIFICATE_VERSION,
    Certificate,
    certify_mapping,
    solve_joint,
    verify_certificate,
)
from repro.core.exact_backends.joint import grid_automorphisms
from repro.core.simulate import check_equivalence

_TOOL = os.path.join(os.path.dirname(__file__), os.pardir, "tools",
                     "check_certificates.py")


def _tool_main():
    spec = importlib.util.spec_from_file_location("check_certificates", _TOOL)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod.main


def _bitcount_cert():
    """A real end-to-end certificate: bitcount on the paper 4x4 grid."""
    suite = load_suite()
    dfg = suite["bitcount"]
    cgra = CGRA(4, 4)
    res = map_dfg(dfg, cgra, deterministic=True, use_cache=False)
    assert res.ok
    cert, better = certify_mapping(dfg, cgra, res.mapping, deterministic=True)
    assert better is None
    return dfg, cgra, cert


# ------------------------------------------------------------------- joint

def test_joint_structural_unsat_is_free():
    """II below capacity feasibility is refuted without any search."""
    dfg = running_example()                      # 14 nodes
    out = solve_joint(dfg, CGRA(2, 2), 3)        # 4 PEs x 3 slots < 14
    assert out.status == "unsat"
    assert out.nodes_visited == 0


def test_joint_sat_witness_is_a_real_mapping():
    dfg = running_example()
    out = solve_joint(dfg, CGRA(2, 2), 4)
    assert out.status == "sat"
    assert out.mapping is not None and out.mapping.ii == 4
    assert out.mapping.validate() == []
    check_equivalence(out.mapping)


def test_joint_unknown_on_starved_budget():
    dfg = load_suite()["sha1"]                   # needs ~28k nodes at II=2
    out = solve_joint(dfg, CGRA(4, 4), 2, node_budget=50)
    assert out.status == "unknown"
    assert out.mapping is None


def test_grid_automorphisms_counts():
    # dihedral group of the square mesh; rectangular mesh keeps only the
    # symmetries that preserve the aspect ratio
    assert len(grid_automorphisms(CGRA(4, 4))) == 8
    assert len(grid_automorphisms(CGRA(3, 4))) == 4
    # torus adds the translations: 8 x 16 for the 4x4
    assert len(grid_automorphisms(CGRA(4, 4, topology="torus"))) == 128


# ----------------------------------------------------------------- certify

def test_certify_free_bound_proof():
    dfg, cgra, cert = _bitcount_cert()
    assert cert.status == "optimal"
    assert cert.ii_opt == cert.ii == cert.m_ii
    assert cert.probes[0]["outcome"] == "bound"
    assert verify_certificate(cert, dfg, cgra) == []
    # and it round-trips through JSON exactly
    wire = json.loads(json.dumps(cert.as_dict()))
    assert verify_certificate(Certificate.from_dict(wire), dfg, cgra) == []


@pytest.mark.slow
def test_certify_refutation_sweep_proves_optimal():
    """sha1's II=3 is optimal: the joint model refutes II=2 by search."""
    dfg = load_suite()["sha1"]
    cgra = CGRA(4, 4)
    res = map_dfg(dfg, cgra, deterministic=True, use_cache=False)
    assert res.ok and res.mapping.ii == 3
    cert, better = certify_mapping(dfg, cgra, res.mapping, deterministic=True)
    assert better is None
    assert cert.status == "optimal" and cert.ii_opt == 3
    assert any(p["outcome"] == "unsat" and p["ii"] == 2 for p in cert.probes)
    assert verify_certificate(cert, dfg, cgra) == []


def test_certify_better_found_adopts_valid_mapping():
    """A deliberately suboptimal (but valid) mapping gets strictly beaten:
    the joint backend finds II=4 on the 2x2 running example and proves it
    optimal, and the certificate adopts the improved mapping."""
    dfg = running_example()
    cgra = CGRA(2, 2)
    worse = solve_joint(dfg, cgra, 5)            # valid witness at II=5
    assert worse.status == "sat" and worse.mapping is not None
    cert, better = certify_mapping(
        dfg, cgra, worse.mapping, deterministic=True
    )
    assert cert.status == "better-found"
    assert better is not None and better.ii == cert.ii_opt == 4
    assert cert.ii_portfolio == 5
    assert better.validate() == []
    check_equivalence(better)
    assert verify_certificate(cert, dfg, cgra) == []


def test_certify_timeout_keeps_partial_lower_bound():
    dfg = load_suite()["susan"]
    cgra = CGRA(4, 4)
    res = map_dfg(dfg, cgra, deterministic=True, use_cache=False)
    assert res.ok
    cert, better = certify_mapping(
        dfg, cgra, res.mapping, node_budget=50, deterministic=True
    )
    assert cert.status == "timeout"
    assert better is None and cert.ii_opt is None
    assert cert.m_ii <= cert.ii_lower_bound <= cert.ii
    # a timeout certificate is still a consistent, verifiable document
    assert verify_certificate(cert, dfg, cgra) == []


def test_certificate_from_dict_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown Certificate keys"):
        Certificate.from_dict({"kernel": "x", "bogus": 1})


# ---------------------------------------------------------------- verifier

def test_verifier_catches_corrupted_certificates():
    dfg, cgra, cert = _bitcount_cert()
    base = cert.as_dict()

    # (a) inflated lower bound with no probes backing it
    c = json.loads(json.dumps(base))
    c["ii_lower_bound"] += 1
    c["ii"] += 1
    c["ii_opt"] += 1
    c["mapping"]["ii"] += 1
    assert any("not covered" in p or "bound" in p
               for p in verify_certificate(c, dfg, cgra))

    # (b) optimality claim below the recomputable mII
    c = json.loads(json.dumps(base))
    c["m_ii"] -= 1
    c["res_ii"] -= 1
    assert any("bound mismatch" in p for p in verify_certificate(c, dfg, cgra))

    # (c) mapping payload with a placement collision
    c = json.loads(json.dumps(base))
    lab = [t % c["mapping"]["ii"] for t in c["mapping"]["t_abs"]]
    v = next(u for u in range(1, len(lab)) if lab[u] == lab[0])
    c["mapping"]["placement"][v] = c["mapping"]["placement"][0]
    assert any("mapping" in p for p in verify_certificate(c, dfg, cgra))

    # (d) certificate for a different kernel
    other = load_suite()["gsm"]
    assert any("hash mismatch" in p for p in verify_certificate(base, other, cgra))

    # (e) unsupported schema version
    c = json.loads(json.dumps(base))
    c["version"] = CERTIFICATE_VERSION + 1
    assert any("version" in p for p in verify_certificate(c, dfg, cgra))


# --------------------------------------------------------------------- CLI

def test_check_certificates_cli_roundtrip(tmp_path):
    main = _tool_main()
    dfg, cgra, cert = _bitcount_cert()
    row = {"name": "bitcount", "size": 4, "ok": True,
           "ii": cert.ii, "ii_opt": cert.ii_opt,
           "certificate": cert.as_dict()}
    good = tmp_path / "bench.json"
    good.write_text(json.dumps({"rows": [row]}))
    assert main([str(good)]) == 0
    assert main([str(good), "--min-certified", "1", "--at-size", "4"]) == 0
    assert main([str(good), "--min-certified", "2", "--at-size", "4"]) == 1

    # corrupted artifact: the embedded claim no longer matches the row
    bad_row = json.loads(json.dumps(row))
    bad_row["ii"] = bad_row["certificate"]["ii"] - 1
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"rows": [bad_row]}))
    assert main([str(bad)]) == 1

    # regression gate: a fresh row doing worse than the recorded optimum
    worse = json.loads(json.dumps(row))
    worse["ii"] = row["ii"] + 1
    del worse["certificate"]
    fresh = tmp_path / "fresh.json"
    fresh.write_text(json.dumps({"rows": [worse]}))
    assert main([str(fresh), "--baseline", str(good)]) == 1
    # and a non-regressing fresh row passes
    same = json.loads(json.dumps(row))
    del same["certificate"]
    fresh.write_text(json.dumps({"rows": [same]}))
    assert main([str(fresh), "--baseline", str(good)]) == 0


def test_compiler_certify_profile_threads_through():
    """`certify` profile: rows gain ii_opt/certificate; plain rows do not."""
    comp = Compiler(CGRA(4, 4), resolve_options("certify"),
                    use_cache=False, deterministic=True)
    res = comp.compile(load_suite()["bitcount"])
    row = res.as_dict()
    assert row["ii_opt"] == row["ii"]
    assert row["certificate"]["status"] == "optimal"
    plain = Compiler(CGRA(4, 4), resolve_options("deterministic-ci"),
                     use_cache=False).compile(load_suite()["bitcount"])
    prow = plain.as_dict()
    assert "ii_opt" not in prow and "certificate" not in prow

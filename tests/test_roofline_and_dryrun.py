"""Roofline parsing/analysis tests + a miniature (8-device) dry-run that
exercises the full production machinery end-to-end in a subprocess."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.roofline.analysis import (
    HW, Roofline, active_param_count, model_flops_train, parse_collectives,
)

HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p = bf16[8,128]{1,0} parameter(0)
  %ag = bf16[64,128]{1,0} all-gather(%p), replica_groups={{0,1}}, dimensions={0}
  %ar = f32[1024]{0} all-reduce(%x), to_apply=%add
  %rs = f32[128]{0} reduce-scatter(%y), dimensions={0}
  %cp = bf16[8,128]{1,0} collective-permute(%p), source_target_pairs={{0,1}}
  %a2a = (f32[16]{0}, f32[16]{0}) all-to-all(%u, %v), dimensions={0}
  %ard = f32[4]{0} all-reduce-done(%h)
}
"""


def test_parse_collectives_kinds_and_wire_factors():
    stats = parse_collectives(HLO_SAMPLE)
    assert stats.count_by_kind == {
        "all-gather": 1, "all-reduce": 1, "reduce-scatter": 1,
        "collective-permute": 1, "all-to-all": 1,
    }
    assert stats.bytes_by_kind["all-gather"] == 64 * 128 * 2
    assert stats.bytes_by_kind["all-reduce"] == 1024 * 4 * 2  # 2x ring factor
    assert stats.bytes_by_kind["reduce-scatter"] == 128 * 4
    assert stats.bytes_by_kind["collective-permute"] == 8 * 128 * 2
    assert stats.bytes_by_kind["all-to-all"] == 2 * 16 * 4


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops=197e12, hbm_bytes=819e9, collective_bytes=100e9,
                 chips=256, hw=HW())
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.t_collective == pytest.approx(2.0)
    assert r.bottleneck == "collective"
    assert r.mfu_upper_bound(197e12 * 256 * 2.0) == pytest.approx(1.0)


def test_active_param_count_sane():
    from repro.configs import get_config

    # qwen3-0.6b: ~0.6B params (tied embeddings)
    n = active_param_count(get_config("qwen3-0.6b"))
    assert 0.3e9 < n < 0.9e9
    # deepseek-v3: ~37B ACTIVE (not 671B)
    n = active_param_count(get_config("deepseek-v3-671b"))
    assert 20e9 < n < 60e9


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import build_model
    from repro.models.zoo import train_input_specs
    from repro.optim import AdamWConfig, adamw_init, adamw_update, build_opt_shardings
    from repro.sharding import batch_shardings, param_shardings
    from repro.roofline.analysis import analyze_compiled

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_config("{arch}").reduced()
    spec = build_model(cfg, mesh=mesh, data_axes=("pod", "data"))
    params_shape = jax.eval_shape(spec.init, jax.random.PRNGKey(0))
    p_sh = param_shardings(params_shape, mesh, min_shard_size=4)
    opt_cfg = AdamWConfig()
    opt_shape = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_shape)
    o_sh = build_opt_shardings(params_shape, p_sh, mesh)
    from repro.models.api import ShapeSpec
    shape = ShapeSpec("mini", 64, 8, "train")
    batch = train_input_specs(cfg, shape)
    b_sh = batch_shardings(batch, mesh, ("pod", "data"))

    def train_step(params, opt, batch):
        (loss, m), g = jax.value_and_grad(spec.loss_fn, has_aux=True)(params, batch)
        p2, o2, om = adamw_update(g, opt, params, opt_cfg)
        return p2, o2, loss

    compiled = jax.jit(
        train_step, in_shardings=(p_sh, o_sh, b_sh), out_shardings=(p_sh, o_sh, None),
    ).lower(params_shape, opt_shape, batch).compile()
    roof = analyze_compiled(compiled, 8)
    assert roof.flops > 0
    mem = compiled.memory_analysis()
    print("OK", roof.flops, roof.collective_bytes, mem.temp_size_in_bytes)
""")


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "deepseek-moe-16b", "gemma2-9b"])
def test_mini_multipod_dryrun(arch):
    """Full production path (mesh + rules + ZeRO + train step) on 8 fake
    devices — the 512-device version is exercised by launch/dryrun.py."""
    env = {**os.environ, "PYTHONPATH": "src"}
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", MINI_DRYRUN.format(arch=arch)],
        capture_output=True, text=True, timeout=600, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK" in proc.stdout


def test_placement_single_hop():
    from repro.core.placement import linear_pipeline, place_stages

    placement = place_stages(linear_pipeline(8), (4, 4))
    assert placement is not None
    assert placement.ii == 1                      # fully spatial pipeline
    assert placement.single_hop_fraction() == 1.0
    assert len(set(placement.stage_to_device)) == 8


def test_placement_device_order():
    from repro.core.placement import device_order_for_pipeline

    order = device_order_for_pipeline(16, (4, 4))
    assert sorted(order) == list(range(16))       # a Hamiltonian ordering

"""Executable form of the paper's §IV-D theorem (hypothesis property test).

Property: for random DFGs, any time solution satisfying the *strict*
constraint set admits a monomorphism found by the space search (with the
mapper's retry budget). The published ("paper") constraint set provably does
NOT have this property (see test_time_and_space.py counterexample); strict
mode plus mapper retries is what makes the pipeline complete in practice.
"""

import random

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import CGRA
from repro.core.dfg import DFG, Edge
from repro.core.mapper import map_dfg
from repro.core.time_smt import TimeSolver
from repro.core.mono import find_monomorphism


@st.composite
def small_dfgs(draw):
    n = draw(st.integers(6, 18))
    rng = random.Random(draw(st.integers(0, 2**16)))
    n_inputs = max(2, n // 4)
    ops = ["input"] * n_inputs
    edges = []
    for v in range(n_inputs, n):
        k = rng.choice([1, 1, 2])
        preds = rng.sample(range(v), min(k, v))
        ops.append("add" if len(preds) == 2 else "mov")
        edges.extend(Edge(p, v) for p in preds)
    # one loop-carried edge closing a small recurrence; the head must have
    # spare arity for the carried operand
    tail = n - 1
    indeg = {v: 0 for v in range(n)}
    for e in edges:
        indeg[e.dst] += 1
    candidates = [v for v in range(n_inputs, tail) if indeg[v] <= 1]
    if candidates:
        head = rng.choice(candidates)
        edges.append(Edge(tail, head, 1))
        ops[head] = "phi"
    d = DFG(num_nodes=n, edges=edges, ops=ops, name="prop")
    d.validate()
    return d


@given(small_dfgs(), st.sampled_from([(2, 2), (3, 3), (4, 4)]))
@settings(max_examples=25, deadline=None)
def test_strict_time_solutions_admit_space_solutions(dfg, grid):
    cgra = CGRA(*grid)
    res = map_dfg(dfg, cgra, time_budget_s=20)
    # the mapper must find a complete mapping (strict constraints + retries)
    assert res.ok, f"mapper failed: {res.reason} (mII={res.stats.m_ii})"
    assert res.mapping.validate() == []


@given(small_dfgs())
@settings(max_examples=15, deadline=None)
def test_first_strict_solution_usually_embeds_directly(dfg):
    """Quantifies the theorem-gap: on random loop DFGs the *first* strict time
    solution almost always embeds (we assert the mapper-level guarantee above;
    here we only record that a direct embed exists for the sampled cases that
    produce a solution at mII on 3x3)."""
    cgra = CGRA(3, 3)
    from repro.core.schedule import min_ii

    ii = min_ii(dfg, cgra)
    try:
        solver = TimeSolver(dfg, cgra, ii, timeout_s=10)
    except ValueError:
        return  # infeasible window at mII — II search territory, not the gap
    sol = solver.next_solution()
    if sol is None:
        return
    space = find_monomorphism(dfg, cgra, sol.labels, ii, timeout_s=10)
    if space is not None:
        from repro.core.mono import check_monomorphism

        assert check_monomorphism(dfg, cgra, sol.labels, space.placement, ii) == []

"""Schedule-layer tests: ASAP/ALAP/MobS/KMS against the paper's Tab. I/II."""

import pytest

from repro.core import CGRA, min_ii, rec_ii, res_ii, running_example
from repro.core.schedule import (
    KMS, alap_schedule, asap_schedule, mobility_schedule, modulo_windows,
)

# Tab. I rows (paper)
ASAP_ROWS = {0: {0, 1, 2, 3, 4}, 1: {5, 11}, 2: {6, 12}, 3: {7, 8, 13}, 4: {9}, 5: {10}}
ALAP_ROWS = {0: {4}, 1: {3, 5}, 2: {0, 2, 6}, 3: {1, 8, 11}, 4: {7, 9, 12}, 5: {10, 13}}
MOBS_ROWS = {
    0: {0, 1, 2, 3, 4},
    1: {0, 1, 2, 3, 5, 11},
    2: {0, 1, 2, 6, 11, 12},
    3: {1, 7, 8, 11, 12, 13},
    4: {7, 9, 12, 13},
    5: {10, 13},
}


def rows_of(schedule):
    out = {}
    for v, t in enumerate(schedule):
        out.setdefault(t, set()).add(v)
    return out


def test_asap_matches_paper_table1():
    assert rows_of(asap_schedule(running_example())) == ASAP_ROWS


def test_alap_matches_paper_table1():
    assert rows_of(alap_schedule(running_example())) == ALAP_ROWS


def test_mobs_matches_paper_table1():
    mobs = mobility_schedule(running_example())
    got = {t: set(row) for t, row in enumerate(mobs.rows())}
    assert got == MOBS_ROWS


def test_mii_matches_paper_running_example():
    d = running_example()
    c = CGRA(2, 2)
    assert res_ii(d, c) == 4          # ceil(14/4)
    assert rec_ii(d) == 4
    assert min_ii(d, c) == 4


def test_kms_folding_covers_mobs():
    """KMS = MobS folded by II (paper's Tab. II up to kernel-row rotation)."""
    d = running_example()
    kms = KMS(mobility_schedule(d), 4)
    assert kms.num_folds == 2          # ceil(6/4), paper: 2 interleaved iters
    rows = kms.rows()
    assert len(rows) == 4
    # every MobS entry appears exactly once with the right fold
    seen = set()
    for kt, row in enumerate(rows):
        for v, fold in row:
            t_abs = fold * 4 + kt
            assert kms.mobs.asap[v] <= t_abs <= kms.mobs.alap[v]
            seen.add((v, t_abs))
    total = sum(m.alap[v] - m.asap[v] + 1 for m in [kms.mobs] for v in range(14))
    assert len(seen) == total


def test_connectivity_degree():
    assert CGRA(2, 2).connectivity_degree == 3    # paper §IV-B3
    assert CGRA(3, 3).connectivity_degree == 5
    assert CGRA(20, 20).connectivity_degree == 5


def test_modulo_windows_tighten_and_detect_infeasible():
    d = running_example()
    asap = asap_schedule(d)
    horizon = max(asap)
    # II = RecII is feasible
    assert modulo_windows(d, 4, horizon) is not None
    # II below RecII must be reported infeasible
    assert modulo_windows(d, 3, horizon) is None
    # windows never widen beyond the DAG windows
    a2, l2 = modulo_windows(d, 4, horizon)
    alap = alap_schedule(d, horizon)
    for v in d.nodes:
        assert a2[v] >= asap[v]
        assert l2[v] <= alap[v]

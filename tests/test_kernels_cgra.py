"""cgra_sim Pallas kernel vs both oracles, swept over shapes and op mixes."""

import numpy as np
import pytest

from repro.core import CGRA, map_dfg, running_example
from repro.core.dfg import DFG, Edge
from repro.core.simulate import interpret_dfg
from repro.kernels.ops import compile_program, cgra_run
from repro.kernels.ref import cgra_sim_reference


def _run_and_compare(dfg, cgra, num_iters, batch, batch_tile=None, seed=0):
    res = map_dfg(dfg, cgra, time_budget_s=30)
    assert res.ok, res.reason
    prog = compile_program(res.mapping)
    rng = np.random.default_rng(seed)
    inputs = {
        v: rng.uniform(-4, 4, (num_iters, batch)).astype(np.float32).round(2)
        for v in dfg.nodes
        if dfg.ops[v] == "input"
    }
    outs_k, trace_k = cgra_run(
        prog, inputs, num_iters, batch_tile=batch_tile or batch
    )
    outs_r, trace_r = cgra_sim_reference(prog, inputs, num_iters)
    np.testing.assert_array_equal(trace_k, trace_r)
    # cross-check against the scalar interpreter on lane 0
    ref = interpret_dfg(
        dfg, {v: [float(x) for x in inputs[v][:, 0]] for v in inputs}, num_iters
    )
    for v, stream in ref.items():
        np.testing.assert_allclose(
            outs_k[v][:, 0], np.asarray(stream, np.float32), rtol=1e-6, atol=1e-6
        )
    return prog


@pytest.mark.parametrize("batch,batch_tile", [(8, 8), (32, 16), (128, 128)])
def test_running_example_shapes(batch, batch_tile):
    _run_and_compare(running_example(), CGRA(2, 2), 5, batch, batch_tile)


@pytest.mark.parametrize("grid", [(2, 2), (3, 3), (4, 4)])
def test_grid_sweep(grid):
    _run_and_compare(running_example(), CGRA(*grid), 4, 8)


def test_all_float_ops_covered():
    """DFG touching every opcode, chained like real straight-line code."""
    from repro.core.dfg import OP_ARITY

    mid = ["add", "sub", "mul", "div", "min", "max", "neg", "abs", "mov",
           "cmp", "and", "or", "xor", "shl", "shr", "not"]
    ops = ["input", "input", "const"] + mid + ["store"]
    n = len(ops)
    edges = []
    prev = 2  # const feeds the first op
    for v in range(3, 3 + len(mid)):
        edges.append(Edge(prev, v))
        if OP_ARITY[ops[v]] == 2:
            edges.append(Edge(v % 2, v))  # alternate the two inputs
        prev = v
    edges.append(Edge(prev, n - 1))
    d = DFG(num_nodes=n, edges=edges, ops=ops, name="opcover")
    d.validate()
    _run_and_compare(d, CGRA(3, 3), 3, 8)


def test_recurrence_semantics_through_kernel():
    """phi accumulation across iterations must flow through the ring buffer."""
    d = DFG(
        num_nodes=4,
        edges=[Edge(0, 1), Edge(1, 2), Edge(2, 1, 1), Edge(2, 3)],
        ops=["input", "phi", "mov", "store"],
        name="accum",
    )
    d.validate()
    prog = _run_and_compare(d, CGRA(2, 2), 6, 8)
    # the carried operand's ring delay equals its schedule distance
    m = prog.mapping
    delta = (m.t_abs[1] - m.t_abs[2]) + m.ii  # edge 2 -> 1, distance 1
    assert 1 <= delta <= prog.ring


def test_vmem_budget_accounting():
    res = map_dfg(running_example(), CGRA(2, 2), time_budget_s=30)
    prog = compile_program(res.mapping)
    assert prog.vmem_bytes(batch_tile=128) < 16 * 2**20  # tiny program fits easily

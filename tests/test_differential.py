"""Differential fuzz harness: portfolio vs exact joint backend (DESIGN.md §14.5).

``hypothesis`` is not available in the container, so this is a deterministic
fuzz suite: :func:`repro.core.fuzz.random_dfg` turns a seed into a small
valid DFG, and every case id embeds the seed, fabric, and backend, so any
failure is replayable verbatim. The default budget is ``FUZZ_SEEDS`` seeds ×
6 (fabric, space-backend) configs ≥ 200 mapped cases; the nightly CI job
raises it via ``REPRO_FUZZ_CASES``.

Three oracles cross-check every accepted mapping:

* **Validity** — ``Mapping.validate()`` must be clean and the cycle-accurate
  executor must agree with the sequential interpreter
  (``check_equivalence``), on every fabric topology and space backend.
* **Joint parity** — the joint solver run *at the portfolio's achieved II*
  may never prove that II unsat: portfolio mappings are witnesses, so an
  unsat there is a soundness bug in one of the two independent encodings.
* **Certificate sanity** — certificates produced on portfolio mappings must
  re-verify (:func:`verify_certificate`), respect ``mII ≤ ii_opt ≤ ii``,
  and only claim ``optimal``/``better-found`` with full probe coverage.

Budgets are deliberately tiny (``det_space_cap=4000``) — differential
testing wants many shallow cases, not a few deep ones — and deterministic
mode keeps every mapper decision a pure function of the case tuple.
"""

from __future__ import annotations

import os

import pytest

from repro.core import CGRA, map_dfg, min_ii
from repro.core.exact_backends import (
    certify_mapping,
    solve_joint,
    verify_certificate,
)
from repro.core.fuzz import random_dfg
from repro.core.simulate import check_equivalence

# Seeds per (fabric, backend) config. 6 configs x 34 seeds = 204 mapped
# cases at the floor the harness promises; the nightly job scales it up.
FUZZ_SEEDS = max(34, int(os.environ.get("REPRO_FUZZ_CASES", "0")) // 6)
_CHUNK = 17  # seeds per test node: failures stay replayable, runtime ~2-8 s

_FABRICS = [
    pytest.param("mesh3x3", dict(rows=3, cols=3), id="mesh3x3"),
    pytest.param("torus4x4", dict(rows=4, cols=4, topology="torus"),
                 id="torus4x4"),
    pytest.param("onehop4x4", dict(rows=4, cols=4, topology="one-hop"),
                 id="onehop4x4"),
]
_BACKENDS = ["exact", "anneal"]
_CHUNKS = [
    (lo, min(lo + _CHUNK, FUZZ_SEEDS)) for lo in range(0, FUZZ_SEEDS, _CHUNK)
]

# Tight deterministic budgets: failures to embed under these caps simply
# yield ok=False rows (anneal is incomplete; that is not a violation).
_MAP_KW = dict(
    deterministic=True,
    use_cache=False,
    det_space_cap=4000,
    max_retries_per_window=1,
    max_slack=1,
)


def _compile(seed: int, fabric_kw: dict, backend: str):
    dfg = random_dfg(seed)
    cgra = CGRA(**fabric_kw)
    res = map_dfg(dfg, cgra, space_backend=backend, **_MAP_KW)
    return dfg, cgra, res


@pytest.mark.fuzz
@pytest.mark.parametrize("fabric_id,fabric_kw", _FABRICS)
@pytest.mark.parametrize("backend", _BACKENDS)
@pytest.mark.parametrize("lo,hi", _CHUNKS, ids=[f"seeds{lo}-{hi - 1}" for lo, hi in _CHUNKS])
def test_fuzz_valid_and_equivalent(fabric_id, fabric_kw, backend, lo, hi):
    """Every accepted mapping validates and executes correctly."""
    mapped = 0
    for seed in range(lo, hi):
        dfg, cgra, res = _compile(seed, fabric_kw, backend)
        if not res.ok:
            continue
        mapped += 1
        case = f"seed={seed} fabric={fabric_id} backend={backend}"
        problems = res.mapping.validate()
        assert problems == [], f"{case}: {problems}"
        check_equivalence(res.mapping)
        assert res.mapping.ii >= min_ii(dfg, cgra), (
            f"{case}: ii {res.mapping.ii} below the structural bound"
        )
    # tiny DFGs on 9-16 PE fabrics embed under these budgets in practice;
    # a collapse to zero would mean the harness stopped testing anything
    assert mapped > (hi - lo) // 2, f"only {mapped}/{hi - lo} cases mapped"


@pytest.mark.fuzz
@pytest.mark.parametrize("fabric_id,fabric_kw", _FABRICS)
@pytest.mark.parametrize("lo,hi", _CHUNKS, ids=[f"seeds{lo}-{hi - 1}" for lo, hi in _CHUNKS])
def test_fuzz_joint_never_refutes_a_witness(fabric_id, fabric_kw, lo, hi):
    """The joint encoding may never call a portfolio mapping's II unsat.

    The portfolio mapping *is* a satisfying assignment of the joint model,
    so ``unsat`` at that II contradicts it — whichever side is wrong, it is
    a real bug. ``unknown`` (budget) is acceptable and merely skipped.
    """
    for seed in range(lo, hi):
        dfg, cgra, res = _compile(seed, fabric_kw, "exact")
        if not res.ok or res.mapping.num_route_movs:
            continue
        out = solve_joint(dfg, cgra, res.mapping.ii, node_budget=200_000)
        assert out.status != "unsat", (
            f"seed={seed} fabric={fabric_id}: joint refuted II="
            f"{res.mapping.ii} but the portfolio holds a witness"
        )
        if out.status == "sat" and out.mapping is not None:
            assert out.mapping.validate() == [], f"seed={seed} joint witness invalid"
            check_equivalence(out.mapping)


@pytest.mark.fuzz
@pytest.mark.parametrize("lo,hi", _CHUNKS, ids=[f"seeds{lo}-{hi - 1}" for lo, hi in _CHUNKS])
def test_fuzz_certificates_verify(lo, hi):
    """Certificates on fuzz mappings re-verify and bound correctly."""
    for seed in range(lo, hi):
        dfg, cgra, res = _compile(seed, dict(rows=3, cols=3), "exact")
        if not res.ok:
            continue
        cert, better = certify_mapping(
            dfg, cgra, res.mapping, budget_s=3.0, deterministic=True
        )
        case = f"seed={seed} status={cert.status}"
        problems = verify_certificate(cert, dfg, cgra)
        assert problems == [], f"{case}: {problems}"
        assert cert.m_ii >= min_ii(dfg, cgra)
        if cert.ii_opt is not None:
            assert cert.m_ii <= cert.ii_opt <= res.mapping.ii, case
            final_ii = better.ii if better is not None else res.mapping.ii
            assert final_ii == cert.ii_opt, (
                f"{case}: final ii {final_ii} != certified optimum {cert.ii_opt}"
            )
        else:
            assert cert.status == "timeout", case
        if better is not None:
            assert better.validate() == []
            check_equivalence(better)

"""Heterogeneous-architecture subsystem tests (core/arch, DESIGN.md §10).

Covers the declarative ArchSpec layer (round-trip, validation, hashing,
presets), the capability threading through every pipeline stage (time
backends, space engine, simulator oracle, caches), the register-pressure
probe surfaced by Mapping.validate, the topology-gated triangle exclusion,
and frontend→map→execute round-trips on heterogeneous presets — including
the acceptance sweep: the full 17-kernel suite on the edge-memory 4×4
preset, every mapping independently verified by execution.
"""

import json

import pytest

from repro.core import CGRA, map_dfg, running_example
from repro.core.arch import ArchSpec, get_preset, list_presets, resolve_arch
from repro.core.cgra import op_class
from repro.core.benchsuite import load_suite
from repro.core.dfg import DFG, Edge
from repro.core.frontend import trace_loop
from repro.core.mapper import Mapping, _cache_base_key, clear_mapping_cache
from repro.core.mono import check_monomorphism
from repro.core.schedule import min_ii, res_ii
from repro.core.simulate import check_equivalence, execute_mapping
from repro.core.time_smt import TimeSolver, check_time_solution


# ------------------------------------------------------------------ ArchSpec

def _left_col_mem_2x2() -> ArchSpec:
    return ArchSpec(
        name="tiny", rows=2, cols=2,
        pe_classes=(("alu", "mem", "mul"), ("alu",),
                    ("alu", "mem", "mul"), ("alu",)),
        mem_ports=1,
    )


def test_spec_json_roundtrip_and_hash():
    spec = _left_col_mem_2x2()
    again = ArchSpec.from_json(spec.to_json())
    assert again == spec
    assert again.spec_hash() == spec.spec_hash()
    # the hash ignores the name (renaming must not orphan caches) ...
    assert spec.renamed("other").spec_hash() == spec.spec_hash()
    # ... but tracks every mapping-relevant field
    import dataclasses
    assert dataclasses.replace(spec, mem_ports=2).spec_hash() != spec.spec_hash()


def test_spec_file_roundtrip(tmp_path):
    spec = get_preset("satmapit_edge_mem_4x4")
    path = str(tmp_path / "arch.json")
    spec.save(path)
    assert ArchSpec.load(path) == spec
    assert resolve_arch(path) == spec


def test_spec_validation_rejects_garbage():
    with pytest.raises(ValueError):
        ArchSpec(name="x", rows=0, cols=4).validate()
    with pytest.raises(ValueError):
        ArchSpec(name="x", rows=2, cols=2, topology="hypercube").validate()
    with pytest.raises(ValueError):
        ArchSpec(name="x", rows=2, cols=2,
                 pe_classes=(("alu",),) * 3).validate()       # wrong length
    with pytest.raises(ValueError):
        ArchSpec(name="x", rows=1, cols=1,
                 pe_classes=(("warp",),)).validate()          # unknown class
    with pytest.raises(ValueError):
        ArchSpec(name="x", rows=1, cols=2,
                 pe_classes=(("alu",), ())).validate()        # capability-free PE


def test_presets_build_and_validate():
    for name in list_presets():
        spec = get_preset(name)
        cgra = spec.cgra()
        assert cgra.rows == spec.rows and cgra.cols == spec.cols
    with pytest.raises(ValueError):
        get_preset("nope")
    with pytest.raises(ValueError):
        resolve_arch("definitely-not-a-preset-or-file")
    # the homogeneous preset is exactly the paper machine
    assert get_preset("paper_homogeneous_4x4").cgra() == CGRA(4, 4)


def test_validate_for_reports_missing_classes():
    spec = ArchSpec(name="nomul", rows=2, cols=2,
                    pe_classes=(("alu", "mem"),) * 4)
    d = DFG(num_nodes=3, ops=["input", "input", "mul"],
            edges=[Edge(0, 2), Edge(1, 2)])
    assert any("mul" in p for p in spec.validate_for(d))
    assert spec.validate_for(running_example()) != []   # has mul nodes too
    homog = get_preset("paper_homogeneous_4x4")
    assert homog.validate_for(running_example()) == []


# ---------------------------------------------------------- CGRA capability

def test_capability_masks_and_class_capacity():
    cgra = _left_col_mem_2x2().cgra()
    masks = cgra.capability_masks
    assert masks["alu"] == 0b1111
    assert masks["mem"] == 0b0101          # PEs 0 and 2 (left column)
    assert cgra.capable(0, "mem") and not cgra.capable(1, "mem")
    assert cgra.class_capacity("mem") == 1  # two mem PEs, one port
    assert cgra.class_capacity("alu") == 4
    homog = CGRA(2, 2)
    assert not homog.heterogeneous
    assert homog.arch_token() is None
    assert cgra.arch_token() is not None
    full = (1 << 4) - 1
    assert all(m == full for m in homog.capability_masks.values())


def test_op_class_partition():
    assert op_class("load") == op_class("store") == "mem"
    assert op_class("mul") == op_class("div") == "mul"
    assert op_class("add") == op_class("phi") == op_class("input") == "alu"


def test_new_topologies_neighbors():
    king = CGRA(3, 3, topology="diagonal")
    # centre PE sees all 8 others
    assert len(king.neighbors[4]) == 8
    assert king.connectivity_degree == 9
    onehop = CGRA(4, 4, topology="one-hop")
    # corner: 2 mesh + 2 two-hop links
    assert len(onehop.neighbors[0]) == 4
    with pytest.raises(ValueError):
        CGRA(2, 2, topology="twisted")


def test_res_ii_accounts_for_class_capacity():
    # 4 stores on a grid with a single memory port: ResII >= 4
    ops = ["input"] + ["store"] * 4
    edges = [Edge(0, v) for v in range(1, 5)]
    d = DFG(num_nodes=5, ops=ops, edges=edges)
    cgra = _left_col_mem_2x2().cgra()
    assert res_ii(d, cgra) >= 4
    assert res_ii(d, CGRA(2, 2)) == 2      # homogeneous bound unchanged


# ----------------------------------------------------- time phase, class caps

def test_time_solver_respects_class_capacity():
    ops = ["input"] + ["store"] * 4
    edges = [Edge(0, v) for v in range(1, 5)]
    d = DFG(num_nodes=5, ops=ops, edges=edges)
    cgra = _left_col_mem_2x2().cgra()
    ii = min_ii(d, cgra)
    solver = TimeSolver(d, cgra, ii, extra_slack=3, backend="cp")
    sol = solver.next_solution()
    assert sol is not None
    # at most one mem op per kernel step (1 port)
    for step in range(ii):
        n_mem = sum(
            1 for v in d.nodes
            if sol.labels[v] == step and op_class(d.ops[v]) == "mem"
        )
        assert n_mem <= 1
    assert check_time_solution(d, cgra, sol) == []


def test_check_time_solution_flags_class_overflow():
    ops = ["input", "store", "store"]
    d = DFG(num_nodes=3, ops=ops, edges=[Edge(0, 1), Edge(0, 2)])
    cgra = _left_col_mem_2x2().cgra()
    from repro.core.time_smt import TimeSolution

    bad = TimeSolution(2, [0, 1, 1])       # both stores on step 1, 1 port
    assert any("class capacity" in e for e in check_time_solution(d, cgra, bad))


def test_window_precheck_prunes_impossible_class_load():
    # 5 stores, capacity 1/step: II=2 can never fit them
    ops = ["input"] + ["store"] * 5
    edges = [Edge(0, v) for v in range(1, 6)]
    d = DFG(num_nodes=6, ops=ops, edges=edges)
    cgra = _left_col_mem_2x2().cgra()
    with pytest.raises(ValueError):
        TimeSolver(d, cgra, 2, extra_slack=4, backend="cp")


# ------------------------------------------------------- space + simulation

def test_monomorphism_checker_flags_capability_violation():
    spec = _left_col_mem_2x2()
    cgra = spec.cgra()
    d = DFG(num_nodes=2, ops=["input", "store"], edges=[Edge(0, 1)])
    # store on PE 1 (no mem class) must be flagged
    errs = check_monomorphism(d, cgra, [0, 1], [1, 1], 2)
    assert any("capability" in e for e in errs)
    assert check_monomorphism(d, cgra, [0, 1], [1, 0], 2) == []


def test_execute_mapping_asserts_capability_and_ports():
    spec = _left_col_mem_2x2()
    cgra = spec.cgra()
    d = DFG(num_nodes=2, ops=["input", "store"], edges=[Edge(0, 1)])
    good = Mapping(dfg=d, cgra=cgra, ii=2, t_abs=[0, 1], placement=[1, 0])
    check_equivalence(good)
    bad = Mapping(dfg=d, cgra=cgra, ii=2, t_abs=[0, 1], placement=[0, 1])
    with pytest.raises(AssertionError, match="capability violation"):
        execute_mapping(bad, {0: [1.0] * 4}, 4)
    # two stores in the same cycle on a 1-port grid: port violation, even
    # though both PEs individually carry the mem class
    d2 = DFG(num_nodes=3, ops=["input", "store", "store"],
             edges=[Edge(0, 1), Edge(0, 2)])
    ports = Mapping(dfg=d2, cgra=cgra, ii=2, t_abs=[0, 1, 1],
                    placement=[1, 0, 2])
    with pytest.raises(AssertionError, match="memory-port violation"):
        execute_mapping(ports, {0: [1.0] * 4}, 4)


def test_mapper_fails_fast_on_unsupported_class():
    spec = ArchSpec(name="nomul", rows=2, cols=2,
                    pe_classes=(("alu", "mem"),) * 4)
    d = DFG(num_nodes=3, ops=["input", "input", "mul"],
            edges=[Edge(0, 2), Edge(1, 2)])
    res = map_dfg(d, spec.cgra())
    assert not res.ok
    assert "capability" in res.reason and "mul" in res.reason
    # fail-fast, not budget exhaustion: no time solutions were ever tried
    assert res.stats.time_solutions_tried == 0
    assert res.stats.rounds == 0


# -------------------------------------------------- satellite: register file

def test_validate_surfaces_register_pressure():
    res = map_dfg(running_example(), CGRA(2, 2), deterministic=True)
    assert res.ok
    m = res.mapping
    assert m.validate() == []              # default grid: 8 registers suffice
    from repro.core.simulate import check_register_pressure

    pressure = check_register_pressure(m)
    assert pressure >= 1
    starved = Mapping(
        dfg=m.dfg,
        cgra=CGRA(2, 2, registers_per_pe=pressure - 1),
        ii=m.ii, t_abs=m.t_abs, placement=m.placement,
    )
    errs = starved.validate()
    assert any("register pressure" in e for e in errs)
    # the probe is skippable for raw space/time validity checks
    assert starved.validate(registers=False) == []


def test_registers_by_class_roundtrip_and_hash():
    spec = ArchSpec(
        name="memfat", rows=2, cols=2,
        pe_classes=(("alu", "mem", "mul"), ("alu",),
                    ("alu", "mem", "mul"), ("alu",)),
        registers_by_class={"mem": 16},
    )
    spec.validate()
    again = ArchSpec.from_json(spec.to_json())
    assert again == spec
    assert again.spec_hash() == spec.spec_hash()
    import dataclasses
    resized = dataclasses.replace(spec, registers_by_class={"mem": 12})
    assert resized.spec_hash() != spec.spec_hash()
    # the dict form normalises to the canonical tuple form
    assert spec.registers_by_class == (("mem", 16),)
    with pytest.raises(ValueError, match="unknown capability class"):
        ArchSpec(name="x", rows=1, cols=1,
                 registers_by_class={"warp": 4}).validate()
    with pytest.raises(ValueError, match=">= 1"):
        ArchSpec(name="x", rows=1, cols=1,
                 registers_by_class={"mem": 0}).validate()


def test_registers_at_per_pe():
    spec = ArchSpec(
        name="memfat", rows=2, cols=2,
        pe_classes=(("alu", "mem", "mul"), ("alu",),
                    ("alu", "mem", "mul"), ("alu",)),
        registers_per_pe=4,
        registers_by_class={"mem": 16},
    )
    cgra = spec.cgra()
    # mem-capable PEs (left column) get the class override, pure-ALU PEs
    # keep the scalar default
    assert cgra.registers_at(0) == 16 and cgra.registers_at(2) == 16
    assert cgra.registers_at(1) == 4 and cgra.registers_at(3) == 4
    # homogeneous grid: every PE carries every class, so the largest wins
    assert CGRA(2, 2, registers_by_class={"mem": 16}).registers_at(3) == 16
    # the scalar form is untouched without overrides (the paper machine)
    assert all(CGRA(2, 2).registers_at(p) == 8 for p in range(4))
    # the shipped SAT-MapIt preset sizes memory-PE buffers at 16
    sm = get_preset("satmapit_edge_mem_4x4").cgra()
    assert sm.registers_at(0) == 16          # border PE: mem-capable
    assert sm.registers_at(sm.pe_index(1, 1)) == 8   # interior: compute-only


def test_validate_respects_per_class_register_files():
    """Mapping.validate compares each PE's pressure against that PE's own
    bound: a class-level register override can clear a violation the scalar
    bound would report (and vice versa)."""
    from repro.core.simulate import check_register_pressure

    res = map_dfg(running_example(), CGRA(2, 2), deterministic=True)
    assert res.ok
    m = res.mapping
    pressure = check_register_pressure(m)
    starved = Mapping(
        dfg=m.dfg, cgra=CGRA(2, 2, registers_per_pe=pressure - 1),
        ii=m.ii, t_abs=m.t_abs, placement=m.placement,
    )
    assert any("register pressure" in e for e in starved.validate())
    # same starved scalar, but an alu-class override restores the headroom
    # (homogeneous PEs carry the alu class, and the per-PE bound is the max)
    relieved = Mapping(
        dfg=m.dfg,
        cgra=CGRA(2, 2, registers_per_pe=pressure - 1,
                  registers_by_class={"alu": pressure}),
        ii=m.ii, t_abs=m.t_abs, placement=m.placement,
    )
    assert relieved.validate() == []


# -------------------------------------- satellite: topology-gated triangles

def _triangle_dfg() -> DFG:
    return DFG(num_nodes=3, ops=["input", "mov", "add"],
               edges=[Edge(0, 1), Edge(0, 2), Edge(1, 2)])


def test_triangle_freeness_by_topology():
    assert CGRA(4, 4).triangle_free                       # mesh: bipartite
    assert CGRA(4, 4, topology="torus").triangle_free
    assert not CGRA(3, 3, topology="torus").triangle_free  # 3-ring wrap
    assert not CGRA(3, 3, topology="diagonal").triangle_free
    assert not CGRA(4, 4, topology="one-hop").triangle_free


def test_diagonal_grid_accepts_monochromatic_triangle():
    """Regression (DESIGN.md §7/§10): king-move grids are not bipartite, so
    the strict-mode triangle exclusion must be gated on topology — on a
    diagonal 2×2 every PE pair is adjacent and a DFG triangle maps at II=1."""
    d = _triangle_dfg()
    king = CGRA(2, 2, topology="diagonal")
    solver = TimeSolver(d, king, 1, extra_slack=2, backend="cp")
    sol = solver.next_solution()
    assert sol is not None, "triangle cut must not fire on a non-bipartite grid"
    assert sol.labels == [0, 0, 0]
    res = map_dfg(d, king, deterministic=True)
    assert res.ok and res.mapping.ii == 1
    assert res.mapping.validate() == []
    # the same mono-chromatic partition stays excluded on the paper's mesh
    mesh_solver = TimeSolver(d, CGRA(2, 2), 1, extra_slack=2, backend="cp")
    assert mesh_solver.next_solution() is None


# ------------------------------- satellite: frontend round-trips on presets

def _mac_body(ins, carried):
    acc = carried["acc"] + ins[0] * ins[1]
    return [acc], {"acc": acc}


def test_trace_map_execute_on_edge_mem_preset():
    spec = get_preset("satmapit_edge_mem_4x4")
    cgra = spec.cgra()
    dfg = trace_loop(_mac_body, num_inputs=2, carried=["acc"], name="mac")
    assert spec.validate_for(dfg) == []
    res = map_dfg(dfg, cgra, deterministic=True)
    assert res.ok, res.reason
    for v in dfg.nodes:
        if op_class(dfg.ops[v]) == "mem":
            assert cgra.capable(res.mapping.placement[v], "mem")
    check_equivalence(res.mapping)          # oracle re-checks capabilities


def test_trace_map_execute_on_mul_sparse_preset():
    spec = get_preset("mul_sparse_8x8")
    cgra = spec.cgra()

    def body(ins, carried):
        prod = ins[0] * ins[1] * ins[2]     # two muls: diagonal PEs only
        acc = carried["acc"] + prod
        return [acc], {"acc": acc}

    dfg = trace_loop(body, num_inputs=3, carried=["acc"], name="prods")
    res = map_dfg(dfg, cgra, deterministic=True)
    assert res.ok, res.reason
    mul_pes = [res.mapping.placement[v] for v in dfg.nodes
               if op_class(dfg.ops[v]) == "mul"]
    assert mul_pes, "trace must contain mul nodes"
    for pe in mul_pes:
        r, c = cgra.pe_coords(pe)
        assert r == c, "mul ops must sit on the diagonal PEs"
    check_equivalence(res.mapping)


def test_infeasible_by_capability_fails_fast():
    spec = ArchSpec(name="alu_only", rows=4, cols=4,
                    pe_classes=(("alu",),) * 16)
    dfg = trace_loop(_mac_body, num_inputs=2, carried=["acc"], name="mac")
    import time

    t0 = time.perf_counter()
    res = map_dfg(dfg, spec.cgra())
    assert not res.ok
    assert "capability" in res.reason
    assert time.perf_counter() - t0 < 1.0, "must not exhaust the window sweep"


# --------------------------------------------------------------- cache keys

def test_cache_key_separates_architectures():
    dfg = trace_loop(_mac_body, num_inputs=2, carried=["acc"], name="mac")
    homog = CGRA(4, 4)
    hetero = get_preset("satmapit_edge_mem_4x4").cgra()
    k1 = _cache_base_key(dfg, homog, "strict", None)
    k2 = _cache_base_key(dfg, hetero, "strict", None)
    assert k1 != k2
    # two spec instances of the same preset agree
    k3 = _cache_base_key(dfg, get_preset("satmapit_edge_mem_4x4").cgra(),
                         "strict", None)
    assert k2 == k3


def test_memory_cache_never_aliases_hetero_and_homog():
    clear_mapping_cache()
    dfg = trace_loop(_mac_body, num_inputs=2, carried=["acc"], name="mac")
    hetero = get_preset("satmapit_edge_mem_4x4").cgra()
    first = map_dfg(dfg, CGRA(4, 4))
    assert first.ok
    second = map_dfg(dfg, hetero)
    assert second.ok
    assert not second.stats.cache_hit      # homogeneous entry must not serve
    for v in dfg.nodes:
        if op_class(dfg.ops[v]) == "mem":
            assert hetero.capable(second.mapping.placement[v], "mem")


# ------------------------------------------------------- acceptance: suite

def test_full_suite_maps_and_verifies_on_edge_mem_4x4():
    """The PR's acceptance sweep: all 17 Table III kernels on the SAT-MapIt
    style edge-memory 4×4 preset, every mapping verified by cycle-accurate
    execution (capability + port assertions live in the oracle)."""
    spec = get_preset("satmapit_edge_mem_4x4")
    cgra = spec.cgra()
    for name, dfg in load_suite().items():
        assert spec.validate_for(dfg) == []
        res = map_dfg(dfg, cgra, time_budget_s=30, use_cache=False)
        assert res.ok, f"{name}: {res.reason}"
        for v in dfg.nodes:
            cls = op_class(dfg.ops[v])
            assert cgra.capable(res.mapping.placement[v], cls), (
                f"{name}: node {v} ({dfg.ops[v]}) on incapable PE"
            )
        check_equivalence(res.mapping)

"""Structured tracing + metrics tests (DESIGN.md §15).

Covers the whole observability contract: the disabled-mode no-op fast path
(zero new objects, bounded overhead), deterministic span trees under the
``deterministic-ci`` profile, cross-process shard merging from a 2-worker
``compile_many``, Chrome/Perfetto trace-event schema validation via
``tools/trace_report.py``, the ``exact_s`` phase-accounting fix, the
two-layer cache counters, and the metrics-block parity between the
in-process, batch, and pooled paths.
"""

import importlib.util
import json
import os

import pytest

from repro import obs
from repro.api import Compiler, resolve_options
from repro.core import CGRA, running_example
from repro.core.benchsuite import load_suite
from repro.core.mapper import clear_mapping_cache, memory_cache_stats
from repro.core.service import CompileJob, compile_many

_TOOL = os.path.join(os.path.dirname(__file__), os.pardir, "tools",
                     "trace_report.py")


def _trace_report():
    spec = importlib.util.spec_from_file_location("trace_report", _TOOL)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _ci_compiler(**overrides):
    return Compiler(CGRA(4, 4), resolve_options("deterministic-ci"),
                    **overrides)


def _traced_compile(dfg, **overrides):
    comp = _ci_compiler(**overrides)
    tracer = obs.Tracer()
    with obs.tracing(tracer):
        result = comp.compile(dfg)
    return result, tracer


# ------------------------------------------------- disabled-mode contract

def test_disabled_span_is_shared_noop_singleton():
    """With no tracer installed, span() returns ONE shared no-op object —
    the zero-allocation contract that lets call sites live in hot loops."""
    assert not obs.enabled()
    s1 = obs.span("time.probe", ii=4)
    s2 = obs.span("space.probe", ii=9)
    assert s1 is s2 is obs._NULL_SPAN
    with s1 as s:
        s.set(found=True)           # no-op, returns self
    obs.event("cache.memory.hit")   # no-op
    obs.incr("anything")            # no-op
    assert obs.get_tracer() is None


def test_disabled_overhead_is_negligible():
    """50k disabled spans must cost well under half a second (they are a
    None check + a shared singleton; generous bound to stay CI-proof)."""
    import time

    t0 = time.perf_counter()
    for i in range(50_000):
        with obs.span("time.probe", ii=i):
            pass
    assert time.perf_counter() - t0 < 0.5


def test_untraced_compile_unaffected_by_instrumentation():
    """A traced and an untraced deterministic compile take the identical
    search path — instrumentation must never consume rng or change
    budgets."""
    dfg = running_example()
    plain = _ci_compiler().compile(dfg)
    traced, _ = _traced_compile(dfg)
    assert plain.ok and traced.ok
    assert plain.ii == traced.ii
    assert plain.mapping.t_abs == traced.mapping.t_abs
    assert plain.mapping.placement == traced.mapping.placement
    assert plain.metrics["solver"] == traced.metrics["solver"]


# ------------------------------------------------------ span-tree capture

def test_span_tree_deterministic():
    """Two deterministic-ci compiles of the same kernel record the same
    span/event name sequence with the same (ii, slack) attributes."""
    dfg = load_suite(names=["bitcount"])["bitcount"]

    def signature():
        _, tracer = _traced_compile(dfg)
        return [(e["name"], e["args"].get("ii"), e["args"].get("slack"))
                for e in tracer.events]

    sig1, sig2 = signature(), signature()
    assert sig1 == sig2
    names = [n for n, _, _ in sig1]
    for expected in ("compile", "time.probe", "space.probe",
                     "mapper.window.open", "mapper.round"):
        assert expected in names, expected


def test_span_covers_phase_total():
    """The root compile span must cover the phase-timing total (it wraps
    the whole mapper call), and not exceed it wildly."""
    dfg = load_suite(names=["fft"])["fft"]
    result, tracer = _traced_compile(dfg)
    assert result.ok
    span_s = tracer.span_totals()["compile"]
    total_s = result.phases.total_s
    assert span_s >= total_s * 0.9
    # the wrapper adds result construction only — sanity-bound the slack
    assert span_s <= total_s * 1.5 + 0.05


def test_trace_json_is_perfetto_schema_valid(tmp_path):
    """The written Chrome trace-event JSON passes trace_report --check."""
    dfg = running_example()
    out = tmp_path / "trace.json"
    comp = _ci_compiler()
    with obs.session(str(out)):
        res = comp.compile(dfg)
    assert res.ok and out.exists()
    doc = json.loads(out.read_text())
    assert doc["displayTimeUnit"] == "ms"
    tr = _trace_report()
    assert tr.check(doc) == []
    # the summary renders without error and mentions the span table
    text = "\n".join(tr.summarize(doc))
    assert "time.probe" in text or "compile" in text


def test_trace_report_check_catches_malformed():
    tr = _trace_report()
    assert tr.check({}) != []                      # no traceEvents
    assert tr.check({"traceEvents": []}) != []     # empty
    bad = {"traceEvents": [{"ph": "X", "name": "a", "ts": 0, "dur": -1,
                            "pid": 1, "tid": 1, "args": {}}]}
    assert any("dur" in v for v in tr.check(bad))


# ------------------------------------------------- cross-process shards

def test_two_worker_shard_merge(tmp_path):
    """compile_many with 2 pool workers writes per-pid span shards that
    merge onto one timeline with worker-pid attribution."""
    suite = load_suite(names=["bitcount", "fft"])
    cgra = CGRA(4, 4)
    batch = [CompileJob(d, cgra) for d in suite.values()]
    report = compile_many(batch, jobs=2, deterministic=True,
                          use_cache=False, trace_dir=str(tmp_path))
    assert report.ok and report.num_workers == 2
    events, counters = obs.merge_shards(str(tmp_path))
    assert events, "workers wrote no span shards"
    pids = {e["pid"] for e in events}
    assert os.getpid() not in pids          # all spans came from workers
    job_spans = [e for e in events if e["name"] == "job"]
    assert {e["args"]["kernel"] for e in job_spans} == set(suite)
    assert all(e["ph"] == "X" and e["dur"] >= 0 for e in job_spans)


def test_batch_compile_adopts_worker_shards(tmp_path):
    """Compiler.compile_batch merges worker shards into the active tracer
    so one trace file holds the whole cross-process timeline."""
    suite = load_suite(names=["bitcount", "fft"])
    comp = _ci_compiler(jobs=2)
    out = tmp_path / "batch.json"
    with obs.session(str(out)):
        batch = comp.compile_batch(list(suite.values()))
    assert batch.ok
    doc = json.loads(out.read_text())
    pids = {e["pid"] for e in doc["traceEvents"] if e["ph"] != "M"}
    assert len(pids) >= 2, "expected spans from at least two processes"
    assert _trace_report().check(doc) == []


def test_merge_shards_tolerates_torn_shard(tmp_path):
    good = [{"name": "job", "ph": "X", "ts": 1.0, "dur": 2.0,
             "pid": 1, "tid": 1, "args": {}}]
    obs.append_shard(str(tmp_path), good, {"jobs": 1})
    (tmp_path / "shard-9999.jsonl").write_text('{"name": "torn', "utf-8")
    events, counters = obs.merge_shards(str(tmp_path))
    assert events == good
    assert counters == {"jobs": 1}


# ------------------------------------------------------- metrics + phases

def test_exact_s_phase_accounted():
    """Satellite 1: certification wall time lands in phases.exact_s and is
    included in total_s (and the metrics block mirrors the final phases)."""
    dfg = running_example()
    comp = _ci_compiler(exact_check=True, exact_budget_s=10.0)
    res = comp.compile(dfg)
    assert res.ok and res.certificate is not None
    assert res.phases.exact_s > 0.0
    row = res.as_dict()
    assert row["phases"]["exact_s"] == pytest.approx(res.phases.exact_s,
                                                     abs=1e-6)
    assert res.phases.total_s >= res.phases.exact_s
    non_exact = (res.phases.time_s + res.phases.space_s
                 + res.phases.validate_s)
    assert res.phases.total_s >= non_exact + res.phases.exact_s - 1e-6
    assert res.metrics["phases"] == row["phases"]


def test_metrics_block_parity_across_paths():
    """The metrics block has the same schema — and, deterministically, the
    same solver counters — from compile(), compile_batch jobs=1, and
    compile_batch jobs=2 (pooled)."""
    dfg = load_suite(names=["bitcount"])["bitcount"]
    single = _ci_compiler().compile(dfg)
    inline = _ci_compiler(jobs=1).compile_batch([dfg]).results[0]
    pooled = _ci_compiler(jobs=2).compile_batch([dfg, dfg],
                                                names=["a", "b"]).results[0]

    def schema(d, prefix=""):
        keys = []
        for k in sorted(d):
            keys.append(prefix + k)
            if isinstance(d[k], dict):
                keys.extend(schema(d[k], prefix + k + "."))
        return keys

    assert schema(single.metrics) == schema(inline.metrics)
    assert schema(single.metrics) == schema(pooled.metrics)
    assert single.metrics["solver"] == inline.metrics["solver"]
    assert single.metrics["solver"] == pooled.metrics["solver"]
    # the serialized row carries the same block (CLI report path)
    assert single.as_dict()["metrics"]["solver"] == single.metrics["solver"]


def test_memory_cache_counters_and_hit_rate():
    """Satellite 2: the in-memory LRU layer counts hits/misses like the
    disk layer, and the per-compile metrics expose the hit rate."""
    clear_mapping_cache()
    base = memory_cache_stats()
    assert (base.hits, base.misses) == (0, 0)
    dfg = running_example()
    comp = Compiler(CGRA(4, 4), resolve_options(), use_cache=True,
                    cache_dir=None, time_budget_s=60.0)
    cold = comp.compile(dfg)
    warm = comp.compile(dfg)
    assert cold.ok and warm.ok and warm.source == "memory"
    st = memory_cache_stats()
    assert st.hits >= 1 and st.writes >= 1
    assert st.hit_rate is not None and 0.0 < st.hit_rate <= 1.0
    assert st.as_dict()["hits"] == st.hits
    mem = warm.metrics["cache"]["memory"]
    assert mem == {"lookups": 1, "hits": 1, "hit_rate": 1.0}
    assert cold.metrics["cache"]["memory"]["hits"] == 0
    clear_mapping_cache()
    fresh = memory_cache_stats()
    assert (fresh.hits, fresh.misses, fresh.writes) == (0, 0, 0)


def test_batch_metrics_aggregates_rows():
    suite = load_suite(names=["bitcount", "fft"])
    comp = _ci_compiler(jobs=1)
    batch = comp.compile_batch(list(suite.values()))
    assert batch.ok
    agg = batch.metrics
    per_row = [r.metrics["solver"] for r in batch.results]
    for key in ("rounds", "windows_opened", "time_steps",
                "space_nodes_visited"):
        assert agg["solver"][key] == sum(m[key] for m in per_row)
    assert batch.as_dict()["metrics"] == agg


# ----------------------------------------------------- solver telemetry

def test_time_probe_spans_carry_steps():
    dfg = load_suite(names=["fft"])["fft"]
    result, tracer = _traced_compile(dfg)
    probes = [e for e in tracer.events if e["name"] == "time.probe"]
    assert probes
    assert all("backend" in e["args"] and "found" in e["args"]
               for e in probes)
    steps = sum(e["args"].get("steps", 0) for e in probes)
    assert steps == result.metrics["solver"]["time_steps"] > 0


def test_anneal_emits_energy_curve_events():
    """Satellite 6: the annealing backend samples its energy curve and
    per-restart accept rates as instant events."""
    dfg = running_example()
    comp = _ci_compiler(space_backend="anneal")
    tracer = obs.Tracer()
    with obs.tracing(tracer):
        res = comp.compile(dfg)
    assert res.ok
    restarts = [e for e in tracer.events
                if e["name"] == "space.anneal.restart"]
    assert restarts
    for e in restarts:
        assert {"energy", "accepts", "proposals",
                "accept_rate"} <= set(e["args"])
        ar = e["args"]["accept_rate"]
        assert ar is None or 0.0 <= ar <= 1.0


def test_session_env_gate(monkeypatch, tmp_path):
    """REPRO_TRACE enables a session with no explicit flag; unset leaves
    the fast path alone."""
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    with obs.session() as t:
        assert t is None and not obs.enabled()
    monkeypatch.setenv("REPRO_TRACE", "1")
    assert obs.env_enabled()
    with obs.session() as t:
        assert t is not None and obs.enabled()
    assert not obs.enabled()

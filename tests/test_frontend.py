"""Tracing frontend + expert-group placement tests."""

import numpy as np
import pytest

from repro.core import CGRA, map_dfg
from repro.core.frontend import trace_loop
from repro.core.placement import expert_groups_graph, place_stages
from repro.core.simulate import check_equivalence, interpret_dfg


def test_trace_mac_loop_maps_and_executes():
    def body(ins, carried):
        acc = carried["acc"] + ins[0] * ins[1]
        return [acc], {"acc": acc}

    dfg = trace_loop(body, num_inputs=2, carried=["acc"], name="mac")
    assert dfg.ops.count("store") == 1
    assert dfg.carried_edges()
    res = map_dfg(dfg, CGRA(2, 2), time_budget_s=20)
    assert res.ok
    check_equivalence(res.mapping, num_iters=6)


def test_trace_semantics_mac():
    """The traced MAC must actually accumulate across iterations."""
    def body(ins, carried):
        acc = carried["acc"] + ins[0] * ins[1]
        return [acc], {"acc": acc}

    dfg = trace_loop(body, num_inputs=2, carried=["acc"])
    a = [1.0, 2.0, 3.0]
    b = [10.0, 10.0, 10.0]
    inputs = {v: (a if i == 0 else b) for i, v in enumerate(
        [n for n in dfg.nodes if dfg.ops[n] == "input"])}
    outs = interpret_dfg(dfg, inputs, 3)
    stream = next(iter(outs.values()))
    assert stream == [10.0, 30.0, 60.0]   # running sum of a*b


def test_trace_mixed_ops_and_constants():
    def body(ins, carried):
        x = (ins[0] + 2.0) * ins[1] - 1.0
        y = abs(-x).min(100.0)
        return [y], {}

    dfg = trace_loop(body, num_inputs=2)
    res = map_dfg(dfg, CGRA(3, 3), time_budget_s=20)
    assert res.ok
    check_equivalence(res.mapping, num_iters=4)


def test_trace_rejects_bad_carried():
    with pytest.raises(ValueError):
        trace_loop(lambda ins, c: ([ins[0]], {"other": ins[0]}),
                   num_inputs=1, carried=["acc"])


def test_expert_group_placement_single_hop():
    g = expert_groups_graph(16, heavy_routes=[(0, 5), (2, 9), (7, 12)])
    placement = place_stages(g, (4, 4))
    assert placement is not None
    assert placement.single_hop_fraction() == 1.0
    assert len(set(placement.stage_to_device)) == 16

"""Pluggable space-backend subsystem (DESIGN.md §13).

Covers the registry surface (name/alias/auto/instance resolution), the
exact engine's bit-parity with the pre-refactor golden mappings, the
annealing backend's validity on the large fabrics it exists for (independent
``Mapping.validate`` + cycle-accurate execution), its determinism contract,
and the cache-key separation between engines (memory, disk, and the
CACHE_VERSION bump orphaning pre-split entries).
"""

from __future__ import annotations

import hashlib
import json
import os

import pytest

from repro.core import CGRA, map_dfg
from repro.core.benchsuite import load_suite
from repro.core.mapper import _cache_base_key, clear_mapping_cache
from repro.core.service.cache import CACHE_VERSION, DiskMappingCache
from repro.core.simulate import check_equivalence, utilization_report
from repro.core.space_backends import (
    AUTO_EXACT_MAX_PES,
    AnnealSpaceBackend,
    ExactSpaceBackend,
    SpaceBudget,
    available_space_backends,
    create_space_backend,
    resolve_space_backend,
    resolve_space_backend_name,
)

_GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data_golden_4x4.json")


def _sha(mapping) -> str:
    return hashlib.sha1(json.dumps(
        {"t_abs": mapping.t_abs, "placement": mapping.placement},
        separators=(",", ":")).encode()).hexdigest()


# ---------------------------------------------------------------- registry

def test_registry_lists_both_engines():
    avail = available_space_backends()
    assert avail.get("exact") is True and avail.get("anneal") is True


def test_name_and_alias_resolution():
    assert resolve_space_backend_name("exact") == "exact"
    assert resolve_space_backend_name("anneal") == "anneal"
    # historical/colloquial aliases canonicalise
    assert resolve_space_backend_name("mono") == "exact"
    assert resolve_space_backend_name("bitset") == "exact"
    assert resolve_space_backend_name("sa") == "anneal"
    assert resolve_space_backend_name("cluster") == "anneal"


def test_unknown_name_raises():
    with pytest.raises(ValueError, match="unknown space backend"):
        resolve_space_backend_name("simplex")
    with pytest.raises(ValueError, match="unknown space backend"):
        create_space_backend("simplex")


def test_auto_resolution_is_fabric_sized():
    with pytest.raises(ValueError, match="needs the target CGRA"):
        resolve_space_backend_name("auto")
    assert resolve_space_backend_name("auto", CGRA(4, 4)) == "exact"
    # 20x20 = 400 PEs sits exactly on the threshold (still exact)
    assert CGRA(20, 20).num_pes == AUTO_EXACT_MAX_PES
    assert resolve_space_backend_name("auto", CGRA(20, 20)) == "exact"
    assert resolve_space_backend_name("auto", CGRA(21, 21)) == "anneal"
    assert resolve_space_backend_name("auto", CGRA(100, 100)) == "anneal"


def test_instance_passthrough_and_type_errors():
    eng = ExactSpaceBackend()
    assert resolve_space_backend(eng) is eng
    anneal = AnnealSpaceBackend()
    assert resolve_space_backend(anneal) is anneal
    assert resolve_space_backend("exact").name == "exact"
    with pytest.raises(TypeError, match="place"):
        resolve_space_backend(42)


def test_mapper_rejects_unknown_backend():
    dfg = load_suite(names=["bitcount"])["bitcount"]
    with pytest.raises(ValueError, match="space.backend"):
        map_dfg(dfg, CGRA(4, 4), space_backend="simplex")


# --------------------------------------------------------- exact bit-parity

@pytest.mark.parametrize("name", ["bitcount", "gsm", "susan"])
def test_explicit_exact_matches_golden(name):
    """``space_backend="exact"`` is the refactored-but-identical engine: the
    deterministic 4×4 mappings must still match the pre-split golden hashes
    bit for bit (the full-suite default-path gate lives in test_api.py)."""
    with open(_GOLDEN_PATH) as f:
        golden = json.load(f)
    dfg = load_suite(names=[name])[name]
    res = map_dfg(dfg, CGRA(4, 4), deterministic=True, use_cache=False,
                  space_backend="exact")
    assert res.ok, res.reason
    assert res.mapping.ii == golden[name]["ii"]
    assert _sha(res.mapping) == golden[name]["sha1"]
    assert res.stats.space_backend == "exact"


# --------------------------------------------------------- anneal validity

@pytest.mark.parametrize("size", [20, 50])
def test_anneal_maps_midsize_kernel_validated_and_executed(size):
    """The annealing backend's acceptance contract: a mid-size suite kernel
    maps on 20×20 and 50×50, passes the independent structural validator,
    and executes bit-identically to the reference interpreter."""
    dfg = load_suite(names=["backprop"])["backprop"]
    res = map_dfg(dfg, CGRA(size, size), space_backend="anneal",
                  use_cache=False, seed=1)
    assert res.ok, res.reason
    assert res.stats.space_backend == "anneal"
    assert res.mapping.validate() == []
    check_equivalence(res.mapping)
    u = utilization_report(res.mapping)
    assert u["num_pes"] == size * size
    assert u["slots_used"] == dfg.num_nodes
    assert 0 < u["occupancy"] < 1


def test_anneal_place_is_deterministic_under_node_budget():
    """Same inputs + same seed + node budget (no wall clock) -> the same
    placement, the deterministic contract ``SpaceBudget`` documents."""
    dfg = load_suite(names=["backprop"])["backprop"]
    cgra = CGRA(50, 50)
    res = map_dfg(dfg, cgra, deterministic=True, use_cache=False,
                  space_backend="anneal", seed=3)
    res2 = map_dfg(dfg, cgra, deterministic=True, use_cache=False,
                   space_backend="anneal", seed=3)
    assert res.ok and res2.ok
    assert res.mapping.ii == res2.mapping.ii
    assert _sha(res.mapping) == _sha(res2.mapping)


def test_auto_uses_anneal_on_large_fabric():
    dfg = load_suite(names=["backprop"])["backprop"]
    res = map_dfg(dfg, CGRA(50, 50), use_cache=False)
    assert res.ok, res.reason
    assert res.stats.space_backend == "anneal"
    assert res.mapping.validate() == []


# ------------------------------------------------------- cache separation

def test_cache_key_separates_backends():
    dfg = load_suite(names=["bitcount"])["bitcount"]
    cgra = CGRA(4, 4)
    k_exact = _cache_base_key(dfg, cgra, "strict", None, 0, "exact")
    k_anneal = _cache_base_key(dfg, cgra, "strict", None, 0, "anneal")
    assert k_exact != k_anneal
    # legacy positional callers mean the exact engine
    assert _cache_base_key(dfg, cgra, "strict", None) == k_exact


def test_memory_cache_never_serves_across_backends():
    clear_mapping_cache()
    dfg = load_suite(names=["bitcount"])["bitcount"]
    cgra = CGRA(4, 4)
    first = map_dfg(dfg, cgra, space_backend="exact")
    assert first.ok and not first.stats.cache_hit
    # same problem, other engine: must solve, not hit exact's entry
    cross = map_dfg(dfg, cgra, space_backend="anneal")
    assert cross.ok and not cross.stats.cache_hit
    assert cross.stats.space_backend == "anneal"
    # same engine again: now it hits, and provenance stays truthful
    again = map_dfg(dfg, cgra, space_backend="exact")
    assert again.ok and again.stats.cache_hit
    assert again.stats.space_backend == "exact"


def test_disk_cache_rejects_poisoned_anneal_entry(tmp_path):
    """A schema-valid but structurally invalid disk entry under the anneal
    key is dropped (re-validation + invalidate), never served."""
    clear_mapping_cache()
    dfg = load_suite(names=["bitcount"])["bitcount"]
    cgra = CGRA(4, 4)
    base_key = _cache_base_key(dfg, cgra, "strict", None, 0, "anneal")
    store = DiskMappingCache(str(tmp_path))
    n = dfg.num_nodes
    # every node on PE 0 at time 0: guaranteed mono1 slot conflicts
    store.put(base_key, 1, [0] * n, [0] * n)
    res = map_dfg(dfg, cgra, space_backend="anneal", cache_dir=str(tmp_path))
    assert res.ok, res.reason
    assert not res.stats.disk_cache_hit
    assert res.mapping.validate() == []


def test_cache_version_bump_orphans_pre_split_entries(tmp_path, monkeypatch):
    """v4 keys carry the backend token; v3-era entries (written before the
    key schema grew it) must stop matching entirely."""
    assert CACHE_VERSION >= 4
    import repro.core.service.cache as cache_mod

    store = DiskMappingCache(str(tmp_path))
    key = store.entry_key("abc", 4, 4, "mesh", "strict", None,
                          space_backend="anneal")
    monkeypatch.setattr(cache_mod, "CACHE_VERSION", CACHE_VERSION - 1)
    store.put(key, 2, [0, 1], [0, 1])
    monkeypatch.setattr(cache_mod, "CACHE_VERSION", CACHE_VERSION)
    assert store.get(key, 1, 4) is None
    assert store.prune() == 1


def test_entry_key_mirrors_mapper_key_with_backend():
    dfg = load_suite(names=["bitcount"])["bitcount"]
    cgra = CGRA(4, 4)
    mapper_key = _cache_base_key(dfg, cgra, "strict", None, 0, "anneal")
    store_key = DiskMappingCache.entry_key(
        dfg.stable_hash(), 4, 4, "mesh", "strict", None, None,
        cgra.pressure_token(None), 0, "anneal")
    assert mapper_key == store_key

"""Time-solver and monomorphism-search tests, including the executable
refutation of the published constraint-sufficiency claim (DESIGN.md §7)."""

import pytest

from repro.core import CGRA, DFG, Edge, running_example
from repro.core.mono import SpaceStats, check_monomorphism, find_monomorphism
from repro.core.time_smt import HAVE_Z3, TimeSolver, check_time_solution


@pytest.mark.parametrize("backend", ["z3", "python"] if HAVE_Z3 else ["python"])
def test_time_solution_satisfies_all_constraints(backend):
    d = running_example()
    c = CGRA(2, 2)
    solver = TimeSolver(d, c, 4, backend=backend, timeout_s=30)
    sol = solver.next_solution()
    assert sol is not None
    assert check_time_solution(d, c, sol, connectivity="strict") == []


@pytest.mark.skipif(not HAVE_Z3, reason="z3 unavailable")
def test_backends_agree_on_feasibility():
    d = running_example()
    c = CGRA(2, 2)
    # II=4 feasible on both; II=3 infeasible (below RecII) on both
    assert TimeSolver(d, c, 4, backend="z3").next_solution() is not None
    assert TimeSolver(d, c, 4, backend="python").next_solution() is not None
    for backend in ("z3", "python"):
        with pytest.raises(ValueError):
            TimeSolver(d, c, 3, backend=backend)


def test_enumeration_blocks_previous_label_partitions():
    d = running_example()
    c = CGRA(2, 2)
    solver = TimeSolver(d, c, 4, timeout_s=30)
    seen = set()
    for _ in range(5):
        sol = solver.next_solution()
        if sol is None:
            break
        key = tuple(sol.labels)
        assert key not in seen, "same label partition enumerated twice"
        seen.add(key)
    assert len(seen) >= 2


def test_monomorphism_found_and_valid():
    d = running_example()
    c = CGRA(2, 2)
    sol = TimeSolver(d, c, 4, timeout_s=30).next_solution()
    space = find_monomorphism(d, c, sol.labels, 4)
    assert space is not None
    assert check_monomorphism(d, c, sol.labels, space.placement, 4) == []


def test_check_monomorphism_detects_violations():
    d = DFG.from_edge_list(3, [(0, 1), (1, 2)], ops=["input", "mov", "store"])
    c = CGRA(2, 2)
    labels = [0, 1, 2]
    # mono1 violation: two nodes on same (pe, step)
    errs = check_monomorphism(d, c, [0, 0, 1], [1, 1, 1], 2)
    assert any("mono1" in e for e in errs)
    # mono3 violation: adjacent nodes on non-adjacent PEs (0 and 3 diagonal)
    errs = check_monomorphism(d, c, labels, [0, 3, 3], 3)
    assert any("mono3" in e for e in errs)


# ----------------------------------------------------------------------
# The paper's §IV-D proof claims capacity+connectivity guarantee a
# monomorphism. Counterexample: a same-step star v-{a,b,c} on a 2x2 CGRA
# satisfies the published constraints (|S_v| = 3 <= D_M = 3, capacity 4 <= 4)
# but cannot embed: a,b,c need distinct PEs in v's OPEN neighbourhood (size
# 2). Our "strict" mode closes this gap; "paper" mode reproduces it.
# ----------------------------------------------------------------------

def _star_dfg():
    # carried edges (distance 1) let all four nodes share a kernel step at II=1
    return DFG(
        num_nodes=4,
        edges=[Edge(0, 1, 1), Edge(0, 2, 1), Edge(0, 3, 1)],
        ops=["input", "phi", "phi", "phi"],
        name="same_step_star",
    )


def test_published_constraints_are_not_sufficient():
    d = _star_dfg()
    c = CGRA(2, 2)
    from repro.core.time_smt import TimeSolution

    sol = TimeSolution(1, [0, 0, 0, 0])
    # satisfies every published constraint...
    assert check_time_solution(d, c, sol, connectivity="paper") == []
    # ...but no monomorphism exists (exhaustive: 4 nodes x 4 PEs)
    assert find_monomorphism(d, c, sol.labels, 1, timeout_s=10) is None


def test_strict_mode_rejects_the_counterexample():
    d = _star_dfg()
    c = CGRA(2, 2)
    from repro.core.time_smt import TimeSolution

    sol = TimeSolution(1, [0, 0, 0, 0])
    errs = check_time_solution(d, c, sol, connectivity="strict")
    assert errs, "strict connectivity must reject the same-step star"


def test_triangle_partitions_rejected_by_strict_solver():
    # triangle via carried edges, II=1: mesh is bipartite => unembeddable
    d = DFG(
        num_nodes=3,
        edges=[Edge(0, 1, 1), Edge(1, 2, 1), Edge(0, 2, 1)],
        ops=["input", "phi", "phi"],
        name="triangle",
    )
    c = CGRA(4, 4)
    solver = TimeSolver(d, c, 1, connectivity="strict", timeout_s=10)
    sol = solver.next_solution()
    assert sol is None, "strict solver must refuse mono-chromatic triangles"

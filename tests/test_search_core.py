"""Property-style cross-checks of the rebuilt search core (bitset
monomorphism engine + incremental CP time backend) against (a) the
independent validators, (b) a compact reference implementation of the
pre-rebuild set-based space search, and (c) the IIs the pre-rebuild pipeline
achieved on the benchmark suite."""

import pytest

from repro.core import CGRA, running_example
from repro.core.benchsuite import load_suite
from repro.core.mapper import clear_mapping_cache, map_dfg
from repro.core.mono import check_monomorphism, find_monomorphism
from repro.core.time_smt import HAVE_Z3, TimeSolver, check_time_solution


# ---------------------------------------------------------------- reference
# Compact port of the pre-rebuild set-based space search (greedy dive +
# chronological backtracking over Python sets). Kept here as an executable
# spec: slow but obviously faithful to the mono1/mono2/mono3 definition.

def reference_monomorphism(dfg, cgra, labels, ii, max_nodes=200_000):
    n = dfg.num_nodes
    adj = dfg.undirected_adjacency()
    closed = [set((p, *cgra.neighbors[p])) for p in range(cgra.num_pes)]
    placement = [-1] * n
    occupied = [set() for _ in range(ii)]
    budget = [max_nodes]

    def candidates(v):
        placed = [placement[u] for u in adj[v] if placement[u] >= 0]
        if placed:
            base = set(closed[placed[0]])
            for pu in placed[1:]:
                base &= closed[pu]
            return sorted(p for p in base if p not in occupied[labels[v]])
        return sorted(
            (p for p in range(cgra.num_pes) if p not in occupied[labels[v]]),
            key=lambda p: -len(closed[p]),
        )

    def select():
        frontier = [
            v for v in range(n)
            if placement[v] < 0 and any(placement[u] >= 0 for u in adj[v])
        ]
        if frontier:
            return min(frontier, key=lambda v: (len(candidates(v)), -len(adj[v])))
        rest = [v for v in range(n) if placement[v] < 0]
        return max(rest, key=lambda v: len(adj[v])) if rest else None

    def rec(count):
        if count == n:
            return True
        v = select()
        if v is None:
            return True
        for p in candidates(v):
            budget[0] -= 1
            if budget[0] < 0:
                return False
            placement[v] = p
            occupied[labels[v]].add(p)
            if rec(count + 1):
                return True
            occupied[labels[v]].discard(p)
            placement[v] = -1
        return False

    return list(placement) if rec(0) else None


CASES = [
    ("bitcount", 2), ("bitcount", 5), ("fft", 2), ("fft", 5),
    ("gsm", 2), ("lud", 5), ("susan", 5), ("aes", 5),
]

# IIs achieved by the pre-rebuild implementation (re-run from the seed commit
# against the PYTHONHASHSEED-stable benchsuite, time_budget_s=30): the rebuilt
# pipeline must never be worse.
OLD_IIS = {
    ("bitcount", 2): 3, ("bitcount", 5): 3,
    ("fft", 2): 7, ("fft", 5): 7,
    ("gsm", 2): 6, ("gsm", 5): 4,
    ("lud", 2): 7, ("lud", 5): 4,
    ("susan", 2): 6, ("susan", 5): 3,
    ("aes", 2): 14, ("aes", 5): 14,
}


@pytest.mark.parametrize("name,size", CASES)
def test_bitset_engine_agrees_with_reference(name, size):
    """Both engines accept the same label partitions; every bitset placement
    passes the independent validator."""
    d = load_suite()[name]
    c = CGRA(size, size)
    solver = TimeSolver(d, c, OLD_IIS[(name, size)], timeout_s=10)
    checked = 0
    while checked < 3:
        sol = solver.next_solution(step_budget=100_000)
        if sol is None:
            break
        bits = find_monomorphism(
            d, c, sol.labels, sol.ii, timeout_s=None, node_budget=300_000
        )
        ref = reference_monomorphism(d, c, sol.labels, sol.ii)
        if bits is not None:
            assert check_monomorphism(d, c, sol.labels, bits.placement, sol.ii) == []
        if ref is not None:
            assert check_monomorphism(d, c, sol.labels, ref, sol.ii) == []
            # the rebuilt engine must not miss embeddings the reference finds
            assert bits is not None
        checked += 1
    assert checked > 0


@pytest.mark.parametrize("name,size", sorted(OLD_IIS))
def test_rebuilt_pipeline_ii_no_worse_than_seed(name, size):
    d = load_suite()[name]
    res = map_dfg(d, CGRA(size, size), deterministic=True, use_cache=False)
    assert res.ok, f"{name}@{size}: {res.reason}"
    assert res.mapping.ii <= OLD_IIS[(name, size)], (
        f"{name}@{size}: II {res.mapping.ii} worse than seed {OLD_IIS[(name, size)]}"
    )
    assert res.mapping.validate() == []


@pytest.mark.parametrize("name,size", CASES[:4])
def test_cp_backend_solutions_satisfy_strict_constraints(name, size):
    d = load_suite()[name]
    c = CGRA(size, size)
    from repro.core.schedule import min_ii

    solver = TimeSolver(d, c, min_ii(d, c) + 1, timeout_s=10)
    seen = set()
    for _ in range(4):
        sol = solver.next_solution(step_budget=100_000)
        if sol is None:
            break
        key = tuple(sol.labels)
        assert key not in seen, "label partition re-proposed"
        seen.add(key)
        assert check_time_solution(d, c, sol, connectivity="strict") == []
    assert seen


def test_cp_backend_is_resumable_under_step_budget():
    d = load_suite()["fft"]
    c = CGRA(5, 5)
    full = TimeSolver(d, c, 7, backend="cp", timeout_s=10).next_solution()
    assert full is not None
    drip = TimeSolver(d, c, 7, backend="cp")   # z3 would ignore step_budget
    got = None
    for _ in range(100_000):
        got = drip.next_solution(step_budget=3)
        if got is not None:
            break
        assert not drip.exhausted
    assert got is not None
    # same deterministic search => same first solution, budgeted or not
    assert got.t_abs == full.t_abs


@pytest.mark.skipif(not HAVE_Z3, reason="z3 unavailable")
def test_z3_and_cp_agree_on_feasibility():
    d = running_example()
    c = CGRA(2, 2)
    for backend in ("z3", "cp"):
        s = TimeSolver(d, c, 4, backend=backend, timeout_s=30)
        assert s.next_solution() is not None, backend


def test_deterministic_mode_bypasses_cache():
    """Reproducibility must not depend on process history: a budget-limited
    wall-clock result in the cache is never returned to a deterministic call."""
    clear_mapping_cache()
    d = load_suite()["bitcount"]
    c = CGRA(5, 5)
    map_dfg(d, c, time_budget_s=10)                 # populates the cache
    det = map_dfg(d, c, deterministic=True)         # must ignore it
    assert det.ok and not det.stats.cache_hit
    clear_mapping_cache()


def test_deterministic_mode_rejects_z3():
    with pytest.raises(ValueError, match="deterministic"):
        map_dfg(running_example(), CGRA(2, 2), deterministic=True, backend="z3")


def test_mapping_cache_round_trip():
    clear_mapping_cache()
    d = load_suite()["bitcount"]
    c = CGRA(5, 5)
    first = map_dfg(d, c, time_budget_s=10)
    again = map_dfg(d, c, time_budget_s=10)
    assert first.ok and again.ok
    assert again.stats.cache_hit
    assert again.stats.backend == "cache"
    assert again.mapping.ii == first.mapping.ii
    assert again.mapping.t_abs == first.mapping.t_abs
    assert again.mapping.placement == first.mapping.placement
    assert again.mapping.validate() == []
    clear_mapping_cache()


def test_deterministic_mode_is_reproducible():
    d = load_suite()["gsm"]
    c = CGRA(5, 5)
    a = map_dfg(d, c, deterministic=True, use_cache=False)
    b = map_dfg(d, c, deterministic=True, use_cache=False)
    assert a.ok and b.ok
    assert a.mapping.t_abs == b.mapping.t_abs
    assert a.mapping.placement == b.mapping.placement

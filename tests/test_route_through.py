"""Route-through mapping (DESIGN.md §12) + per-PE register-pressure guarantee.

Covers the two PR-5 fixes end to end:

* multi-hop fabrics: a kernel whose producer/consumer banks are never
  adjacent (``onehop_split_4x4``) is unmappable under direct adjacency at
  every II, maps with ``max_route_hops <= 2``, and the routed mapping passes
  every independent validator and executes bit-identically to the *original*
  DFG's reference interpretation (movs are identity ops);
* the ``max_register_pressure`` guarantee is per-PE
  (``min(max_rp, registers_at(pe))``): a mapping whose scalar pressure fold
  passes but oversubscribes a smaller per-class file is rejected — including
  when it arrives through either mapping-cache layer (CACHE_VERSION 3).
"""

import numpy as np
import pytest

from repro.api import Compiler, CompileResult, resolve_options
from repro.core import CGRA, get_preset, map_dfg, splice_routes
from repro.core.benchsuite import route_stress_dfg
from repro.core.dfg import DFG, Edge
from repro.core.mapper import (
    Mapping,
    _cache_base_key,
    _cache_put,
    _pressure_offenders,
    clear_mapping_cache,
)
from repro.core.mono import check_monomorphism, check_routes
from repro.core.service.batch import JobReport
from repro.core.service.cache import CACHE_VERSION, DiskMappingCache
from repro.core.simulate import (
    check_equivalence,
    check_register_pressure,
    execute_mapping,
    interpret_dfg,
    register_pressure_by_pe,
)


@pytest.fixture(autouse=True)
def _fresh_caches(monkeypatch):
    monkeypatch.delenv("REPRO_CACHE_DIR", raising=False)
    clear_mapping_cache()
    yield
    clear_mapping_cache()


# ------------------------------------------------------------- reach masks

def test_reach_masks_extend_closed_masks():
    cgra = CGRA(4, 4)
    assert cgra.reach_masks(1) == cgra.closed_masks
    r2 = cgra.reach_masks(2)
    for pe in range(cgra.num_pes):
        assert r2[pe] & cgra.closed_masks[pe] == cgra.closed_masks[pe]
    # corner of a 4x4 mesh: 3 closed, 6 within 2 hops, full grid within 6
    assert cgra.closed_masks[0].bit_count() == 3
    assert r2[0].bit_count() == 6
    assert cgra.reach_masks(6)[0] == (1 << 16) - 1
    assert cgra.reach_degree(2) > cgra.connectivity_degree


def test_onehop_split_banks_never_adjacent():
    cgra = get_preset("onehop_split_4x4").cgra()
    mem = cgra.capability_masks["mem"]
    mul = cgra.capability_masks["mul"]
    for pe in range(cgra.num_pes):
        if (mem >> pe) & 1:
            assert cgra.closed_masks[pe] & mul == 0      # direct: impossible
            assert cgra.reach_masks(2)[pe] & mul != 0    # one mov: bridged


# ------------------------------------------------------------ DFG rewrite

def test_splice_routes_preserves_noncommutative_operand_order():
    dfg = DFG(num_nodes=4, ops=["input", "input", "sub", "store"],
              edges=[Edge(0, 2), Edge(1, 2), Edge(2, 3)], name="subtract")
    routed, routes = splice_routes(dfg, [(0, 2, 0, 1)])
    assert routed.num_nodes == 5 and routed.ops[4] == "mov"
    assert routes[0].movs == (4,)
    # the mov (id 4) replaces operand 0 of the sub; without port pinning it
    # would sort after input 1 and flip the subtraction
    inputs = {0: [5.0, 7.0], 1: [2.0, 3.0]}
    assert interpret_dfg(dfg, inputs, 2)[3] == [3.0, 4.0]
    assert interpret_dfg(routed, inputs, 2)[3] == [3.0, 4.0]
    # port-pinned edges survive the JSON round-trip
    again = DFG.from_json(routed.to_json())
    assert interpret_dfg(again, inputs, 2)[3] == [3.0, 4.0]
    assert again.stable_hash() == routed.stable_hash()


def test_splice_routes_rejects_unknown_edge():
    with pytest.raises(ValueError, match="no unrouted edge"):
        splice_routes(route_stress_dfg(), [(0, 4, 0, 1)])


# ----------------------------------------------------- route-through mapping

def test_route_kernel_unmappable_direct():
    cgra = get_preset("onehop_split_4x4").cgra()
    res = map_dfg(route_stress_dfg(), cgra, deterministic=True, max_ii=4)
    assert not res.ok


def test_route_through_maps_validates_and_executes():
    dfg = route_stress_dfg()
    cgra = get_preset("onehop_split_4x4").cgra()
    res = map_dfg(dfg, cgra, deterministic=True, max_route_hops=2, max_ii=6)
    assert res.ok, res.reason
    m = res.mapping
    assert m.routes and m.num_route_movs >= 2
    assert all(m.dfg.ops[v] == "mov" for r in m.routes for v in r.movs)
    # original node ids survive the rewrite
    assert list(m.original_nodes) == list(dfg.nodes)
    assert len(m.original_placement()) == dfg.num_nodes
    # every independent validator: monomorphism, routes, full validate
    assert check_monomorphism(m.dfg, cgra, m.labels, m.placement, m.ii) == []
    assert check_routes(m.dfg, cgra, m.t_abs, m.placement, m.ii, m.routes) == []
    assert m.validate(connectivity="strict") == []
    # the routed mapping computes the ORIGINAL kernel (movs are identity)
    check_equivalence(m)
    inputs = {0: [float(i) for i in range(6)]}
    ref = interpret_dfg(dfg, inputs, 6)
    rep = execute_mapping(m, inputs, 6)
    for v, stream in ref.items():
        assert rep.outputs[v][: len(stream)] == stream


def test_carried_edge_routes_with_distance_preserved():
    """A loop-carried cross-bank edge splices as src→mov (intra) + mov→dst
    (carrying the original distance) and still executes the original
    recurrence."""
    dfg = DFG(num_nodes=5, ops=["input", "load", "const", "mul", "store"],
              edges=[Edge(0, 1), Edge(1, 3, distance=1), Edge(2, 3),
                     Edge(3, 4)],
              name="carried_route")
    cgra = get_preset("onehop_split_4x4").cgra()
    res = map_dfg(dfg, cgra, deterministic=True, max_route_hops=2, max_ii=8)
    assert res.ok, res.reason
    m = res.mapping
    assert (1, 3, 1, 1) in m.routes_spec()     # the carried edge was routed
    assert m.validate() == []
    inputs = {0: [float(i + 1) for i in range(6)]}
    ref = interpret_dfg(dfg, inputs, 6)
    rep = execute_mapping(m, inputs, 6)
    for v, stream in ref.items():
        assert rep.outputs[v][: len(stream)] == stream


def test_route_escalation_is_deterministic():
    dfg = route_stress_dfg()
    cgra = get_preset("onehop_split_4x4").cgra()
    a = map_dfg(dfg, cgra, deterministic=True, max_route_hops=2, max_ii=6)
    b = map_dfg(dfg, cgra, deterministic=True, max_route_hops=2, max_ii=6)
    assert a.ok and b.ok
    assert a.mapping.t_abs == b.mapping.t_abs
    assert a.mapping.placement == b.mapping.placement
    assert a.mapping.routes_spec() == b.mapping.routes_spec()


def test_direct_embeddings_still_preferred_with_hops_allowed():
    """Escalation order: a kernel that embeds directly spends zero movs even
    when route-through is allowed."""
    from repro.core import running_example

    res = map_dfg(running_example(), CGRA(2, 2), deterministic=True,
                  max_route_hops=2)
    assert res.ok and res.mapping.routes == [] and res.mapping.ii == 4


def test_routed_mapping_through_pallas_program():
    """The cgra_sim program builder consumes routed mappings unchanged: the
    rewritten DFG is an ordinary DFG whose movs occupy real (PE, step) slots."""
    from repro.kernels.ops import cgra_run, compile_program

    dfg = route_stress_dfg()
    cgra = get_preset("onehop_split_4x4").cgra()
    res = map_dfg(dfg, cgra, deterministic=True, max_route_hops=2, max_ii=6)
    assert res.ok, res.reason
    prog = compile_program(res.mapping)
    num_iters, batch = 5, 8
    rng = np.random.default_rng(0)
    inputs = {0: rng.uniform(-4, 4, (num_iters, batch)).astype(np.float32).round(2)}
    outs, _trace = cgra_run(prog, inputs, num_iters, batch_tile=batch)
    ref = interpret_dfg(
        dfg, {0: [float(x) for x in inputs[0][:, 0]]}, num_iters
    )
    for v, stream in ref.items():
        np.testing.assert_allclose(
            outs[v][:, 0], np.asarray(stream, np.float32), rtol=1e-6, atol=1e-6
        )


def test_batch_path_reconstructs_routed_mapping():
    comp = Compiler(
        "onehop_split_4x4",
        resolve_options("deterministic-ci", max_route_hops=2, max_ii=6),
    )
    batch = comp.compile_batch([route_stress_dfg()])
    assert batch.ok
    row = batch.results[0]
    assert row.mapping is not None and row.mapping.routes
    assert row.route_movs == row.mapping.num_route_movs >= 2
    assert row.as_dict()["route_movs"] == row.route_movs
    check_equivalence(row.mapping)


# ------------------------------------------- per-PE register-pressure fixes

#: A 12-node ring on the satmapit 4x4: node 0 on interior PE 5 produces a
#: value consumed 11 cycles later (node 11, adjacent to PE 5), so PE 5 holds
#: ~12 live values at II=1 — above the interior file (8), below the scalar
#: mem-file bound (16) the old scalar fold checked against.
_RING_PES = [5, 6, 7, 3, 2, 1, 0, 4, 8, 12, 13, 9]


def _ring_dfg() -> DFG:
    edges = [Edge(i, i + 1) for i in range(11)] + [Edge(0, 11)]
    return DFG(num_nodes=12, ops=["input"] + ["add"] * 11, edges=edges,
               name="pressure_ring")


def _poisoned_mapping(cgra) -> Mapping:
    return Mapping(dfg=_ring_dfg(), cgra=cgra, ii=1,
                   t_abs=list(range(12)), placement=list(_RING_PES))


def test_scalar_fold_passes_but_per_pe_bound_catches():
    cgra = get_preset("satmapit_edge_mem_4x4").cgra()
    m = _poisoned_mapping(cgra)
    by_pe = register_pressure_by_pe(m)
    assert by_pe[5] > cgra.registers_at(5)            # interior file (8) blown
    assert check_register_pressure(m) <= 16           # old scalar check passes
    assert _pressure_offenders(m, 16) == [5]
    assert any("register pressure" in e for e in m.validate())


def test_map_dfg_guarantee_is_per_pe():
    cgra = get_preset("satmapit_edge_mem_4x4").cgra()
    res = map_dfg(_ring_dfg(), cgra, deterministic=True,
                  max_register_pressure=16)
    assert res.ok, res.reason
    for pe, p in register_pressure_by_pe(res.mapping).items():
        assert p <= min(16, cgra.registers_at(pe)), (pe, p)
    assert res.mapping.validate() == []


def test_memory_cache_cannot_serve_oversubscribing_mapping():
    cgra = get_preset("satmapit_edge_mem_4x4").cgra()
    dfg = _ring_dfg()
    base_key = _cache_base_key(dfg, cgra, "strict", 16)
    _cache_put(base_key, _poisoned_mapping(cgra))
    res = map_dfg(dfg, cgra, max_register_pressure=16, time_budget_s=60)
    assert res.ok, res.reason
    assert not res.stats.cache_hit                    # poisoned entry dropped
    for pe, p in register_pressure_by_pe(res.mapping).items():
        assert p <= min(16, cgra.registers_at(pe))


def test_disk_cache_cannot_serve_oversubscribing_mapping(tmp_path):
    cgra = get_preset("satmapit_edge_mem_4x4").cgra()
    dfg = _ring_dfg()
    base_key = _cache_base_key(dfg, cgra, "strict", 16)
    poisoned = _poisoned_mapping(cgra)
    store = DiskMappingCache(str(tmp_path))
    store.put(base_key, 1, poisoned.t_abs, poisoned.placement)
    res = map_dfg(dfg, cgra, max_register_pressure=16, time_budget_s=60,
                  cache_dir=str(tmp_path))
    assert res.ok, res.reason
    assert not res.stats.disk_cache_hit
    for pe, p in register_pressure_by_pe(res.mapping).items():
        assert p <= min(16, cgra.registers_at(pe))


def test_cache_key_tracks_register_sizing():
    """Two same-shape grids with different register files must not alias under
    a pressure guarantee (they admit different mappings) — and must still
    share entries when no guarantee is requested (sizing can't matter then)."""
    dfg = _ring_dfg()
    small = CGRA(4, 4, registers_per_pe=4)
    big = CGRA(4, 4, registers_per_pe=16)
    assert (_cache_base_key(dfg, small, "strict", 12)
            != _cache_base_key(dfg, big, "strict", 12))
    assert (_cache_base_key(dfg, small, "strict", None)
            == _cache_base_key(dfg, big, "strict", None))
    # the route-hops allowance is keyed too: routed mappings carry movs a
    # direct-only caller cannot accept
    assert (_cache_base_key(dfg, big, "strict", None, 2)
            != _cache_base_key(dfg, big, "strict", None))


def test_cache_version_bumped_and_orphans_pre_fix_entries(tmp_path, monkeypatch):
    assert CACHE_VERSION >= 3     # per-PE pressure token + routes schema
    import repro.core.service.cache as cache_mod

    store = DiskMappingCache(str(tmp_path))
    key = store.entry_key("abc", 4, 4, "mesh", "strict", 16)
    monkeypatch.setattr(cache_mod, "CACHE_VERSION", CACHE_VERSION - 1)
    store.put(key, 2, [0, 1], [0, 1])                 # a "pre-fix" entry
    monkeypatch.setattr(cache_mod, "CACHE_VERSION", CACHE_VERSION)
    assert store.get(key, 1, 4) is None               # orphaned, never served
    assert store.prune() == 1


def test_batch_reconstruction_rejects_oversubscribing_worker_row():
    cgra = get_preset("satmapit_edge_mem_4x4").cgra()
    dfg = _ring_dfg()
    job = JobReport(name="ring", ok=True, ii=1, m_ii=1, wall_s=0.1,
                    t_abs=list(range(12)), placement=list(_RING_PES))
    # same per-PE bounds as the direct path: the row is flipped to a failure
    row = CompileResult.from_job_report(job, dfg, cgra,
                                        max_register_pressure=16)
    assert not row.ok and row.failure == "error" and row.mapping is None
    assert "PE 5" in row.reason
    # without a pressure guarantee the (structurally valid) row stays ok
    row2 = CompileResult.from_job_report(job, dfg, cgra)
    assert row2.ok and row2.mapping is not None

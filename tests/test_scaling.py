"""Large-CGRA scaling tests: the rebuilt search core must handle 20x20 (400
PE) grids inside the CI budget — the regime the paper's Fig. 5 targets and
the one the pre-rebuild Python-set engine could not reach interactively."""

import time

import pytest

from repro.core import CGRA
from repro.core.benchsuite import load_suite
from repro.core.mapper import map_dfg
from repro.core.simulate import check_equivalence

CI_BUDGET_S = 60.0


def test_20x20_midsize_dfg_maps_within_ci_budget():
    """A mid-size DFG (nw: 33 nodes) end-to-end on a 20x20 CGRA in < 60 s."""
    d = load_suite()["nw"]
    start = time.perf_counter()
    res = map_dfg(d, CGRA(20, 20), time_budget_s=40, use_cache=False)
    elapsed = time.perf_counter() - start
    assert res.ok, res.reason
    assert res.mapping.validate() == []
    assert res.mapping.ii >= res.stats.m_ii
    assert elapsed < CI_BUDGET_S, f"20x20 mapping took {elapsed:.1f}s"
    check_equivalence(res.mapping, num_iters=3)


def test_20x20_aes_near_flat_vs_4x4():
    """Fig. 5 property: `aes` compile time must not blow up with grid size —
    20x20 within 5x of 4x4 (the paper's joint baselines grow ~10^5x)."""
    d = load_suite()["aes"]
    times = {}
    for size in (4, 20):
        res = map_dfg(d, CGRA(size, size), time_budget_s=30, use_cache=False)
        assert res.ok, f"aes@{size}: {res.reason}"
        times[size] = max(res.stats.total_s, 0.05)  # clamp timer noise floor
    assert times[20] <= 5 * times[4], (
        f"aes not near-flat: 4x4 {times[4]:.3f}s vs 20x20 {times[20]:.3f}s"
    )


@pytest.mark.parametrize("size", [10, 20])
def test_large_grid_mapping_is_valid_and_executes(size):
    d = load_suite()["sha1"]
    res = map_dfg(d, CGRA(size, size), time_budget_s=30, use_cache=False)
    assert res.ok, res.reason
    assert res.mapping.validate() == []
    check_equivalence(res.mapping, num_iters=3)

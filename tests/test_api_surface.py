"""Public-API snapshot test (DESIGN.md §11).

``tests/data_api_surface.json`` is the checked-in contract: the exported
symbol sets of ``repro.api`` and ``repro.core``, the ``CompileOptions``
field list, the profile names, and the ``CompileResult`` row schema. Any
drift — a renamed option, a dropped export, a new result key — fails here
first, forcing a deliberate snapshot update (and a migration note) instead
of a silent break for downstream users.

To regenerate after an *intentional* change, update the JSON to match the
assertion messages (every assert compares against the live value).
"""

import dataclasses
import json
import os

import repro.api as api
import repro.core as core
from repro.api import PROFILES, CompileOptions, CompileResult

_SNAPSHOT_PATH = os.path.join(os.path.dirname(__file__),
                              "data_api_surface.json")

with open(_SNAPSHOT_PATH) as f:
    SNAPSHOT = json.load(f)


def test_api_exports_match_snapshot():
    assert sorted(api.__all__) == SNAPSHOT["api_exports"]
    # everything advertised is actually importable
    for name in api.__all__:
        assert hasattr(api, name), name


def test_core_exports_match_snapshot():
    assert sorted(core.__all__) == SNAPSHOT["core_exports"]


def test_compile_options_field_set_matches_snapshot():
    """Field ORDER matters too: it is the positional-construction contract
    and the readability grouping documented in DESIGN.md §11.1."""
    fields = [f.name for f in dataclasses.fields(CompileOptions)]
    assert fields == SNAPSHOT["compile_options_fields"]


def test_profiles_match_snapshot():
    assert sorted(PROFILES) == SNAPSHOT["profiles"]


def test_result_row_schema_matches_snapshot():
    row = CompileResult(name="x", ok=False).as_dict()
    assert sorted(row) == SNAPSHOT["result_row_keys"]
    assert sorted(row["phases"]) == SNAPSHOT["result_phase_keys"]
    assert sorted(row["trace"]) == SNAPSHOT["result_trace_keys"]


def test_top_level_lazy_exports():
    """``repro`` lazily re-exports the api surface (no heavy imports on
    plain ``import repro``)."""
    import repro

    assert repro.Compiler is api.Compiler
    assert repro.CompileOptions is CompileOptions
    assert repro.resolve_options is api.resolve_options

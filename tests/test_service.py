"""Compilation-service tests (DESIGN.md §8–§9): persistent mapping cache
(hit/miss, cross-process reuse, version-bump invalidation, corruption
tolerance), the process-pool batch compiler, window striping/racing, and the
``python -m repro.compile`` CLI."""

import glob
import json
import os
import threading
import time

import pytest

from repro.core import CGRA, map_dfg, running_example
from repro.core.dfg import DFG
from repro.core.benchsuite import load_suite
from repro.core.mapper import clear_mapping_cache, ii_slack_windows
from repro.core.service import (
    CACHE_VERSION,
    CompileJob,
    DiskMappingCache,
    compile_many,
    map_dfg_racing,
    resolve_cache_dir,
)


@pytest.fixture(autouse=True)
def _fresh_memory_cache():
    clear_mapping_cache()
    yield
    clear_mapping_cache()


def _small_jobs(cgra=None, names=("bitcount", "fft")):
    cgra = cgra or CGRA(4, 4)
    suite = load_suite(names=list(names))
    return [CompileJob(dfg, cgra) for dfg in suite.values()]


# ------------------------------------------------------------- disk cache

def test_disk_cache_miss_then_hit(tmp_path):
    dfg, cgra = running_example(), CGRA(2, 2)
    cold = map_dfg(dfg, cgra, cache_dir=str(tmp_path), time_budget_s=30)
    assert cold.ok and not cold.stats.disk_cache_hit
    assert len(DiskMappingCache(str(tmp_path))) == 1

    clear_mapping_cache()       # force the lookup past the in-memory layer
    warm = map_dfg(dfg, cgra, cache_dir=str(tmp_path), time_budget_s=30)
    assert warm.ok and warm.stats.disk_cache_hit
    assert warm.stats.backend == "disk-cache"
    assert warm.mapping.ii == cold.mapping.ii
    assert warm.mapping.validate() == []

    # the disk hit was promoted into memory: next lookup never touches disk
    hot = map_dfg(dfg, cgra, cache_dir=str(tmp_path), time_budget_s=30)
    assert hot.stats.cache_hit and not hot.stats.disk_cache_hit


def test_disk_cache_key_separates_targets(tmp_path):
    dfg = running_example()
    a = map_dfg(dfg, CGRA(2, 2), cache_dir=str(tmp_path), time_budget_s=30)
    assert a.ok
    clear_mapping_cache()
    # same DFG, different grid: must miss (and solve) rather than reuse
    b = map_dfg(dfg, CGRA(3, 3), cache_dir=str(tmp_path), time_budget_s=30)
    assert b.ok and not b.stats.disk_cache_hit and not b.stats.cache_hit


def test_disk_cache_version_bump_invalidates(tmp_path, monkeypatch):
    dfg, cgra = running_example(), CGRA(2, 2)
    assert map_dfg(dfg, cgra, cache_dir=str(tmp_path), time_budget_s=30).ok
    clear_mapping_cache()

    import repro.core.service.cache as cache_mod

    monkeypatch.setattr(cache_mod, "CACHE_VERSION", CACHE_VERSION + 1)
    bumped = map_dfg(dfg, cgra, cache_dir=str(tmp_path), time_budget_s=30)
    assert bumped.ok and not bumped.stats.disk_cache_hit   # orphaned entry

    # prune() under the new version reclaims the orphaned entry
    store = DiskMappingCache(str(tmp_path))
    assert store.prune() == 1
    assert len(store) == 1      # the re-solved entry written under v+1


def test_disk_cache_tolerates_corrupt_and_truncated_files(tmp_path):
    dfg, cgra = running_example(), CGRA(2, 2)
    assert map_dfg(dfg, cgra, cache_dir=str(tmp_path), time_budget_s=30).ok
    (entry,) = glob.glob(str(tmp_path / "*" / "*.json"))

    for garbage in ["", '{"version": 1, "tru', '{"version": 1}', "[]"]:
        with open(entry, "w") as f:
            f.write(garbage)
        clear_mapping_cache()
        res = map_dfg(dfg, cgra, cache_dir=str(tmp_path), time_budget_s=30)
        assert res.ok and not res.stats.disk_cache_hit   # corrupt => miss
        # the bad file was dropped and replaced by the fresh solve's entry
        clear_mapping_cache()
        hit = map_dfg(dfg, cgra, cache_dir=str(tmp_path), time_budget_s=30)
        assert hit.ok and hit.stats.disk_cache_hit


def test_disk_cache_drops_semantically_invalid_entry(tmp_path):
    """A schema-valid entry whose mapping fails validation is deleted, not
    re-read (and re-rejected) on every cold lookup forever."""
    dfg, cgra = running_example(), CGRA(2, 2)
    assert map_dfg(dfg, cgra, cache_dir=str(tmp_path), time_budget_s=30).ok
    (entry,) = glob.glob(str(tmp_path / "*" / "*.json"))
    payload = json.load(open(entry))
    payload["placement"] = [0] * len(payload["placement"])   # breaks mono1
    with open(entry, "w") as f:
        json.dump(payload, f)

    clear_mapping_cache()
    res = map_dfg(dfg, cgra, cache_dir=str(tmp_path), time_budget_s=30)
    assert res.ok and not res.stats.disk_cache_hit
    assert res.mapping.validate() == []
    # the poisoned entry was dropped and the path now holds the fresh solve
    # (same content address), which serves the next cold lookup
    assert json.load(open(entry))["placement"] != payload["placement"]
    clear_mapping_cache()
    again = map_dfg(dfg, cgra, cache_dir=str(tmp_path), time_budget_s=30)
    assert again.ok and again.stats.disk_cache_hit


def test_disk_cache_stats_counters(tmp_path):
    store = DiskMappingCache(str(tmp_path))
    key = store.entry_key("abc", 2, 2, "mesh", "strict", None)
    assert store.get(key, 1, 3) is None
    assert store.stats.misses == 1
    store.put(key, 2, [0, 1], [0, 1])
    assert store.stats.writes == 1
    assert store.get(key, 1, 3) == (2, [0, 1], [0, 1], [])
    assert store.stats.hits == 1


def test_resolve_cache_dir_precedence(monkeypatch):
    monkeypatch.delenv("REPRO_CACHE_DIR", raising=False)
    assert resolve_cache_dir(None) is None
    assert resolve_cache_dir("/x") == "/x"
    monkeypatch.setenv("REPRO_CACHE_DIR", "/env")
    assert resolve_cache_dir(None) == "/env"
    assert resolve_cache_dir("/x") == "/x"
    assert resolve_cache_dir("") is None        # explicit disable
    monkeypatch.setenv("REPRO_CACHE_DIR", "")
    assert resolve_cache_dir(None) is None


def test_deterministic_mode_bypasses_disk_cache(tmp_path):
    dfg, cgra = running_example(), CGRA(2, 2)
    assert map_dfg(dfg, cgra, cache_dir=str(tmp_path), time_budget_s=30).ok
    clear_mapping_cache()
    det = map_dfg(dfg, cgra, cache_dir=str(tmp_path), deterministic=True)
    assert det.ok and not det.stats.disk_cache_hit and not det.stats.cache_hit


# ----------------------------------------------------------- batch compiler

def test_compile_many_sequential_matches_map_dfg():
    batch = _small_jobs()
    report = compile_many(batch, jobs=1, deterministic=True)
    assert report.ok and report.num_workers == 1
    for job, rep in zip(batch, report.jobs):
        direct = map_dfg(job.dfg, job.cgra, deterministic=True)
        assert rep.ii == direct.mapping.ii
        assert rep.m_ii == direct.stats.m_ii


def test_compile_many_deterministic_smoke_is_reproducible():
    batch = _small_jobs(names=("bitcount", "fft", "gsm"))
    a = compile_many(batch, jobs=1, deterministic=True)
    b = compile_many(batch, jobs=1, deterministic=True)
    assert [j.ii for j in a.jobs] == [j.ii for j in b.jobs]
    assert all(not j.cache_hit and not j.disk_cache_hit for j in b.jobs)


def test_compile_many_process_pool_and_cross_process_cache(tmp_path):
    batch = _small_jobs(names=("bitcount", "fft", "gsm"))
    cold = compile_many(batch, jobs=2, cache_dir=str(tmp_path), deadline_s=30)
    assert cold.ok
    assert cold.cache_counters["solved"] == 3
    # entries were written by *worker* processes; this process and a fresh
    # pool both read them back — cross-process reuse in both directions
    clear_mapping_cache()
    warm = compile_many(batch, jobs=2, cache_dir=str(tmp_path), deadline_s=30)
    assert warm.ok
    assert warm.cache_counters["solved"] == 0
    assert warm.cache_counters["disk_hits"] == 3
    assert [j.ii for j in warm.jobs] == [j.ii for j in cold.jobs]
    # (no wall-clock comparison: these few-ms solves are dominated by pool
    # startup; the counters above are the semantic assertion)


def test_compile_many_reports_failures_without_raising():
    # 1x1 grid cannot hold a 2-node same-step structure: jobs must fail
    # gracefully with ok=False rows, not exceptions
    suite = load_suite(names=["bitcount"])
    batch = [CompileJob(suite["bitcount"], CGRA(1, 1),
                        options={"max_ii": 4})]
    report = compile_many(batch, jobs=1, deadline_s=5)
    assert not report.ok
    assert report.jobs[0].reason
    assert report.cache_counters["failed"] == 1


def test_compile_many_cancellation():
    cancel = threading.Event()
    cancel.set()        # cancelled before anything starts
    batch = _small_jobs(names=("bitcount", "fft"))
    report = compile_many(batch, jobs=1, cancel=cancel)
    assert not report.ok
    assert all(j.cancelled for j in report.jobs)


def test_compile_many_per_job_options_override():
    suite = load_suite(names=["bitcount"])
    job = CompileJob(suite["bitcount"], CGRA(4, 4),
                     options={"deterministic": True})
    report = compile_many([job], jobs=1, deadline_s=30)
    assert report.ok and report.jobs[0].backend == "cp-inc"


# ------------------------------------------------------ striping and racing

def test_window_striping_partitions_the_sweep():
    dfg, cgra = running_example(), CGRA(2, 2)
    full = map_dfg(dfg, cgra, deterministic=True)
    assert full.ok
    # the union of striped sweeps covers every window exactly once
    stride = 3
    results = [
        map_dfg(dfg, cgra, deterministic=True, window_offset=off,
                window_stride=stride)
        for off in range(stride)
    ]
    best = min((r.mapping.ii for r in results if r.ok), default=None)
    assert best == full.mapping.ii      # some stripe holds the best window
    windows = list(ii_slack_windows(4, 8, 3))
    striped = [w for off in range(stride) for i, w in enumerate(windows)
               if i % stride == off]
    assert sorted(striped) == sorted(windows)


def test_window_striping_validation():
    with pytest.raises(ValueError):
        map_dfg(running_example(), CGRA(2, 2), window_stride=0)
    with pytest.raises(ValueError):
        map_dfg(running_example(), CGRA(2, 2), window_offset=2,
                window_stride=2)


def test_should_stop_finishes_early():
    calls = {"n": 0}

    def stop():
        calls["n"] += 1
        return calls["n"] > 3       # let the search open, then cancel

    res = map_dfg(load_suite(names=["aes"])["aes"], CGRA(5, 5),
                  should_stop=stop, use_cache=False, time_budget_s=60)
    # cancelled long before the 60s budget; best-so-far (or clean failure)
    assert res.stats.total_s < 30


def test_map_dfg_racing_smoke():
    suite = load_suite(names=["fft"])
    res = map_dfg_racing(suite["fft"], CGRA(4, 4), workers=2,
                         use_cache=False, time_budget_s=30)
    assert res.ok
    assert res.mapping.validate() == []
    direct = map_dfg(suite["fft"], CGRA(4, 4), use_cache=False,
                     time_budget_s=30)
    assert res.mapping.ii == direct.mapping.ii


def test_map_dfg_racing_falls_back_when_deterministic():
    res = map_dfg_racing(running_example(), CGRA(2, 2), workers=4,
                         deterministic=True)
    assert res.ok and res.mapping.ii == 4


@pytest.mark.skipif((os.cpu_count() or 1) < 4,
                    reason="speedup assertion needs >=4 cores")
def test_compile_many_parallel_speedup(tmp_path):
    names = ["aes", "backprop", "crc32", "particlefilter", "sha2", "susan"]
    cgra = CGRA(5, 5)
    suite = load_suite(names=names)
    batch = [CompileJob(d, cgra) for d in suite.values()]
    seq = compile_many(batch, jobs=1, use_cache=False, deadline_s=30)
    clear_mapping_cache()
    par = compile_many(batch, jobs=4, use_cache=False, deadline_s=30)
    assert par.ok and seq.ok
    assert [j.ii for j in par.jobs] == [j.ii for j in seq.jobs]
    assert par.wall_s <= 0.5 * seq.wall_s + 1.0


# -------------------------------------------------------------------- CLI

def test_cli_report_and_cache_counters(tmp_path):
    from repro.compile import main

    report_path = tmp_path / "report.json"
    cache_dir = tmp_path / "cache"
    argv = ["--bench", "bitcount", "--bench", "fft", "--size", "4",
            "--jobs", "1", "--cache-dir", str(cache_dir),
            "--report", str(report_path), "--quiet"]
    assert main(argv) == 0
    cold = json.loads(report_path.read_text())
    assert cold["ok"] and cold["cache"]["solved"] == 2

    clear_mapping_cache()
    assert main(argv) == 0
    warm = json.loads(report_path.read_text())
    assert warm["ok"]
    assert warm["cache"]["solved"] == 0
    assert warm["cache"]["disk_hits"] == 2
    assert [j["ii"] for j in warm["jobs"]] == [j["ii"] for j in cold["jobs"]]


def test_cli_requires_a_workload(capsys):
    from repro.compile import main

    assert main([]) == 2


def test_cli_deterministic_exit_codes(tmp_path):
    from repro.compile import main

    assert main(["--bench", "bitcount", "--size", "4", "--jobs", "1",
                 "--deterministic", "--quiet"]) == 0


# ------------------------------------------------- disk-cache prune bounds

def _filled_store(tmp_path, n=3):
    """A store with n entries whose mtimes ascend entry-0 .. entry-(n-1)."""
    store = DiskMappingCache(str(tmp_path))
    keys = [store.entry_key(f"dfg{i}", 2, 2, "mesh", "strict", None)
            for i in range(n)]
    now = time.time()
    for i, key in enumerate(keys):
        store.put(key, 2, [0, 1], [0, 1])
        # explicit mtimes make LRU order deterministic (oldest = entry 0)
        os.utime(store._path(key, 2), (now - 1000 + i, now - 1000 + i))
    return store, keys


def test_disk_cache_prune_lru_byte_budget(tmp_path):
    store, keys = _filled_store(tmp_path)
    entry_size = os.path.getsize(store._path(keys[0], 2))
    # budget for exactly one entry: the two oldest must go, newest survives
    removed = store.prune(max_bytes=entry_size)
    assert removed == 2
    assert store.stats.evictions == 2
    assert len(store) == 1
    assert store.get(keys[2], 2, 2) is not None     # newest kept
    assert store.get(keys[0], 2, 2) is None         # oldest evicted


def test_disk_cache_prune_age_bound(tmp_path):
    store, keys = _filled_store(tmp_path)
    fresh = store.entry_key("fresh", 2, 2, "mesh", "strict", None)
    store.put(fresh, 2, [0, 1], [0, 1])             # mtime = now
    removed = store.prune(max_age_s=500)            # backdated trio expires
    assert removed == 3 and store.stats.evictions == 3
    assert len(store) == 1
    assert store.get(fresh, 2, 2) is not None


def test_disk_cache_prune_stale_versions_not_counted_as_evictions(tmp_path):
    store, keys = _filled_store(tmp_path)
    path = store._path(keys[0], 2)
    payload = json.load(open(path))
    payload["version"] = CACHE_VERSION - 1
    json.dump(payload, open(path, "w"))
    removed = store.prune()
    assert removed == 1
    assert store.stats.evictions == 0   # stale removal is GC, not eviction
    assert len(store) == 2


def test_disk_cache_prune_unbounded_keeps_current_entries(tmp_path):
    store, _keys = _filled_store(tmp_path)
    assert store.prune() == 0
    assert len(store) == 3


# ------------------------------------------------------ worker-loss recovery

class KillerDFG(DFG):
    """A DFG whose ``stable_hash`` kills the worker process mid-job.

    Top-level (fork-picklable) on purpose: pool workers call ``stable_hash``
    while building the mapping-cache key, i.e. genuinely mid-solve. With a
    ``sentinel`` path the kill is one-shot — the first call records the
    sentinel and dies, later calls (the respawned pool) behave normally;
    without one it kills every pool that touches it. ``delay_s`` lets
    innocent neighbors finish first so the test's expectations are exact."""

    def __init__(self, base, sentinel=None, delay_s=0.0):
        super().__init__(num_nodes=base.num_nodes, edges=list(base.edges),
                         ops=list(base.ops), imms=list(base.imms),
                         name="killer")
        self.sentinel = sentinel
        self.delay_s = delay_s

    def stable_hash(self):
        if self.sentinel and os.path.exists(self.sentinel):
            return super().stable_hash()
        if self.sentinel:
            open(self.sentinel, "w").close()
        time.sleep(self.delay_s)
        os._exit(1)


def test_compile_many_respawns_pool_after_worker_death(tmp_path):
    # a worker dying mid-solve breaks the whole pool; the batch must respawn
    # it once and finish every job — including the one that killed it
    suite = load_suite(names=["bitcount", "fft"])
    killer = KillerDFG(running_example(),
                       sentinel=str(tmp_path / "sentinel"), delay_s=0.3)
    batch = [CompileJob(suite["bitcount"], CGRA(4, 4)),
             CompileJob(suite["fft"], CGRA(4, 4)),
             CompileJob(killer, CGRA(4, 4))]
    report = compile_many(batch, jobs=2, deadline_s=30)
    assert report.ok, [j.reason for j in report.jobs]
    assert [j.name for j in report.jobs] == ["bitcount", "fft", "killer"]
    assert all(j.ii is not None for j in report.jobs)


def test_compile_many_worker_lost_after_respawn_fails_job_not_batch(tmp_path):
    # a persistent killer breaks the respawned pool too: its row must come
    # back failure="worker-lost" while innocent neighbors still succeed
    from repro.api.result import classify_failure

    suite = load_suite(names=["bitcount", "fft"])
    killer = KillerDFG(running_example(), sentinel=None, delay_s=0.5)
    batch = [CompileJob(suite["bitcount"], CGRA(4, 4)),
             CompileJob(suite["fft"], CGRA(4, 4)),
             CompileJob(killer, CGRA(4, 4))]
    report = compile_many(batch, jobs=2, deadline_s=30)
    assert not report.ok
    rows = {j.name: j for j in report.jobs}
    assert rows["bitcount"].ok and rows["fft"].ok
    lost = rows["killer"]
    assert not lost.ok
    assert lost.reason.startswith("worker lost")
    assert classify_failure(lost.ok, lost.reason, lost.cancelled) == "worker-lost"

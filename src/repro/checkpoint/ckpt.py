"""Sharding-aware, async, versioned checkpointing (no external deps).

Layout:  <dir>/step_<n>/arrays.npz + manifest.json, written to a temp dir and
atomically renamed, so a crash mid-write never corrupts the latest step.
``save_async`` snapshots to host memory synchronously (cheap) and writes on a
background thread — the train loop keeps stepping. Restore re-places every
array with the caller's shardings (which may target a *different* mesh than
the one that saved it — this is what makes elastic re-scaling work; see
runtime/elastic.py).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}

    def visit(path, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)

    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> str:
    """Synchronous atomic save; returns the final directory."""
    flat = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **{k.replace("/", "|"): v for k, v in flat.items()})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(
            {
                "step": step,
                "keys": sorted(flat),
                "shapes": {k: list(v.shape) for k, v in flat.items()},
                "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            },
            f,
        )
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write on a daemon thread."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()
        host = _flatten(tree)  # device->host copy happens here, synchronously

        def work():
            try:
                final = os.path.join(self.ckpt_dir, f"step_{step:08d}")
                tmp = final + ".tmp"
                os.makedirs(tmp, exist_ok=True)
                np.savez(
                    os.path.join(tmp, "arrays.npz"),
                    **{k.replace("/", "|"): v for k, v in host.items()},
                )
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump({"step": step, "keys": sorted(host)}, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                _gc(self.ckpt_dir, self.keep)
            except Exception as e:  # pragma: no cover
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error:
            raise self.last_error


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any | None = None) -> Any:
    """Restore into the structure of `like`, placing with `shardings`."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
    data = np.load(path)
    flat = {k.replace("|", "/"): data[k] for k in data.files}

    def pick(kp, leaf, sh=None):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        arr = flat[key]
        if sh is not None:
            return jax.device_put(arr.astype(leaf.dtype), sh)
        return jax.numpy.asarray(arr.astype(leaf.dtype))

    if shardings is None:
        return jax.tree_util.tree_map_with_path(pick, like)
    return jax.tree_util.tree_map_with_path(pick, like, shardings)


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir) if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)

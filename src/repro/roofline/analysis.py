"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs   / (chips x peak_FLOPs)
    memory     = HLO_bytes   / (chips x HBM_bw)
    collective = sum over collective ops of bytes / (chips x link_bw)

HLO_FLOPs / bytes come from compiled.cost_analysis(). Collective bytes are
NOT in cost_analysis: we parse the optimized HLO text and sum operand sizes
of all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops. Hardware constants are TPU v5e-class: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (set in HW).
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12       # bf16 / chip
    hbm_bw: float = 819e9            # bytes/s / chip
    ici_bw: float = 50e9             # bytes/s / link


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(type_str: str) -> int:
    """bytes of 'bf16[1024,512]' — tuple types handled by the caller."""
    m = _SHAPE_RE.match(type_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


@dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, int] = field(default_factory=dict)
    count_by_kind: dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


_WIRE_FACTOR = {
    # ring algorithms: wire bytes per device relative to the tensor size
    "all-reduce": 2.0,        # reduce-scatter + all-gather phases
    "all-gather": 1.0,        # (n-1)/n ~= 1
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum wire bytes of every collective op in optimized (per-device) HLO.

    Output-shape bytes x ring wire factor; all-reduce counts 2x (RS+AG
    phases). `-start` variants are matched once (the `-done` op has no shape
    payload of its own in the same form).
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        # e.g.:  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups=...
        m = re.match(r"%?[\w.\-]+\s*=\s*(\(?[^=]*?)\s*([\w\-]+)\(", s)
        if not m:
            continue
        type_part, op = m.groups()
        if op.endswith("-done"):
            continue
        kind = next((k for k in _COLLECTIVE_KINDS if op.startswith(k)), None)
        if kind is None:
            continue
        total = 0
        for piece in re.findall(r"(\w+\[[\d,]*\])", type_part):
            total += _shape_bytes(piece)
        total = int(total * _WIRE_FACTOR[kind])
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + total
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    chips: int
    hw: HW
    collectives: CollectiveStats | None = None
    per_device_hbm_peak: float | None = None

    @property
    def t_compute(self) -> float:
        # cost_analysis() reports the per-device partitioned module
        # (verified experimentally, see EXPERIMENTS.md §Dry-run): divide by a
        # single chip's peak.
        return self.flops / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        # per-device wire bytes (already ring-factor adjusted) over one
        # chip's ICI link bandwidth — conservative single-link serialisation
        return self.collective_bytes / self.hw.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def mfu_upper_bound(self, model_flops: float) -> float:
        """Fraction of peak the *useful* model FLOPs could reach if the run
        takes exactly the dominant roofline term."""
        if self.bound_time == 0:
            return 0.0
        return model_flops / (self.chips * self.hw.peak_flops * self.bound_time)


def analyze_compiled(compiled, chips: int, hw: HW = HW()) -> Roofline:
    """Roofline from a jax Compiled object (dry-run artifact)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    text = compiled.as_text()
    coll = parse_collectives(text)
    mem = compiled.memory_analysis()
    peak = getattr(mem, "peak_memory_in_bytes", None)
    if peak is None:
        peak = getattr(mem, "temp_size_in_bytes", 0) + getattr(
            mem, "argument_size_in_bytes", 0
        ) + getattr(mem, "output_size_in_bytes", 0)
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=float(coll.total_bytes),
        chips=chips,
        hw=hw,
        collectives=coll,
        per_device_hbm_peak=float(peak) if peak is not None else None,
    )


def model_flops_train(cfg, shape) -> float:
    """6·N·D (dense) or 6·N_active·D (MoE), D = tokens processed."""
    n_active = active_param_count(cfg)
    tokens = shape.global_batch * shape.seq_len
    return 6.0 * n_active * tokens


def model_flops_decode(cfg, shape) -> float:
    """Decode processes global_batch tokens (one step)."""
    return 6.0 * active_param_count(cfg) * shape.global_batch


def active_param_count(cfg) -> int:
    """Active (per-token) parameter count from the architecture config."""
    d, v, L = cfg.d_model, cfg.vocab, cfg.num_layers
    hd = cfg.resolved_head_dim
    total = 2 * v * d if not cfg.tie_embeddings else v * d
    n_dense = cfg.num_dense_layers if cfg.moe else L
    n_moe = L - n_dense if cfg.moe else 0

    if cfg.mla is not None:
        m = cfg.mla
        attn = (
            d * m.q_lora + m.q_lora * cfg.num_heads * (m.qk_nope_dim + m.rope_dim)
            + d * (m.kv_lora + m.rope_dim)
            + m.kv_lora * cfg.num_heads * (m.qk_nope_dim + m.v_dim)
            + cfg.num_heads * m.v_dim * d
        )
    else:
        attn = d * cfg.num_heads * hd + 2 * d * cfg.num_kv_heads * hd + cfg.num_heads * hd * d

    def mlp_params(ff, gated=True):
        return (3 if gated else 2) * d * ff

    dense_mlp = mlp_params(cfg.d_ff, cfg.mlp_kind != "gelu") if cfg.d_ff else 0
    total += n_dense * (attn + dense_mlp)
    if cfg.moe:
        active_experts = cfg.moe.top_k + cfg.moe.num_shared
        total += n_moe * (attn + active_experts * mlp_params(cfg.moe_d_ff))
    if cfg.ssm is not None or cfg.family in ("ssm", "hybrid"):
        total += L * 4 * d * d  # mixer projections (approximate)
    return int(total)

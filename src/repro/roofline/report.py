"""Render the dry-run sweep (results/dryrun/*.json) into the EXPERIMENTS.md
§Dry-run / §Roofline tables.

    PYTHONPATH=src python -m repro.roofline.report results/dryrun
"""

from __future__ import annotations

import json
import os
import sys


def load_results(results_dir: str) -> list[dict]:
    out = []
    for f in sorted(os.listdir(results_dir)):
        if f.endswith(".json"):
            with open(os.path.join(results_dir, f)) as fh:
                out.append(json.load(fh))
    return out


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def _gb(x: float) -> str:
    return f"{x/2**30:.2f}"


def roofline_table(rows: list[dict], mesh: str) -> str:
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | bottleneck "
        "| useful/HLO | MFU bound | args GB/dev | temps GB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if not r.get("ok") or r["mesh"] != mesh:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['t_compute'])} "
            f"| {_fmt_s(r['t_memory'])} | {_fmt_s(r['t_collective'])} "
            f"| **{r['bottleneck']}** | {r['useful_flops_ratio']*100:.0f}% "
            f"| {r['mfu_upper_bound']*100:.2f}% | {_gb(r['arg_bytes_per_dev'])} "
            f"| {_gb(r['temp_bytes_per_dev'])} |"
        )
    return "\n".join(lines)


def collective_detail(rows: list[dict], mesh: str) -> str:
    lines = [
        "| arch | shape | AR GB | AG GB | RS GB | A2A GB | permute GB |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if not r.get("ok") or r["mesh"] != mesh:
            continue
        c = r.get("collectives", {})
        g = lambda k: f"{c.get(k, 0)/2**30:.2f}"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {g('all-reduce')} | {g('all-gather')} "
            f"| {g('reduce-scatter')} | {g('all-to-all')} | {g('collective-permute')} |"
        )
    return "\n".join(lines)


def summary(rows: list[dict]) -> str:
    ok = [r for r in rows if r.get("ok")]
    fail = [r for r in rows if not r.get("ok")]
    per_b = {}
    for r in ok:
        per_b[r["bottleneck"]] = per_b.get(r["bottleneck"], 0) + 1
    worst = sorted(ok, key=lambda r: r["mfu_upper_bound"])[:3]
    coll = sorted(ok, key=lambda r: -r["t_collective"])[:3]
    lines = [
        f"- cells compiled OK: {len(ok)}; failed: {len(fail)}",
        f"- bottleneck distribution: {per_b}",
        "- lowest MFU-upper-bound cells: "
        + ", ".join(f"{r['arch']}/{r['shape']}/{r['mesh']} ({r['mfu_upper_bound']*100:.2f}%)" for r in worst),
        "- most collective-bound cells: "
        + ", ".join(f"{r['arch']}/{r['shape']}/{r['mesh']} ({_fmt_s(r['t_collective'])})" for r in coll),
    ]
    return "\n".join(lines)


def main() -> None:
    results_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    rows = load_results(results_dir)
    print("## Summary\n")
    print(summary(rows))
    for mesh in ("16x16", "2x16x16"):
        print(f"\n## Roofline — {mesh} mesh\n")
        print(roofline_table(rows, mesh))
        print(f"\n### Collective detail — {mesh}\n")
        print(collective_detail(rows, mesh))


if __name__ == "__main__":
    main()

from .pipeline import MemmapLM, SyntheticLM

__all__ = ["MemmapLM", "SyntheticLM"]

"""Sharded input pipelines.

Two sources behind one interface:
  * SyntheticLM — deterministic stateless token stream (seed, step) -> batch;
    restart-safe by construction (resuming at step k regenerates batch k), so
    checkpoint/restart needs no data-state snapshotting.
  * MemmapLM — file-backed token corpus (np.memmap), strided per step, for
    the train examples.

Batches are placed with jax.device_put + NamedSharding (batch dim over the
data axes), so each host/device only materialises its slice in real
deployments; frontends (audio/vision stubs) get synthetic embeddings.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import numpy as np

from repro.models.api import ArchConfig


@dataclass
class SyntheticLM:
    cfg: ArchConfig
    batch: int
    seq: int
    seed: int = 0

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng((self.seed, step))

    def host_batch(self, step: int) -> dict[str, np.ndarray]:
        rng = self._rng(step)
        # structured stream: Zipfian unigram + local repetition, so the loss
        # curve has learnable signal (not pure noise)
        z = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        toks = (z % (self.cfg.vocab - 2)).astype(np.int32) + 1
        rep = rng.random((self.batch, self.seq + 1)) < 0.3
        toks[:, 1:] = np.where(rep[:, 1:], toks[:, :-1], toks[:, 1:])
        out = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].astype(np.int32),
        }
        if self.cfg.frontend == "audio":
            out["frames"] = rng.standard_normal(
                (self.batch, self.cfg.frontend_len, self.cfg.d_model), np.float32
            ) * 0.1
        elif self.cfg.frontend == "vision":
            out["prefix_embeds"] = rng.standard_normal(
                (self.batch, self.cfg.frontend_len, self.cfg.d_model), np.float32
            ) * 0.1
        return out

    def batch_at(self, step: int, shardings: Any | None = None):
        host = self.host_batch(step)
        if shardings is None:
            return {k: jax.numpy.asarray(v) for k, v in host.items()}
        return {
            k: jax.device_put(v, shardings[k] if isinstance(shardings, dict) else shardings)
            for k, v in host.items()
        }


@dataclass
class MemmapLM:
    """Token file pipeline: flat int32 tokens, strided deterministic batches."""

    path: str
    cfg: ArchConfig
    batch: int
    seq: int

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=np.int32, mode="r")
        self._tokens_per_step = self.batch * (self.seq + 1)

    @property
    def num_steps(self) -> int:
        return len(self._data) // self._tokens_per_step

    def host_batch(self, step: int) -> dict[str, np.ndarray]:
        off = (step % self.num_steps) * self._tokens_per_step
        chunk = np.asarray(self._data[off : off + self._tokens_per_step])
        chunk = chunk.reshape(self.batch, self.seq + 1) % self.cfg.vocab
        return {"tokens": chunk[:, :-1], "labels": chunk[:, 1:].astype(np.int32)}

    def batch_at(self, step: int, shardings: Any | None = None):
        host = self.host_batch(step)
        if shardings is None:
            return {k: jax.numpy.asarray(v) for k, v in host.items()}
        return {k: jax.device_put(v, shardings[k]) for k, v in host.items()}

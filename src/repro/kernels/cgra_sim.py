"""Pallas TPU kernel: batched functional execution of a mapped CGRA program.

Executes the steady-state modulo schedule produced by the paper's mapper on a
PE grid, vectorised over a batch of independent loop instances (the common
CGRA deployment: the same accelerated loop applied to many data streams).

Hardware adaptation (CGRA -> TPU), per DESIGN.md §3:

  * the PE grid's crossbar/neighbour reads become **one-hot routing matmuls**
    on the MXU: operand_a = route_a[k] @ ring_state — a gather expressed as a
    dense matmul, the TPU-idiomatic form;
  * the per-PE ALU opcode select becomes a **one-hot blend** on the VPU:
    val = Σ_op sel[:, op] * op(a, b) — no data-dependent control flow;
  * PE register files become a **ring buffer in VMEM scratch**, rolled one
    slot per cycle so operand addresses are static per kernel step;
  * the cycle loop is the sequential grid dimension; the batch is tiled to
    128-lane blocks.

VMEM working set: ring·pes·Bt (state) + 2·II·pes·ring·pes (routes) floats;
callers size pes/ring accordingly (ops.py validates). The kernel is exact in
f32: all ALU ops (incl. 16-bit-masked bitwise) produce f32-representable
values, so assert-equal against the scalar oracle is legitimate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Fixed opcode ordering shared with core.simulate.OPCODES (asserted in ops.py).
KERNEL_OPS = (
    "input", "const", "load", "store", "add", "sub", "mul", "div",
    "and", "or", "xor", "shl", "shr", "min", "max", "neg", "not",
    "abs", "mov", "phi", "cmp",
)
NOPS = len(KERNEL_OPS)


def _alu_all(a: jax.Array, b: jax.Array, imm: jax.Array, inj: jax.Array) -> jax.Array:
    """All candidate op results, stacked [NOPS, pes, bt] (f32-exact)."""
    ia = jnp.abs(a).astype(jnp.int32) & 0xFFFF
    ib = jnp.abs(b).astype(jnp.int32) & 0xFFFF
    sh = ib % 8
    f = jnp.float32
    outs = [
        inj,                                        # input
        jnp.broadcast_to(imm, a.shape),             # const
        a,                                          # load
        a,                                          # store
        a + b,                                      # add
        a - b,                                      # sub
        a * b,                                      # mul
        jnp.where(b != 0, a / jnp.where(b != 0, b, 1.0), 0.0),  # div (safe)
        (ia & ib).astype(f),                        # and
        (ia | ib).astype(f),                        # or
        (ia ^ ib).astype(f),                        # xor
        ((ia << sh) & 0xFFFF).astype(f),            # shl
        (ia >> sh).astype(f),                       # shr
        jnp.minimum(a, b),                          # min
        jnp.maximum(a, b),                          # max
        -a,                                         # neg
        (~ia & 0xFFFF).astype(f),                   # not
        jnp.abs(a),                                 # abs
        a,                                          # mov
        a + b,                                      # phi (carried accumulate)
        (a > b).astype(f),                          # cmp
    ]
    return jnp.stack(outs)


def _cgra_sim_kernel(
    # inputs (blocked)
    route_a_ref,   # [1, pes, ring*pes]   routing one-hot for step k=c%II (op a)
    route_b_ref,   # [1, pes, ring*pes]
    op_sel_ref,    # [1, pes, NOPS]       opcode one-hot for step k
    imm_ref,       # [1, pes]             immediates for step k
    inj_ref,       # [1, pes, bt]         input-node injections for cycle c
    active_ref,    # [1, pes]             1.0 where a node fires at cycle c
    # outputs
    trace_ref,     # [1, pes, bt]         value produced at (c, pe)
    # scratch
    ring_ref,      # [ring, pes, bt]      register-file ring buffer
):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        ring_ref[...] = jnp.zeros_like(ring_ref)

    ring, pes, bt = ring_ref.shape
    state = ring_ref[...].reshape(ring * pes, bt)

    # crossbar: one-hot routing matmuls (MXU)
    a = jnp.dot(route_a_ref[0], state, preferred_element_type=jnp.float32)
    b = jnp.dot(route_b_ref[0], state, preferred_element_type=jnp.float32)

    imm = imm_ref[0][:, None]
    inj = inj_ref[0]
    candidates = _alu_all(a, b, imm, inj)          # [NOPS, pes, bt]
    sel = op_sel_ref[0]                            # [pes, NOPS]
    val = jnp.einsum("opb,po->pb", candidates, sel)
    val = val * active_ref[0][:, None]

    # roll the register ring by one cycle; newest value enters slot 0
    if ring > 1:  # static: ring==1 means every operand is consumed next cycle
        shifted = ring_ref[: ring - 1]
        ring_ref[1:] = shifted
    ring_ref[0] = val
    trace_ref[0] = val


@functools.partial(
    jax.jit,
    static_argnames=("ii", "ring", "num_cycles", "batch_tile", "interpret"),
)
def cgra_sim_pallas(
    route_a: jax.Array,   # [II, pes, ring*pes] f32 one-hot
    route_b: jax.Array,
    op_sel: jax.Array,    # [II, pes, NOPS] f32 one-hot
    imm: jax.Array,       # [II, pes] f32
    inj: jax.Array,       # [C, pes, B] f32
    active: jax.Array,    # [C, pes] f32
    *,
    ii: int,
    ring: int,
    num_cycles: int,
    batch_tile: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Run the program; returns the full trace [C, pes, B]."""
    pes = route_a.shape[1]
    batch = inj.shape[2]
    bt = min(batch_tile, batch)
    if batch % bt:
        raise ValueError(f"batch {batch} not divisible by tile {bt}")
    nb = batch // bt

    grid = (nb, num_cycles)  # batch tiles outer, cycles inner (sequential)
    return pl.pallas_call(
        _cgra_sim_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, pes, ring * pes), lambda b, c: (c % ii, 0, 0)),
            pl.BlockSpec((1, pes, ring * pes), lambda b, c: (c % ii, 0, 0)),
            pl.BlockSpec((1, pes, NOPS), lambda b, c: (c % ii, 0, 0)),
            pl.BlockSpec((1, pes), lambda b, c: (c % ii, 0)),
            pl.BlockSpec((1, pes, bt), lambda b, c: (c, 0, b)),
            pl.BlockSpec((1, pes), lambda b, c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((1, pes, bt), lambda b, c: (c, 0, b)),
        out_shape=jax.ShapeDtypeStruct((num_cycles, pes, batch), jnp.float32),
        scratch_shapes=[pltpu.VMEM((ring, pes, bt), jnp.float32)],
        interpret=interpret,
    )(route_a, route_b, op_sel, imm, inj, active)

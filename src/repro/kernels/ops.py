"""Jit'd wrappers and program compilation for the Pallas kernels.

``compile_program`` lowers a space-time Mapping (core/mapper.py) into the
dense one-hot tables the cgra_sim kernel consumes — the step where the CGRA's
crossbar and opcode decoders become MXU/VPU-friendly tensors (DESIGN.md §3).

``cgra_run`` executes a compiled program over batched input streams and
returns per-store-node outputs, via the Pallas kernel (interpret=True on CPU).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dfg import DFG
from repro.core.mapper import Mapping
from repro.core.simulate import OPCODES, _operands

from .cgra_sim import KERNEL_OPS, NOPS, cgra_sim_pallas

assert list(KERNEL_OPS) == list(OPCODES), "kernel/oracle opcode tables diverged"


@dataclass
class CGRAProgram:
    """Dense, device-ready encoding of one mapped loop kernel."""

    mapping: Mapping
    ii: int
    ring: int
    num_pes: int
    # one-hot tables, per kernel step
    route_a: np.ndarray    # [II, pes, ring*pes] f32
    route_b: np.ndarray    # [II, pes, ring*pes] f32
    op_sel: np.ndarray     # [II, pes, NOPS] f32
    imm: np.ndarray        # [II, pes] f32
    # integer views (used by ref.py and the injection builder)
    op_id: np.ndarray      # [II, pes] int32 (-1 = idle)
    node_at: np.ndarray    # [II, pes] int32 (-1 = idle)
    src_pe: np.ndarray     # [II, pes, 2] int32
    src_delta: np.ndarray  # [II, pes, 2] int32 (cycles since operand produced)

    def vmem_bytes(self, batch_tile: int) -> int:
        route = 2 * self.ii * self.num_pes * self.ring * self.num_pes * 4
        state = self.ring * self.num_pes * batch_tile * 4
        return route + state


def compile_program(mapping: Mapping) -> CGRAProgram:
    dfg, cgra, ii = mapping.dfg, mapping.cgra, mapping.ii
    pes = cgra.num_pes
    labels, t_abs, placement = mapping.labels, mapping.t_abs, mapping.placement

    # operand delay: value produced delta cycles before consumption
    deltas: list[list[int]] = [[] for _ in dfg.nodes]
    srcs: list[list[int]] = [[] for _ in dfg.nodes]
    for v in dfg.nodes:
        for e in _operands(dfg, v):
            delta = (t_abs[v] - t_abs[e.src]) + e.distance * ii
            if delta < 1:
                raise AssertionError(f"non-causal operand on edge {e}")
            deltas[v].append(delta)
            srcs[v].append(placement[e.src])
    ring = max((d for ds in deltas for d in ds), default=1)

    route_a = np.zeros((ii, pes, ring * pes), np.float32)
    route_b = np.zeros((ii, pes, ring * pes), np.float32)
    op_sel = np.zeros((ii, pes, NOPS), np.float32)
    imm = np.zeros((ii, pes), np.float32)
    op_id = np.full((ii, pes), -1, np.int32)
    node_at = np.full((ii, pes), -1, np.int32)
    src_pe = np.full((ii, pes, 2), -1, np.int32)
    src_delta = np.zeros((ii, pes, 2), np.int32)

    for v in dfg.nodes:
        k, pe = labels[v], placement[v]
        op = dfg.ops[v]
        op_sel[k, pe, OPCODES[op]] = 1.0
        op_id[k, pe] = OPCODES[op]
        node_at[k, pe] = v
        imm[k, pe] = dfg.imms[v]
        for slot, (sp, dl) in enumerate(zip(srcs[v], deltas[v])):
            # ring slot dl-1 holds the value produced dl cycles ago
            flat = (dl - 1) * pes + sp
            (route_a if slot == 0 else route_b)[k, pe, flat] = 1.0
            src_pe[k, pe, slot] = sp
            src_delta[k, pe, slot] = dl

    return CGRAProgram(
        mapping=mapping, ii=ii, ring=ring, num_pes=pes,
        route_a=route_a, route_b=route_b, op_sel=op_sel, imm=imm,
        op_id=op_id, node_at=node_at, src_pe=src_pe, src_delta=src_delta,
    )


def num_cycles(program: CGRAProgram, num_iters: int) -> int:
    return program.mapping.schedule_length + (num_iters - 1) * program.ii


def build_injection(
    program: CGRAProgram, inputs: dict[int, np.ndarray], num_iters: int
) -> tuple[np.ndarray, np.ndarray]:
    """Input-node value injection [C, pes, B] and firing mask [C, pes]."""
    m = program.mapping
    C = num_cycles(program, num_iters)
    batch = next(iter(inputs.values())).shape[1] if inputs else 1
    inj = np.zeros((C, program.num_pes, batch), np.float32)
    active = np.zeros((C, program.num_pes), np.float32)
    for v in m.dfg.nodes:
        pe = m.placement[v]
        for it in range(num_iters):
            c = m.t_abs[v] + it * m.ii
            active[c, pe] = 1.0
            if m.dfg.ops[v] == "input":
                inj[c, pe, :] = inputs[v][it]
    return inj, active


def cgra_run(
    program: CGRAProgram,
    inputs: dict[int, np.ndarray],   # input node -> [num_iters, B] f32
    num_iters: int,
    *,
    batch_tile: int = 128,
    interpret: bool = True,          # CPU container: interpret; TPU: False
) -> tuple[dict[int, np.ndarray], np.ndarray]:
    """Execute on the Pallas kernel; returns (store outputs, full trace)."""
    inj, active = build_injection(program, inputs, num_iters)
    C = inj.shape[0]
    batch = inj.shape[2]
    bt = min(batch_tile, batch)
    trace = cgra_sim_pallas(
        jnp.asarray(program.route_a),
        jnp.asarray(program.route_b),
        jnp.asarray(program.op_sel),
        jnp.asarray(program.imm),
        jnp.asarray(inj),
        jnp.asarray(active),
        ii=program.ii,
        ring=program.ring,
        num_cycles=C,
        batch_tile=bt,
        interpret=interpret,
    )
    trace = np.asarray(trace)
    m = program.mapping
    outs: dict[int, np.ndarray] = {}
    for v in m.dfg.nodes:
        if m.dfg.ops[v] == "store":
            cyc = m.t_abs[v] + np.arange(num_iters) * m.ii
            outs[v] = trace[cyc, m.placement[v], :]
    return outs, trace

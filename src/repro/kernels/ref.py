"""Pure-jnp oracles for the Pallas kernels.

``cgra_sim_reference`` executes the same compiled program as the cgra_sim
kernel but with a structurally different method: integer-indexed reads from
the full value trace (no ring buffer, no one-hot matmuls), so it validates the
kernel's routing/ring logic rather than sharing it. Scalar semantics are the
same ALU as core.simulate (bit-identical in f32 by construction).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simulate import OPCODES

from .ops import CGRAProgram, build_injection, num_cycles

_F = np.float32


def reference_attention(
    q: jax.Array,   # [B, Hq, S, D]
    k: jax.Array,   # [B, Hkv, S, D]
    v: jax.Array,   # [B, Hkv, S, D]
    *,
    sm_scale: float | None = None,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
) -> jax.Array:
    """Direct-softmax oracle for kernels/flash_attention.py (f32 math)."""
    b, hq, s_len, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if sm_scale is None:
        sm_scale = d ** -0.5
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * sm_scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(s_len)[:, None]
    k_pos = jnp.arange(s_len)[None, :]
    mask = jnp.ones((s_len, s_len), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows: softmax of all -1e30 is uniform garbage; zero them
    p = jnp.where(mask.any(-1)[:, None], p, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _alu_np(op_id: int, a: np.ndarray, b: np.ndarray, imm: float, inj: np.ndarray) -> np.ndarray:
    names = {v: k for k, v in OPCODES.items()}
    op = names[op_id]
    ia = np.abs(a).astype(np.int64) & 0xFFFF
    ib = np.abs(b).astype(np.int64) & 0xFFFF
    sh = ib % 8
    if op == "input":
        return inj
    if op == "const":
        return np.full_like(a, _F(imm))
    if op in ("load", "store", "mov"):
        return a
    if op == "phi":
        return a + b
    if op == "add":
        return a + b
    if op == "sub":
        return a - b
    if op == "mul":
        return a * b
    if op == "div":
        return np.where(b != 0, a / np.where(b != 0, b, 1.0), _F(0)).astype(_F)
    if op == "and":
        return (ia & ib).astype(_F)
    if op == "or":
        return (ia | ib).astype(_F)
    if op == "xor":
        return (ia ^ ib).astype(_F)
    if op == "shl":
        return ((ia << sh) & 0xFFFF).astype(_F)
    if op == "shr":
        return (ia >> sh).astype(_F)
    if op == "min":
        return np.minimum(a, b)
    if op == "max":
        return np.maximum(a, b)
    if op == "neg":
        return -a
    if op == "not":
        return (~ia & 0xFFFF).astype(_F)
    if op == "abs":
        return np.abs(a)
    if op == "cmp":
        return (a > b).astype(_F)
    raise ValueError(op)


def cgra_sim_reference(
    program: CGRAProgram,
    inputs: dict[int, np.ndarray],
    num_iters: int,
) -> tuple[dict[int, np.ndarray], np.ndarray]:
    """Trace-indexed reference execution; returns (store outputs, trace)."""
    inj, active = build_injection(program, inputs, num_iters)
    C = num_cycles(program, num_iters)
    pes = program.num_pes
    batch = inj.shape[2]
    trace = np.zeros((C, pes, batch), _F)
    for c in range(C):
        k = c % program.ii
        for pe in range(pes):
            if active[c, pe] == 0.0:
                continue
            oid = int(program.op_id[k, pe])
            ops_ab = []
            for slot in range(2):
                sp = int(program.src_pe[k, pe, slot])
                dl = int(program.src_delta[k, pe, slot])
                if sp < 0 or c - dl < 0:
                    ops_ab.append(np.zeros(batch, _F))
                else:
                    ops_ab.append(trace[c - dl, sp, :])
            val = _alu_np(
                oid, ops_ab[0], ops_ab[1], float(program.imm[k, pe]), inj[c, pe]
            )
            trace[c, pe, :] = val.astype(_F)
    m = program.mapping
    outs: dict[int, np.ndarray] = {}
    for v in m.dfg.nodes:
        if m.dfg.ops[v] == "store":
            cyc = m.t_abs[v] + np.arange(num_iters) * m.ii
            outs[v] = trace[cyc, m.placement[v], :]
    return outs, trace

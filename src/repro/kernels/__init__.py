"""Pallas TPU kernels.

cgra_sim.py          batched execution of mapped CGRA programs (the paper's
                     compute substrate as a TPU kernel: crossbar -> one-hot
                     MXU matmuls, register files -> VMEM ring buffer)
flash_attention.py   fused attention (causal/sliding-window/softcap/GQA) —
                     the TPU hot path behind the model zoo's blocked-attention
                     jnp fallback

ops.py               program compilation + jit'd wrappers
ref.py               pure-jnp / numpy oracles (kernels assert against these)

Validated with interpret=True on CPU; pass interpret=False on real TPUs.
"""

"""Pallas TPU kernel: fused flash attention for the LM model zoo.

Supports the attention variants the assigned architectures need:

  * causal masking (decoder LMs)
  * sliding-window masking (gemma2 local layers, hymba SWA)
  * logit soft-capping (gemma2: s <- cap * tanh(s / cap))
  * GQA via a q-heads-per-kv-head group factor

Standard online-softmax tiling: grid (batch*q_heads, q blocks, kv blocks) with
the kv dimension innermost/sequential; running max / denominator / accumulator
live in VMEM scratch in f32. Block shapes default to (128, 128) so the
q-block x d and kv-block x d tiles are MXU-aligned.

On this CPU container the kernel is validated with interpret=True against
ref.reference_attention; on TPU pass interpret=False. The model zoo uses the
pure-jnp reference by default (portable + SPMD-partitionable); this kernel is
the TPU hot-path drop-in.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,      # [1, bq, d]
    k_ref,      # [1, bkv, d]
    v_ref,      # [1, bkv, d]
    o_ref,      # [1, bq, d]
    m_ref,      # [bq, 128] scratch (running max, lane-broadcast)
    l_ref,      # [bq, 128] scratch (running denominator)
    acc_ref,    # [bq, d]   scratch (weighted value accumulator)
    *,
    sm_scale: float,
    causal: bool,
    window: int | None,
    softcap: float | None,
    block_q: int,
    block_kv: int,
    kv_steps: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, :1]                            # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)        # [bq, 1]
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (all NEG_INF): exp(NEG_INF - NEG_INF) would be 1
    safe = m_new > NEG_INF / 2
    p = jnp.where(safe, jnp.exp(s - m_new), 0.0)
    alpha = jnp.where(safe, jnp.exp(m_prev - m_new), 0.0)

    l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == kv_steps - 1)
    def _finalize():
        l = l_ref[:, :1]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "sm_scale", "block_q", "block_kv",
        "interpret",
    ),
)
def flash_attention(
    q: jax.Array,   # [B, Hq, S, D]
    k: jax.Array,   # [B, Hkv, S, D]
    v: jax.Array,   # [B, Hkv, S, D]
    *,
    sm_scale: float | None = None,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, hq, s_len, d = q.shape
    _, hkv, _, _ = k.shape
    if hq % hkv:
        raise ValueError(f"q heads {hq} not a multiple of kv heads {hkv}")
    group = hq // hkv
    if sm_scale is None:
        sm_scale = d ** -0.5
    bq = min(block_q, s_len)
    bkv = min(block_kv, s_len)
    if s_len % bq or s_len % bkv:
        raise ValueError(f"seq len {s_len} not divisible by blocks {bq},{bkv}")
    q_steps = s_len // bq
    kv_steps = s_len // bkv

    qf = q.reshape(b * hq, s_len, d)
    kf = k.reshape(b * hkv, s_len, d)
    vf = v.reshape(b * hkv, s_len, d)

    kernel = functools.partial(
        _flash_kernel,
        sm_scale=sm_scale, causal=causal, window=window, softcap=softcap,
        block_q=bq, block_kv=bkv, kv_steps=kv_steps,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * hq, q_steps, kv_steps),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, qi, ki, grp=group: (h // grp, ki, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, qi, ki, grp=group: (h // grp, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, s_len, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, s_len, d)

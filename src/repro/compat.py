"""Version-compatibility shims for the pinned accelerator stack.

The repo targets the jax API surface of >= 0.5 (``jax.shard_map`` at top
level) while the baked-in container toolchain pins jax 0.4.x, where the same
callable lives at ``jax.experimental.shard_map.shard_map``. Import the shim
instead of reaching into ``jax`` directly:

    from repro.compat import shard_map
"""

from __future__ import annotations

import jax


def _resolve_shard_map():
    sm = getattr(jax, "shard_map", None)
    if sm is not None:  # jax >= 0.5: promoted to the top-level namespace
        return sm
    from jax.experimental.shard_map import shard_map as sm  # jax 0.4.x

    return sm


shard_map = _resolve_shard_map()


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` (jax >= 0.5) for 0.4.x mapped contexts.

    ``psum(1, axis)`` constant-folds to a Python int under shard_map tracing,
    which is exactly what ``jax.lax.axis_size`` returns on newer jax.
    """
    sz = getattr(jax.lax, "axis_size", None)
    if sz is not None:
        return sz(axis_name)
    return jax.lax.psum(1, axis_name)


__all__ = ["shard_map", "axis_size"]

"""Optimizers and distributed-optimization utilities."""

from .adamw import (
    AdamWConfig, adamw_init, adamw_update, build_opt_shardings, global_norm,
    lr_at,
)
from .compression import (
    compress, compress_grads_with_feedback, decompress, init_residual,
)

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "build_opt_shardings",
    "global_norm", "lr_at", "compress", "compress_grads_with_feedback",
    "decompress", "init_residual",
]

"""Gradient compression with error feedback (distributed-optimization trick).

int8 block-quantisation of gradients before the data-parallel reduction, with
an error-feedback residual so compression noise is unbiased over steps
(Seide et al. / EF-SGD family). On real multi-slice deployments the quantised
tensors are what crosses DCI between pods — an 4x wire-size reduction for the
pod-level all-reduce; here the compress->reduce->decompress pipeline is
implemented functionally (correct semantics, testable) and the launcher
enables it per-axis via TrainConfig.grad_compression.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x: jax.Array) -> tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def compress(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """-> (int8 payload, f32 per-block scales). Blockwise symmetric quant."""
    flat, _ = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / safe), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def decompress(q: jax.Array, scale: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    import math

    n = math.prod(shape)
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    return flat[:n].reshape(shape)


def compress_grads_with_feedback(
    grads: Any, residual: Any
) -> tuple[Any, Any]:
    """Error-feedback compression: g' = Q(g + r); r' = (g + r) - g'."""

    def one(g, r):
        target = g.astype(jnp.float32) + r
        q, s = compress(target)
        deq = decompress(q, s, g.shape)
        return deq.astype(g.dtype), target - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        jax.tree.unflatten(tdef, [o[0] for o in outs]),
        jax.tree.unflatten(tdef, [o[1] for o in outs]),
    )


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum_mean(x: jax.Array, axis: str) -> jax.Array:
    """Mean-reduce `x` over a (slow, cross-pod) mesh axis with int8 wire.

    For use inside shard_map: quantise locally, all_gather the int8 payload +
    f32 block scales over `axis` (the bytes that cross DCI are 1/4 of bf16),
    dequantise and average locally. The within-pod (fast ICI) reduction stays
    full precision — this implements the hierarchical scheme from DESIGN.md:
    ICI psum in bf16/f32, DCI hop compressed.

    The int8 all-gather is verifiable in the compiled HLO (s8[...] operand) —
    tests/test_substrate.py asserts it.
    """
    q, scale = compress(x)
    qs = jax.lax.all_gather(q, axis)          # int8 across the slow axis
    ss = jax.lax.all_gather(scale, axis)
    n = qs.shape[0]
    deq = jax.vmap(lambda qq, sc: decompress(qq, sc, x.shape))(qs, ss)
    return jnp.mean(deq, axis=0).astype(x.dtype)

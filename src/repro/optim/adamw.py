"""AdamW with global-norm clipping, ZeRO-1 moment sharding, and optional
gradient compression (optim/compression.py) — self-contained, no optax.

ZeRO-1: Adam moments follow the param TP sharding *plus* the largest
still-unsharded dim is sharded over the 'data' axis when divisible — the
optimizer state (the largest training-memory term) thus scales down with the
full mesh, while params keep their TP layout for fast matmuls. The sharding
is applied through jit out_shardings by the launcher (moment_shardings()).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1.0, cfg.warmup_steps))
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    grads: Any, opt_state: dict, params: Any, cfg: AdamWConfig
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, metrics). All math in f32."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh = m32 / b1c
        vh = v32 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    new = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = jax.tree.unflatten(tdef, [t[0] for t in new])
    new_m = jax.tree.unflatten(tdef, [t[1] for t in new])
    new_v = jax.tree.unflatten(tdef, [t[2] for t in new])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics


def moment_shardings(
    param_shardings: Any,
    mesh: Mesh,
    *,
    data_axis: str = "data",
) -> dict:
    """ZeRO-1 moment shardings: param spec + 'data' on the largest free dim.

    Requires the params pytree of shardings AND the corresponding shapes are
    implied by usage: we only rewrite the PartitionSpec, so callers pass a
    pytree of (sharding, shape) via .shape-bearing leaves at init time.
    """
    dsize = mesh.shape[data_axis]

    def zero1(sh: NamedSharding, leaf) -> NamedSharding:
        spec = list(sh.spec) + [None] * (leaf.ndim - len(sh.spec))
        used = {a for s in spec if s is not None for a in (s if isinstance(s, tuple) else (s,))}
        if data_axis in used:  # FSDP params already consume the data axis
            return NamedSharding(mesh, P(*spec))
        best, best_size = -1, 0
        for i, (ax, dim) in enumerate(zip(spec, leaf.shape)):
            if ax is None and dim % dsize == 0 and dim > best_size and dim >= dsize:
                best, best_size = i, dim
        if best >= 0:
            spec[best] = data_axis
        return NamedSharding(mesh, P(*spec))

    return zero1


def build_opt_shardings(params_shape: Any, p_shardings: Any, mesh: Mesh,
                        *, data_axis: str = "data") -> dict:
    zero1 = moment_shardings(p_shardings, mesh, data_axis=data_axis)
    mom = jax.tree.map(zero1, p_shardings, params_shape)
    return {
        "m": mom,
        "v": mom,
        "step": NamedSharding(mesh, P()),
    }

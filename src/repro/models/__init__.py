"""Model zoo for the 10 assigned architectures."""

from .api import ArchConfig, MLASpec, MoESpec, ModelSpec, ShapeSpec, SSMSpec
from .zoo import build_model, param_count, train_input_specs

__all__ = [
    "ArchConfig", "MLASpec", "MoESpec", "ModelSpec", "ShapeSpec", "SSMSpec",
    "build_model", "param_count", "train_input_specs",
]

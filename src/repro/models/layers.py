"""Common neural layers for the model zoo (functional, dict-of-arrays params).

Parameter keys follow a naming convention that sharding/rules.py pattern-
matches to assign PartitionSpecs — e.g. any key ending in ``w_up`` shards its
last dim over the 'model' mesh axis. Compute runs in the config dtype
(bf16 by default) with f32 for norms/softmax/logits.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_param(rng, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(rng, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_param(rng, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(rng, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + weight.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, *, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding. x: [..., seq, dim(even)], positions: [..., seq]."""
    dim = x.shape[-1]
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu_mlp_init(rng, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "w_gate": dense_param(k1, d_model, d_ff, dtype),
        "w_up": dense_param(k2, d_model, d_ff, dtype),
        "w_down": dense_param(k3, d_ff, d_model, dtype),
    }


def swiglu_mlp(params: dict, x: jax.Array) -> jax.Array:
    gate = jax.nn.silu(x @ params["w_gate"])
    return (gate * (x @ params["w_up"])) @ params["w_down"]


def gelu_mlp_init(rng, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2 = jax.random.split(rng)
    return {
        "w_up": dense_param(k1, d_model, d_ff, dtype),
        "w_down": dense_param(k2, d_ff, d_model, dtype),
    }


def gelu_mlp(params: dict, x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ params["w_up"], approximate=True) @ params["w_down"]


def geglu_mlp(params: dict, x: jax.Array) -> jax.Array:
    """Gemma-style GeGLU (same param layout as swiglu)."""
    gate = jax.nn.gelu(x @ params["w_gate"], approximate=True)
    return (gate * (x @ params["w_up"])) @ params["w_down"]


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    xf = x.astype(jnp.float32)
    return (cap * jnp.tanh(xf / cap)).astype(x.dtype)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array, *, z_loss: float = 1e-4) -> jax.Array:
    """Mean token CE in f32, with an optional z-loss stabiliser."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    loss = (lse - ll).mean()
    if z_loss:
        loss = loss + z_loss * (lse**2).mean()
    return loss

"""Hymba (arXiv:2411.13676): hybrid-head blocks — attention and Mamba2-style
SSD heads process the same input in parallel; outputs are normalised and
averaged. 128 learnable meta tokens are prepended to every sequence. Most
layers use sliding-window attention; {first, middle, last} are global.

Simplifications vs the paper (recorded in DESIGN.md §5): attention and SSM
branches run at full width and are averaged (the paper splits head groups and
uses learned per-head mixing); cross-layer KV sharing is not implemented
(caches are per-layer).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .api import ArchConfig
from .attention import KVCache, gqa_attention, gqa_init, make_kv_cache
from .build import layer_windows
from .layers import (
    cross_entropy_loss, dense_param, embed_param, rms_norm, swiglu_mlp,
    swiglu_mlp_init,
)
from .ssm import SSDState, ssd, ssd_init, ssd_step


class HymbaCaches(NamedTuple):
    kv: list          # per layer KVCache
    ssm: list         # per layer SSDState


def hymba_init(rng, cfg: ArchConfig) -> dict:
    ks = jax.random.split(rng, cfg.num_layers + 4)
    params: dict = {
        "embed": embed_param(ks[0], cfg.vocab, cfg.d_model, cfg.dtype),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        "lm_head": dense_param(ks[1], cfg.d_model, cfg.vocab, cfg.dtype),
        "meta_tokens": (
            jax.random.normal(ks[2], (cfg.num_meta_tokens, cfg.d_model), jnp.float32)
            * 0.02
        ).astype(cfg.dtype),
        "layers": [],
    }
    layers = []
    for i in range(cfg.num_layers):
        ka, kb, kc = jax.random.split(ks[3 + i], 3)
        layers.append(
            {
                "norm": jnp.zeros((cfg.d_model,), cfg.dtype),
                "attn": gqa_init(ka, cfg, cfg.dtype),
                "attn_out_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
                "ssd": ssd_init(kb, cfg.d_model, cfg.num_heads, cfg.ssm.state_dim, cfg.dtype),
                "ssd_out_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
                "ffn_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
                "mlp": swiglu_mlp_init(kc, cfg.d_model, cfg.d_ff, cfg.dtype),
            }
        )
    params["layers"] = layers
    return params


def _forward(params, cfg: ArchConfig, tokens, caches: HymbaCaches | None = None,
             positions=None):
    b, s = tokens.shape
    x = params["embed"][tokens]
    if s > 1:  # train/prefill: prepend meta tokens
        meta = jnp.broadcast_to(
            params["meta_tokens"][None], (b, cfg.num_meta_tokens, cfg.d_model)
        ).astype(x.dtype)
        x = jnp.concatenate([meta, x], axis=1)
    if positions is None:
        positions = jnp.arange(x.shape[1])
    windows = layer_windows(cfg, cfg.num_layers)
    train_mode = caches is None
    new_kv, new_ssm = [], []

    def layer_fwd(lp, xin, window):
        h = rms_norm(xin, lp["norm"])
        a, _ = gqa_attention(lp["attn"], h, positions, cfg, window=window)
        m, nssm = ssd(lp["ssd"], h, cfg.num_heads, cfg.ssm.state_dim,
                      chunk=cfg.ssm.chunk)
        mixed = 0.5 * (
            rms_norm(a, lp["attn_out_norm"]) + rms_norm(m, lp["ssd_out_norm"])
        )
        xo = xin + mixed
        xo = xo + swiglu_mlp(lp["mlp"], rms_norm(xo, lp["ffn_norm"]))
        return xo, nssm

    layer_train = jax.checkpoint(layer_fwd, static_argnums=(2,)) if cfg.remat else layer_fwd

    for i, lp in enumerate(params["layers"]):
        window = int(windows[i]) or None
        if train_mode:
            x, nssm = layer_train(lp, x, window)
            new_kv.append(None)
            new_ssm.append(nssm)
            continue
        h = rms_norm(x, lp["norm"])
        kv_c = caches.kv[i]
        ssm_c = caches.ssm[i]
        a, nkv = gqa_attention(
            lp["attn"], h, positions, cfg, window=window, cache=kv_c,
        )
        if x.shape[1] == 1 and ssm_c is not None:
            m, nssm = ssd_step(lp["ssd"], h, ssm_c, cfg.num_heads, cfg.ssm.state_dim)
        else:
            m, nssm = ssd(lp["ssd"], h, cfg.num_heads, cfg.ssm.state_dim,
                          chunk=cfg.ssm.chunk)
        mixed = 0.5 * (
            rms_norm(a, lp["attn_out_norm"]) + rms_norm(m, lp["ssd_out_norm"])
        )
        x = x + mixed
        x = x + swiglu_mlp(lp["mlp"], rms_norm(x, lp["ffn_norm"]))
        new_kv.append(nkv)
        new_ssm.append(nssm)
    return x, HymbaCaches(new_kv, new_ssm) if caches is not None else None


def hymba_loss(params, cfg: ArchConfig, batch, **_):
    x, _ = _forward(params, cfg, batch["tokens"])
    x = x[:, cfg.num_meta_tokens :]
    logits = rms_norm(x, params["final_norm"]) @ params["lm_head"]
    loss = cross_entropy_loss(logits, batch["labels"])
    return loss, {"ce": loss}


def hymba_make_caches(params, cfg: ArchConfig, batch: int, cache_len: int):
    dh = cfg.d_model // cfg.num_heads
    kv = [
        make_kv_cache(cfg, batch, cache_len + cfg.num_meta_tokens, cfg.dtype)
        for _ in range(cfg.num_layers)
    ]
    ssm = [
        SSDState(jnp.zeros((batch, cfg.num_heads, cfg.ssm.state_dim, dh), jnp.float32))
        for _ in range(cfg.num_layers)
    ]
    return HymbaCaches(kv, ssm)


def hymba_decode_step(params, cfg: ArchConfig, token, caches, pos, **_):
    positions = jnp.reshape(jnp.asarray(pos), (1,))
    x, new_caches = _forward(params, cfg, token, caches, positions)
    logits = rms_norm(x, params["final_norm"]) @ params["lm_head"]
    return logits[:, -1], new_caches


def hymba_prefill(params, cfg: ArchConfig, tokens, cache_len, **_):
    caches = hymba_make_caches(params, cfg, tokens.shape[0], cache_len)
    x, new_caches = _forward(params, cfg, tokens, caches)
    logits = rms_norm(x, params["final_norm"]) @ params["lm_head"]
    return logits[:, -1], new_caches

"""Recurrent sequence mixers: mLSTM (xLSTM), sLSTM (xLSTM), Mamba2-style SSD.

One generic *chunked linear recurrence* drives both mLSTM and SSD:

    state_t = a_t * state_{t-1} + k_t ⊗ v_t          (state: [dk, dv])
    y_t     = q_t @ state_t

computed chunk-parallel (intra-chunk masked matmuls with cumulative decay,
inter-chunk lax.scan carrying the state) — the TPU-friendly formulation: the
sequential dimension collapses to T/chunk scan steps of MXU matmuls.

Stability adaptation (recorded in DESIGN.md §3): mLSTM's exponential input
gate is implemented in its normalised form — the normaliser n_t is tracked by
appending a ones-column to v, and gates use sigmoid/exp with per-step decay in
log space, all decays <= 1. sLSTM keeps the published stabilised recurrence
(m_t running max) and is inherently sequential (lax.scan over time).

Every mixer has a decode step with O(1) state — this is what makes the
long_500k shape runnable for the ssm/hybrid architectures.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import dense_param


# ------------------------------------------------- generic chunked recurrence

def chunked_linear_recurrence(
    q: jax.Array,        # [B, H, T, dk]
    k: jax.Array,        # [B, H, T, dk]
    v: jax.Array,        # [B, H, T, dv]
    log_a: jax.Array,    # [B, H, T] per-step log decay (<= 0)
    *,
    chunk: int = 128,
    init_state: jax.Array | None = None,   # [B, H, dk, dv]
) -> tuple[jax.Array, jax.Array]:
    b, h, t, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, t)
    t_pad = (-t) % c
    if t_pad:
        # zero-pad to a chunk multiple: k=v=0 contributes nothing and
        # log_a=0 leaves the carried state unchanged, so semantics hold
        pad4 = ((0, 0), (0, 0), (0, t_pad), (0, 0))
        q = jnp.pad(q, pad4)
        k = jnp.pad(k, pad4)
        v = jnp.pad(v, pad4)
        log_a = jnp.pad(log_a, ((0, 0), (0, 0), (0, t_pad)))
    t_full = t + t_pad
    nc = t_full // c
    f32 = jnp.float32
    qc = q.reshape(b, h, nc, c, dk).astype(f32)
    kc = k.reshape(b, h, nc, c, dk).astype(f32)
    vc = v.reshape(b, h, nc, c, dv).astype(f32)
    la = log_a.reshape(b, h, nc, c).astype(f32)
    cum = jnp.cumsum(la, axis=-1)                       # L_i within chunk

    # One chunk per scan step: the [c, c] decay/score tensors exist for a
    # single chunk at a time (streamed working set — VMEM-sized on TPU,
    # bounded liveness in the memory analysis), instead of materialising
    # [nc, c, c] for the whole sequence.
    h0 = (
        init_state.astype(f32)
        if init_state is not None
        else jnp.zeros((b, h, dk, dv), f32)
    )
    mask = jnp.tril(jnp.ones((c, c), bool))

    def body(state, xs):
        q_n, k_n, v_n, cum_n = xs                       # [b,h,c,*]
        # intra-chunk: y[i] = sum_{j<=i} exp(L_i - L_j) (q_i.k_j) v_j
        diff = cum_n[..., :, None] - cum_n[..., None, :]
        decay = jnp.where(mask, jnp.exp(diff), 0.0)
        s = jnp.einsum("bhid,bhjd->bhij", q_n, k_n) * decay
        y_n = jnp.einsum("bhij,bhjv->bhiv", s, v_n)
        # cross-chunk: y[i] += exp(L_i) * q_i @ state
        y_n = y_n + jnp.einsum(
            "bhid,bhdv->bhiv", q_n * jnp.exp(cum_n)[..., None], state
        )
        # carry: state = exp(L_last) * state + sum_j exp(L_last - L_j) k_j v_j
        w = jnp.exp(cum_n[..., -1:] - cum_n)
        summary = jnp.einsum("bhjd,bhj,bhjv->bhdv", k_n, w, v_n)
        state = state * jnp.exp(cum_n[..., -1])[..., None, None] + summary
        return state, y_n

    xs = (
        qc.transpose(2, 0, 1, 3, 4),
        kc.transpose(2, 0, 1, 3, 4),
        vc.transpose(2, 0, 1, 3, 4),
        cum.transpose(2, 0, 1, 3),
    )
    final_state, y = jax.lax.scan(body, h0, xs)
    y = y.transpose(1, 2, 0, 3, 4).reshape(b, h, t_full, dv)[:, :, :t]
    return y, final_state


def linear_recurrence_step(
    q: jax.Array,      # [B, H, dk]
    k: jax.Array,
    v: jax.Array,      # [B, H, dv]
    log_a: jax.Array,  # [B, H]
    state: jax.Array,  # [B, H, dk, dv]
) -> tuple[jax.Array, jax.Array]:
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    state = state * a + k.astype(jnp.float32)[..., :, None] * v.astype(jnp.float32)[..., None, :]
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), state)
    return y, state


# ----------------------------------------------------------------- mLSTM

class MLSTMState(NamedTuple):
    c: jax.Array   # [B, H, dk, dv+1] (last column = normaliser n)


def mlstm_init(rng, d_model: int, num_heads: int, dtype) -> dict:
    ks = jax.random.split(rng, 6)
    dh = d_model // num_heads
    return {
        "w_q": dense_param(ks[0], d_model, d_model, dtype),
        "w_k": dense_param(ks[1], d_model, d_model, dtype),
        "w_v": dense_param(ks[2], d_model, d_model, dtype),
        "w_if": dense_param(ks[3], d_model, 2 * num_heads, dtype),  # i,f gates
        "w_o": dense_param(ks[4], d_model, d_model, dtype),
        "out_norm": jnp.zeros((d_model,), dtype),
    }


def _mlstm_qkv(params, x, num_heads):
    b, t, d = x.shape
    dh = d // num_heads
    def heads(y):
        return y.reshape(b, t, num_heads, dh).transpose(0, 2, 1, 3)
    q = heads(x @ params["w_q"]) * dh**-0.5
    k = heads(x @ params["w_k"]) * dh**-0.5
    v = heads(x @ params["w_v"])
    gates = (x @ params["w_if"]).reshape(b, t, num_heads, 2).transpose(0, 2, 1, 3)
    i_gate = jax.nn.sigmoid(gates[..., 0].astype(jnp.float32))
    log_f = jax.nn.log_sigmoid(gates[..., 1].astype(jnp.float32))
    return q, k, v, i_gate, log_f


def _mlstm_out(params, y, x_dtype, b, t, d):
    num = y[..., :-1]
    den = y[..., -1:]
    h = num / jnp.maximum(jnp.abs(den), 1.0)
    h = h.transpose(0, 2, 1, 3).reshape(b, t, d).astype(x_dtype)
    from .layers import rms_norm
    return rms_norm(h, params["out_norm"]) @ params["w_o"]


def mlstm(params: dict, x: jax.Array, num_heads: int, *, chunk: int = 128):
    """Parallel (training/prefill) mLSTM; returns output + final state."""
    b, t, d = x.shape
    q, k, v, i_gate, log_f = _mlstm_qkv(params, x, num_heads)
    v1 = jnp.concatenate([v, jnp.ones((*v.shape[:-1], 1), v.dtype)], -1)
    y, state = chunked_linear_recurrence(
        q, k * i_gate[..., None].astype(k.dtype), v1, log_f, chunk=chunk
    )
    return _mlstm_out(params, y, x.dtype, b, t, d), MLSTMState(state)


def mlstm_step(params: dict, x: jax.Array, state: MLSTMState, num_heads: int):
    """O(1) decode step; x: [B, 1, d]."""
    b, t, d = x.shape
    q, k, v, i_gate, log_f = _mlstm_qkv(params, x, num_heads)
    v1 = jnp.concatenate([v, jnp.ones((*v.shape[:-1], 1), v.dtype)], -1)
    y, new = linear_recurrence_step(
        q[:, :, 0], (k * i_gate[..., None].astype(k.dtype))[:, :, 0],
        v1[:, :, 0], log_f[:, :, 0], state.c,
    )
    return _mlstm_out(params, y[:, :, None, :], x.dtype, b, 1, d), MLSTMState(new)


# ----------------------------------------------------------------- sLSTM

class SLSTMState(NamedTuple):
    c: jax.Array   # [B, H, dh]
    n: jax.Array   # [B, H, dh]
    m: jax.Array   # [B, H, dh]
    h: jax.Array   # [B, H, dh]


def slstm_init(rng, d_model: int, num_heads: int, dtype) -> dict:
    dh = d_model // num_heads
    ks = jax.random.split(rng, 3)
    return {
        # 4 gates (i, f, z, o) from input and block-diagonal recurrence
        "w_x": dense_param(ks[0], d_model, 4 * d_model, dtype),
        "r_h": (jax.random.normal(ks[1], (num_heads, dh, 4 * dh), jnp.float32)
                / dh**0.5).astype(dtype),
        "b": jnp.zeros((4 * d_model,), dtype),
        "w_o": dense_param(ks[2], d_model, d_model, dtype),
        "out_norm": jnp.zeros((d_model,), dtype),
    }


def slstm_zero_state(batch: int, d_model: int, num_heads: int) -> SLSTMState:
    dh = d_model // num_heads
    z = jnp.zeros((batch, num_heads, dh), jnp.float32)
    return SLSTMState(z, z, z - 10.0, z)


def _slstm_cell(params, xg, state: SLSTMState, num_heads: int, dh: int):
    """One stabilised sLSTM step. xg: [B, 4*d] pre-computed input gates."""
    b = xg.shape[0]
    rec = jnp.einsum("bhd,hdg->bhg", state.h.astype(jnp.float32),
                     params["r_h"].astype(jnp.float32))
    g = xg.reshape(b, num_heads, 4 * dh).astype(jnp.float32) + rec
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    m_new = jnp.maximum(gf + state.m, gi)
    i = jnp.exp(gi - m_new)
    f = jnp.exp(gf + state.m - m_new)
    c = f * state.c + i * jnp.tanh(gz)
    n = f * state.n + i
    h = jax.nn.sigmoid(go) * c / jnp.maximum(jnp.abs(n), 1.0)
    return SLSTMState(c, n, m_new, h)


def slstm(params: dict, x: jax.Array, num_heads: int,
          state: SLSTMState | None = None):
    """Sequential sLSTM over time (lax.scan); returns output + final state."""
    b, t, d = x.shape
    dh = d // num_heads
    xg = (x @ params["w_x"] + params["b"]).astype(jnp.float32)  # [B,T,4d]
    if state is None:
        state = slstm_zero_state(b, d, num_heads)

    def body(st, xg_t):
        st = _slstm_cell(params, xg_t, st, num_heads, dh)
        return st, st.h

    final, hs = jax.lax.scan(body, state, xg.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2, 3).reshape(b, t, d).astype(x.dtype)
    from .layers import rms_norm
    return rms_norm(h, params["out_norm"]) @ params["w_o"], final


def slstm_step(params: dict, x: jax.Array, state: SLSTMState, num_heads: int):
    b, t, d = x.shape
    dh = d // num_heads
    xg = (x[:, 0] @ params["w_x"] + params["b"]).astype(jnp.float32)
    new = _slstm_cell(params, xg, state, num_heads, dh)
    h = new.h.reshape(b, 1, d).astype(x.dtype)
    from .layers import rms_norm
    return rms_norm(h, params["out_norm"]) @ params["w_o"], new


# ------------------------------------------------------------------- SSD

class SSDState(NamedTuple):
    h: jax.Array   # [B, H, N, dh]


def ssd_init(rng, d_model: int, num_heads: int, state_dim: int, dtype) -> dict:
    dh = d_model // num_heads
    ks = jax.random.split(rng, 5)
    return {
        "w_x": dense_param(ks[0], d_model, d_model, dtype),
        "w_b": dense_param(ks[1], d_model, num_heads * state_dim, dtype),
        "w_c": dense_param(ks[2], d_model, num_heads * state_dim, dtype),
        "w_dt": dense_param(ks[3], d_model, num_heads, dtype),
        "a_log": jnp.zeros((num_heads,), jnp.float32),   # A = -exp(a_log)
        "d_skip": jnp.ones((num_heads,), jnp.float32),
        "w_o": dense_param(ks[4], d_model, d_model, dtype),
        "out_norm": jnp.zeros((d_model,), dtype),
    }


def _ssd_proj(params, x, num_heads, state_dim):
    b, t, d = x.shape
    dh = d // num_heads
    xs = (x @ params["w_x"]).reshape(b, t, num_heads, dh).transpose(0, 2, 1, 3)
    bb = (x @ params["w_b"]).reshape(b, t, num_heads, state_dim).transpose(0, 2, 1, 3)
    cc = (x @ params["w_c"]).reshape(b, t, num_heads, state_dim).transpose(0, 2, 1, 3)
    dt = jax.nn.softplus((x @ params["w_dt"]).astype(jnp.float32))  # [b,t,h]
    dt = dt.transpose(0, 2, 1)                                      # [b,h,t]
    log_a = -jnp.exp(params["a_log"])[None, :, None] * dt           # <= 0
    return xs, bb, cc, dt, log_a


def _ssd_out(params, y, xs, x_dtype, b, t, d, num_heads):
    y = y + xs.astype(jnp.float32) * params["d_skip"][None, :, None, None]
    h = y.transpose(0, 2, 1, 3).reshape(b, t, d).astype(x_dtype)
    from .layers import rms_norm
    return rms_norm(h, params["out_norm"]) @ params["w_o"]


def ssd(params: dict, x: jax.Array, num_heads: int, state_dim: int,
        *, chunk: int = 128):
    """Mamba2-style SSD (training/prefill); returns output + final state."""
    b, t, d = x.shape
    xs, bb, cc, dt, log_a = _ssd_proj(params, x, num_heads, state_dim)
    v = xs * dt.astype(xs.dtype)[..., None]
    y, state = chunked_linear_recurrence(cc, bb, v, log_a, chunk=chunk)
    return _ssd_out(params, y, xs, x.dtype, b, t, d, num_heads), SSDState(state)


def ssd_step(params: dict, x: jax.Array, state: SSDState, num_heads: int,
             state_dim: int):
    b, t, d = x.shape
    xs, bb, cc, dt, log_a = _ssd_proj(params, x, num_heads, state_dim)
    v = xs * dt.astype(xs.dtype)[..., None]
    y, new = linear_recurrence_step(
        cc[:, :, 0], bb[:, :, 0], v[:, :, 0], log_a[:, :, 0], state.h
    )
    return (
        _ssd_out(params, y[:, :, None, :], xs, x.dtype, b, 1, d, num_heads),
        SSDState(new),
    )

"""Mixture-of-Experts FFN with expert parallelism (DeepSeek-style).

Routing: top-k over router scores (softmax or sigmoid per config), optional
shared experts that always fire, capacity-bounded dispatch (tokens over
capacity are dropped — standard GShard/Switch semantics), plus a Switch-style
load-balance auxiliary loss.

Distribution (the EP design): expert weights are sharded over the 'model' mesh
axis; activations arrive replicated across 'model' (they are sharded over
'data'/'pod' only). Each model-shard computes *its* experts' contribution for
all local tokens via a sort-based capacity-buffer dispatch — entirely local
gathers/scatters — and one psum over 'model' combines routed + shared-expert
partial outputs. Compared to all-to-all EP this trades some redundant router
compute (replicated, negligible) for a single fused all-reduce that overlaps
with the shared-expert matmul; the a2a variant is evaluated in the §Perf
hillclimb. Under a single device (smoke tests) the same code runs with the
whole expert set local and the psum skipped.

Token->buffer slots are computed with the argsort/searchsorted rank trick so
no [tokens, experts] one-hot ever materialises — O(Tk log Tk) and shardable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..compat import axis_size
from .layers import dense_param


def moe_init(rng, cfg, dtype) -> dict:
    m = cfg.moe
    d, f = cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(rng, 8)
    e = m.num_experts
    p = {
        "router": dense_param(ks[0], d, e, jnp.float32),
        "expert_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) / d**0.5).astype(dtype),
        "expert_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) / d**0.5).astype(dtype),
        "expert_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32) / f**0.5).astype(dtype),
    }
    if m.num_shared > 0:
        fs = f * m.num_shared
        p["shared_gate"] = dense_param(ks[4], d, fs, dtype)
        p["shared_up"] = dense_param(ks[5], d, fs, dtype)
        p["shared_down"] = dense_param(ks[6], fs, d, dtype)
    return p


def _routing(params: dict, x_flat: jax.Array, cfg):
    """Top-k routing; identical (replicated) on every model shard."""
    m = cfg.moe
    logits = (x_flat.astype(jnp.float32)) @ params["router"]
    if m.score_fn == "sigmoid":           # deepseek-v3
        scores = jax.nn.sigmoid(logits)
    else:                                  # softmax (deepseek-moe-16b)
        scores = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(scores, m.top_k)      # [T, k]
    if m.normalize_gates:
        top_vals = top_vals / (top_vals.sum(-1, keepdims=True) + 1e-9)
    top_vals = top_vals * m.routed_scale
    # Switch-style load-balance aux loss
    e = m.num_experts
    density = jax.nn.one_hot(top_idx, e).sum(1).mean(0)       # frac routed / expert
    mean_prob = (scores / scores.sum(-1, keepdims=True)).mean(0)
    aux = e * jnp.sum(density * mean_prob) * m.aux_loss_coef
    return top_idx, top_vals.astype(jnp.float32), aux


def _dispatch_slots(expert_ids: jax.Array, capacity: int):
    """Rank of each assignment within its expert (sort-based, no one-hot)."""
    tk = expert_ids.shape[0]
    order = jnp.argsort(expert_ids)
    sorted_e = expert_ids[order]
    seg_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank_sorted = jnp.arange(tk) - seg_start
    slots = jnp.zeros(tk, jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    return slots, slots < capacity


def moe_ffn(
    params: dict,
    x: jax.Array,             # [batch_loc, seq, d] (replicated over 'model')
    cfg,
    *,
    model_axis: str | None = None,   # inside shard_map: the EP psum axis
) -> tuple[jax.Array, jax.Array]:
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    x_flat = x.reshape(t, d)
    top_idx, gates, aux = _routing(params, x_flat, cfg)      # [T,k]

    e = m.num_experts
    if model_axis is not None:
        # model_axis may be a tuple (full-EP serving mode: experts sharded
        # over every mesh axis, weights stationary, activations replicated)
        axes = model_axis if isinstance(model_axis, tuple) else (model_axis,)
        n_shards, shard = 1, 0
        for a in axes:
            n_shards = n_shards * axis_size(a)
        for a in axes:
            shard = shard * axis_size(a) + jax.lax.axis_index(a)
    else:
        n_shards, shard = 1, 0
    e_loc = params["expert_up"].shape[0]                     # E/shards (sharded in)
    capacity = max(8, int(t * m.top_k * m.capacity_factor) // e)

    flat_e = top_idx.reshape(-1)                             # [T*k]
    flat_gate = gates.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), m.top_k)
    slots, in_cap = _dispatch_slots(flat_e, capacity)

    local = (flat_e // e_loc) == shard
    valid = (local & in_cap).astype(jnp.float32)
    lin = ((flat_e % e_loc) * capacity + slots).astype(jnp.int32)
    lin = jnp.where(valid > 0, lin, 0)

    # dispatch: [E_loc*C, d] buffers via unique-slot scatter-add
    buf = jnp.zeros((e_loc * capacity, d), x.dtype)
    buf = buf.at[lin].add(x_flat[flat_tok] * valid[:, None].astype(x.dtype))
    buf = buf.reshape(e_loc, capacity, d)

    # batched expert SwiGLU (MXU-friendly [E_loc] batched matmuls)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["expert_gate"]))
    u = jnp.einsum("ecd,edf->ecf", buf, params["expert_up"])
    h = jnp.einsum("ecf,efd->ecd", g * u, params["expert_down"])
    h_flat = h.reshape(e_loc * capacity, d)

    # combine: gather back, weight by gate, accumulate per token
    contrib = h_flat[lin] * (flat_gate * valid)[:, None].astype(x.dtype)
    out = jnp.zeros((t, d), x.dtype).at[flat_tok].add(contrib)

    if m.num_shared > 0:
        # shared expert(s): d_ff sharded over 'model' => partial sums psum'd
        sg = jax.nn.silu(x_flat @ params["shared_gate"])
        su = x_flat @ params["shared_up"]
        out = out + (sg * su) @ params["shared_down"]

    if model_axis is not None:
        out = jax.lax.psum(out, model_axis)
    return out.reshape(b, s, d), aux

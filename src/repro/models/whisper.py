"""Whisper-small backbone (arXiv:2212.04356): encoder-decoder transformer.

The conv/mel audio frontend is a STUB per the assignment — `input_specs()`
supplies precomputed frame embeddings [batch, frames=1500, d_model]. The
backbone is faithful: sinusoidal-position encoder with bidirectional MHA,
learned-position decoder with causal self-attention + cross-attention, GELU
MLPs, pre-LayerNorm, tied unembedding.

Decode carries (a) per-layer self-attention KV caches and (b) per-layer
cross-attention K/V computed once from the encoder output at prefill.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .api import ArchConfig
from .attention import KVCache, gqa_attention, gqa_init, make_kv_cache
from .layers import (
    cross_entropy_loss, dense_param, embed_param, gelu_mlp, gelu_mlp_init,
    layer_norm,
)


class WhisperCaches(NamedTuple):
    self_kv: list            # per decoder layer KVCache
    cross_kv: list           # per decoder layer (k, v) from encoder


def _ln_init(d, dtype):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def _sinusoid(length: int, dim: int) -> np.ndarray:
    pos = np.arange(length)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    return np.concatenate([np.sin(angle), np.cos(angle)], axis=1).astype(np.float32)


def whisper_init(rng, cfg: ArchConfig) -> dict:
    d, dtype = cfg.d_model, cfg.dtype
    n_enc = n_dec = cfg.num_layers
    ks = jax.random.split(rng, 2 * cfg.num_layers + 6)
    ki = iter(ks)
    params: dict = {
        "embed": embed_param(next(ki), cfg.vocab, d, dtype),
        # decoder learned positions sized to the largest serving shape
        "pos_embed": (jax.random.normal(next(ki), (cfg.max_positions, d), jnp.float32) * 0.01).astype(dtype),
        "enc_final_ln": _ln_init(d, dtype),
        "dec_final_ln": _ln_init(d, dtype),
        "enc_layers": [],
        "dec_layers": [],
    }
    for _ in range(n_enc):
        k1, k2 = jax.random.split(next(ki))
        params["enc_layers"].append(
            {
                "ln1": _ln_init(d, dtype),
                "attn": gqa_init(k1, cfg, dtype),
                "ln2": _ln_init(d, dtype),
                "mlp": gelu_mlp_init(k2, d, cfg.d_ff, dtype),
            }
        )
    for _ in range(n_dec):
        k1, k2, k3 = jax.random.split(next(ki), 3)
        params["dec_layers"].append(
            {
                "ln1": _ln_init(d, dtype),
                "self_attn": gqa_init(k1, cfg, dtype),
                "ln2": _ln_init(d, dtype),
                "cross_attn": gqa_init(k2, cfg, dtype),
                "ln3": _ln_init(d, dtype),
                "mlp": gelu_mlp_init(k3, d, cfg.d_ff, dtype),
            }
        )
    return params


def _ln(x, p):
    return layer_norm(x, p["w"], p["b"])


def whisper_encode(params, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    b, f, d = frames.shape
    x = frames.astype(cfg.dtype) + jnp.asarray(_sinusoid(f, d), cfg.dtype)[None]
    pos = jnp.arange(f)

    def layer(lp, xin):
        h, _ = gqa_attention(lp["attn"], _ln(xin, lp["ln1"]), pos, cfg, causal=False)
        xo = xin + h
        return xo + gelu_mlp(lp["mlp"], _ln(xo, lp["ln2"]))

    if cfg.remat:
        layer = jax.checkpoint(layer)
    for lp in params["enc_layers"]:
        x = layer(lp, x)
    return _ln(x, params["enc_final_ln"])


def _cross_kv(params_layer, cfg, enc_out):
    b, f, d = enc_out.shape
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    k = (enc_out @ params_layer["cross_attn"]["w_k"]).reshape(b, f, hkv, hd).transpose(0, 2, 1, 3)
    v = (enc_out @ params_layer["cross_attn"]["w_v"]).reshape(b, f, hkv, hd).transpose(0, 2, 1, 3)
    return k, v


def whisper_decode_stack(params, cfg, tokens, enc_out=None, caches=None, positions=None):
    b, s = tokens.shape
    if positions is None:
        positions = jnp.arange(s)
    x = params["embed"][tokens] + jax.lax.dynamic_slice_in_dim(
        params["pos_embed"], positions[0], s, axis=0
    )[None].astype(cfg.dtype)
    train_mode = caches is None

    def layer(lp, xin):
        h, _ = gqa_attention(lp["self_attn"], _ln(xin, lp["ln1"]), positions, cfg)
        xo = xin + h
        ckv = _cross_kv(lp, cfg, enc_out)
        h, _ = gqa_attention(
            lp["cross_attn"], _ln(xo, lp["ln2"]), positions, cfg, cross_kv=ckv,
            causal=False,
        )
        xo = xo + h
        return xo + gelu_mlp(lp["mlp"], _ln(xo, lp["ln3"])), ckv

    layer_train = jax.checkpoint(layer) if cfg.remat else layer

    new_self, cross_list = [], []
    for i, lp in enumerate(params["dec_layers"]):
        if train_mode:
            x, ckv = layer_train(lp, x)
            new_self.append(None)
            cross_list.append(ckv)
            continue
        self_c = caches.self_kv[i]
        h, nc = gqa_attention(
            lp["self_attn"], _ln(x, lp["ln1"]), positions, cfg, cache=self_c
        )
        x = x + h
        ckv = (
            caches.cross_kv[i]
            if caches.cross_kv is not None
            else _cross_kv(lp, cfg, enc_out)
        )
        h, _ = gqa_attention(
            lp["cross_attn"], _ln(x, lp["ln2"]), positions, cfg, cross_kv=ckv,
            causal=False,
        )
        x = x + h
        x = x + gelu_mlp(lp["mlp"], _ln(x, lp["ln3"]))
        new_self.append(nc)
        cross_list.append(ckv)
    x = _ln(x, params["dec_final_ln"])
    logits = x @ params["embed"].T  # tied
    return logits, WhisperCaches(new_self, cross_list)


def whisper_loss(params, cfg: ArchConfig, batch, **_):
    enc_out = whisper_encode(params, cfg, batch["frames"])
    logits, _ = whisper_decode_stack(params, cfg, batch["tokens"], enc_out)
    loss = cross_entropy_loss(logits, batch["labels"])
    return loss, {"ce": loss}


def whisper_make_caches(params, cfg: ArchConfig, batch: int, cache_len: int):
    self_kv = [make_kv_cache(cfg, batch, cache_len, cfg.dtype) for _ in range(cfg.num_layers)]
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    cross = [
        (
            jnp.zeros((batch, hkv, cfg.frontend_len, hd), cfg.dtype),
            jnp.zeros((batch, hkv, cfg.frontend_len, hd), cfg.dtype),
        )
        for _ in range(cfg.num_layers)
    ]
    return WhisperCaches(self_kv, cross)


def whisper_decode_step(params, cfg: ArchConfig, token, caches, pos, **_):
    positions = jnp.reshape(jnp.asarray(pos), (1,))
    logits, new_caches = whisper_decode_stack(
        params, cfg, token, caches=caches, positions=positions
    )
    return logits[:, -1], new_caches


def whisper_prefill(params, cfg: ArchConfig, batch, cache_len, **_):
    """batch: {frames, tokens}; returns last logits + caches (self + cross)."""
    enc_out = whisper_encode(params, cfg, batch["frames"])
    caches = whisper_make_caches(params, cfg, batch["tokens"].shape[0], cache_len)
    # fill cross caches from the encoder, then run the prompt with self caches
    cross = [_cross_kv(lp, cfg, enc_out) for lp in params["dec_layers"]]
    caches = WhisperCaches(caches.self_kv, cross)
    logits, new_caches = whisper_decode_stack(
        params, cfg, batch["tokens"], caches=caches
    )
    return logits[:, -1], new_caches

"""Unified architecture config + model API for the 10 assigned architectures.

Every architecture is described by an ArchConfig (built in src/repro/configs/)
and materialised by models.build.build_model() into a ModelSpec exposing:

    init(rng)                 -> params pytree
    loss_fn(params, batch)    -> (scalar loss, metrics)       [train]
    prefill(params, batch)    -> (logits_last, caches)        [inference]
    decode_step(params, tok, caches, pos) -> (logits, caches) [inference]
    input_specs(shape, ...)   -> ShapeDtypeStruct pytree for the dry-run

Shapes: each arch owns the assignment's four shapes; `shapes()` applies the
skip policy (no long_500k for pure full-attention archs — see DESIGN.md §5).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable

import jax.numpy as jnp


@dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    num_shared: int = 0
    score_fn: str = "softmax"        # "softmax" | "sigmoid" (deepseek-v3)
    normalize_gates: bool = True
    routed_scale: float = 1.0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01


@dataclass(frozen=True)
class MLASpec:
    q_lora: int = 1536
    kv_lora: int = 512
    rope_dim: int = 64
    qk_nope_dim: int = 128
    v_dim: int = 128


@dataclass(frozen=True)
class SSMSpec:
    state_dim: int = 16
    chunk: int = 128


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


LM_SHAPES = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 => d_model // num_heads
    # attention options
    qk_norm: bool = False
    use_rope: bool = True
    rope_theta: float = 10_000.0
    attn_softcap: float | None = None
    attn_scale: float | None = None
    final_softcap: float | None = None
    sliding_window: int | None = None
    # per-layer window pattern: "none" | "alternating" (gemma2) | "hymba"
    window_pattern: str = "none"
    sandwich_norm: bool = False    # gemma2 post-norms
    tie_embeddings: bool = False
    embed_scale: bool = False      # gemma: x *= sqrt(d)
    mlp_kind: str = "swiglu"       # swiglu | gelu
    # family extensions
    moe: MoESpec | None = None
    moe_d_ff: int = 0
    num_dense_layers: int = 0      # leading dense layers in MoE models
    mla: MLASpec | None = None
    ssm: SSMSpec | None = None
    mtp: bool = False              # deepseek-v3 multi-token prediction
    mtp_weight: float = 0.3
    # frontends (stubs provide precomputed embeddings via input_specs)
    frontend: str | None = None    # None | "audio" | "vision"
    frontend_len: int = 0          # frames/patches
    num_meta_tokens: int = 0       # hymba learnable prefix
    prefix_lm: bool = False        # bidirectional attention over the prefix
    max_positions: int = 0         # learned-position table size (whisper)
    # runtime
    dtype: Any = jnp.bfloat16
    long_context_ok: bool = False  # may run long_500k (sub-quadratic story)
    remat: bool = True
    scan_layers: bool = True
    activation_constraints: bool = True  # per-layer with_sharding_constraint
    # full-EP serving mode: experts sharded over every mesh axis (1/device at
    # 256 experts x 256 chips), weights stationary, the (tiny) decode
    # activations replicated into the island instead of gathering weights
    ep_over_data: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def shapes(self) -> list[ShapeSpec]:
        out = []
        for s in LM_SHAPES:
            if s.name == "long_500k" and not self.long_context_ok:
                continue
            out.append(s)
        return out

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        return replace(
            self,
            num_layers=min(self.num_layers, 2 if self.num_dense_layers == 0 else 2 + self.num_dense_layers),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            moe_d_ff=128 if self.moe else 0,
            vocab=512,
            num_dense_layers=min(self.num_dense_layers, 1),
            # capacity_factor generous so smoke tests are drop-free (capacity
            # dropping is exercised explicitly in tests/test_models.py)
            moe=replace(self.moe, num_experts=8, top_k=2, capacity_factor=8.0)
            if self.moe
            else None,
            mla=MLASpec(q_lora=64, kv_lora=32, rope_dim=16, qk_nope_dim=32, v_dim=32)
            if self.mla
            else None,
            ssm=replace(self.ssm, chunk=16) if self.ssm else None,
            sliding_window=16 if self.sliding_window else None,
            frontend_len=16 if self.frontend else 0,
            num_meta_tokens=8 if self.num_meta_tokens else 0,
            max_positions=128 if self.max_positions else 0,
            dtype=jnp.float32,
            scan_layers=False,
        )


@dataclass
class ModelSpec:
    cfg: ArchConfig
    init: Callable
    loss_fn: Callable | None = None
    prefill: Callable | None = None
    decode_step: Callable | None = None
    make_caches: Callable | None = None
    input_specs: Callable | None = None
    param_count: Callable | None = None

"""xLSTM LM (arXiv:2405.04517): alternating mLSTM / sLSTM blocks.

Assignment config (xlstm-125m): 12L, d_model=768, 4 heads, d_ff=0 (no separate
FFN blocks — mixing blocks only), vocab 50304. Layers alternate mLSTM (even)
and sLSTM (odd). mLSTM trains chunk-parallel; sLSTM is a sequential lax.scan
(its recurrence is not parallelisable — inherent to the architecture). Decode
carries O(1) recurrent state per layer, which is what makes long_500k decoding
linear-time/constant-memory for this family.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .api import ArchConfig
from .layers import cross_entropy_loss, dense_param, embed_param, rms_norm
from .ssm import (
    MLSTMState, SLSTMState, mlstm, mlstm_init, mlstm_step, slstm,
    slstm_init, slstm_step, slstm_zero_state,
)


def xlstm_init(rng, cfg: ArchConfig) -> dict:
    ks = jax.random.split(rng, cfg.num_layers + 3)
    params: dict = {
        "embed": embed_param(ks[0], cfg.vocab, cfg.d_model, cfg.dtype),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        "lm_head": dense_param(ks[1], cfg.d_model, cfg.vocab, cfg.dtype),
        "layers": [],
    }
    layers = []
    for i in range(cfg.num_layers):
        k = ks[2 + i]
        if i % 2 == 0:
            layers.append(
                {"kind_mlstm": mlstm_init(k, cfg.d_model, cfg.num_heads, cfg.dtype),
                 "norm": jnp.zeros((cfg.d_model,), cfg.dtype)}
            )
        else:
            layers.append(
                {"kind_slstm": slstm_init(k, cfg.d_model, cfg.num_heads, cfg.dtype),
                 "norm": jnp.zeros((cfg.d_model,), cfg.dtype)}
            )
    params["layers"] = layers
    return params


def _forward(params, cfg: ArchConfig, tokens, states=None):
    x = params["embed"][tokens]
    chunk = cfg.ssm.chunk if cfg.ssm else 128
    train_mode = tokens.shape[1] > 1 and states is None
    new_states = []

    def mlstm_layer(lp, h):
        return mlstm(lp["kind_mlstm"], h, cfg.num_heads, chunk=chunk)

    def slstm_layer(lp, h):
        return slstm(lp["kind_slstm"], h, cfg.num_heads, state=None)

    if cfg.remat and train_mode:
        mlstm_layer = jax.checkpoint(mlstm_layer)
        slstm_layer = jax.checkpoint(slstm_layer)

    for i, lp in enumerate(params["layers"]):
        h = rms_norm(x, lp["norm"])
        st = states[i] if states is not None else None
        if "kind_mlstm" in lp:
            if tokens.shape[1] == 1 and st is not None:
                out, ns = mlstm_step(lp["kind_mlstm"], h, st, cfg.num_heads)
            elif train_mode:
                out, ns = mlstm_layer(lp, h)
            else:
                out, ns = mlstm(lp["kind_mlstm"], h, cfg.num_heads, chunk=chunk)
        else:
            if tokens.shape[1] == 1 and st is not None:
                out, ns = slstm_step(lp["kind_slstm"], h, st, cfg.num_heads)
            elif train_mode:
                out, ns = slstm_layer(lp, h)
            else:
                out, ns = slstm(lp["kind_slstm"], h, cfg.num_heads, state=st)
        x = x + out
        new_states.append(ns)
    return x, new_states


def xlstm_loss(params, cfg: ArchConfig, batch, **_):
    x, _ = _forward(params, cfg, batch["tokens"])
    logits = rms_norm(x, params["final_norm"]) @ params["lm_head"]
    loss = cross_entropy_loss(logits, batch["labels"])
    return loss, {"ce": loss}


def xlstm_make_states(params, cfg: ArchConfig, batch: int):
    states = []
    dh = cfg.d_model // cfg.num_heads
    for i in range(cfg.num_layers):
        if i % 2 == 0:
            states.append(
                MLSTMState(jnp.zeros((batch, cfg.num_heads, dh, dh + 1), jnp.float32))
            )
        else:
            states.append(slstm_zero_state(batch, cfg.d_model, cfg.num_heads))
    return states


def xlstm_decode_step(params, cfg: ArchConfig, token, states, pos, **_):
    x, new_states = _forward(params, cfg, token, states)
    logits = rms_norm(x, params["final_norm"]) @ params["lm_head"]
    return logits[:, -1], new_states


def xlstm_prefill(params, cfg: ArchConfig, tokens, cache_len=None, **_):
    x, states = _forward(params, cfg, tokens)
    logits = rms_norm(x, params["final_norm"]) @ params["lm_head"]
    return logits[:, -1], states

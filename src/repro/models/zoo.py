"""build_model: ArchConfig -> ModelSpec, for all families.

Also defines input_specs() — the ShapeDtypeStruct stand-ins the multi-pod
dry-run lowers against (weak-type-correct, shardable, no device allocation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import build as lm
from . import hymba as hy
from . import whisper as wh
from . import xlstm as xl
from .api import ArchConfig, ModelSpec, ShapeSpec


def train_input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.frontend == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, cfg.d_model), jnp.float32
        )
    elif cfg.frontend == "vision":
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, cfg.d_model), jnp.float32
        )
    return specs


def decode_input_specs(cfg: ArchConfig, shape: ShapeSpec, spec_caches) -> dict:
    b = shape.global_batch
    return {
        "token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "caches": spec_caches,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def build_model(cfg: ArchConfig, *, mesh=None, data_axes=("data",)) -> ModelSpec:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        def loss_fn(params, batch):
            return lm.lm_loss(params, cfg, batch, mesh=mesh, data_axes=data_axes)

        def make_caches(params, batch, cache_len):
            extra = cfg.frontend_len + cfg.num_meta_tokens
            return lm.lm_make_caches(params, cfg, batch, cache_len + extra)

        def decode_step(params, token, caches, pos):
            return lm.lm_decode_step(
                params, cfg, token, caches, pos, mesh=mesh, data_axes=data_axes
            )

        def prefill(params, batch, cache_len):
            tokens = batch["tokens"] if isinstance(batch, dict) else batch
            return lm.lm_prefill(
                params, cfg, tokens, cache_len, mesh=mesh, data_axes=data_axes
            )

        return ModelSpec(
            cfg=cfg,
            init=functools.partial(lm._lm_init, cfg=cfg),
            loss_fn=loss_fn,
            prefill=prefill,
            decode_step=decode_step,
            make_caches=make_caches,
        )
    if fam == "audio":
        return ModelSpec(
            cfg=cfg,
            init=functools.partial(wh.whisper_init, cfg=cfg),
            loss_fn=lambda p, b: wh.whisper_loss(p, cfg, b),
            prefill=lambda p, b, n: wh.whisper_prefill(p, cfg, b, n),
            decode_step=lambda p, t, c, pos: wh.whisper_decode_step(p, cfg, t, c, pos),
            make_caches=lambda p, b, n: wh.whisper_make_caches(p, cfg, b, n),
        )
    if fam == "ssm":
        return ModelSpec(
            cfg=cfg,
            init=functools.partial(xl.xlstm_init, cfg=cfg),
            loss_fn=lambda p, b: xl.xlstm_loss(p, cfg, b),
            prefill=lambda p, b, n: xl.xlstm_prefill(
                p, cfg, b["tokens"] if isinstance(b, dict) else b
            ),
            decode_step=lambda p, t, c, pos: xl.xlstm_decode_step(p, cfg, t, c, pos),
            make_caches=lambda p, b, n: xl.xlstm_make_states(p, cfg, b),
        )
    if fam == "hybrid":
        return ModelSpec(
            cfg=cfg,
            init=functools.partial(hy.hymba_init, cfg=cfg),
            loss_fn=lambda p, b: hy.hymba_loss(p, cfg, b),
            prefill=lambda p, b, n: hy.hymba_prefill(
                p, cfg, b["tokens"] if isinstance(b, dict) else b, n
            ),
            decode_step=lambda p, t, c, pos: hy.hymba_decode_step(p, cfg, t, c, pos),
            make_caches=lambda p, b, n: hy.hymba_make_caches(p, cfg, b, n),
        )
    raise ValueError(f"unknown family {fam}")


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))

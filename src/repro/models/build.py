"""Decoder-LM assembly for dense/MoE families + dispatch to special families.

Layer stacks are scanned (params stacked on a leading layer axis, lax.scan
over them, jax.checkpoint remat inside) so full-size configs lower to compact
HLO for the 512-device dry-run. Per-layer heterogeneity that fits in arrays
(sliding-window sizes) rides along as scan inputs; structural heterogeneity
(dense-vs-MoE prefix layers) becomes separate stacks.

Distribution: attention/MLP math is plain jnp — XLA SPMD partitions it from
the parameter/activation shardings installed by sharding/rules.py. The MoE FFN
is a shard_map island (explicit EP + psum, models/moe.py) when a mesh is
given; single-device otherwise.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import shard_map
from . import attention as attn_mod
from .api import ArchConfig, ModelSpec
from .attention import (
    KVCache, MLACache, gqa_attention, gqa_init, make_kv_cache, make_mla_cache,
    mla_attention, mla_init,
)
from .layers import (
    cross_entropy_loss, dense_param, embed_param, geglu_mlp, gelu_mlp,
    gelu_mlp_init, rms_norm, softcap, swiglu_mlp, swiglu_mlp_init,
)
from .moe import moe_ffn, moe_init

P = jax.sharding.PartitionSpec


# ------------------------------------------------------------------ blocks

def block_init(rng, cfg: ArchConfig, kind: str) -> dict:
    ks = jax.random.split(rng, 4)
    d, dtype = cfg.d_model, cfg.dtype
    p: dict = {"attn_norm": jnp.zeros((d,), dtype)}
    if cfg.mla is not None:
        p["attn"] = mla_init(ks[0], cfg, dtype)
    else:
        p["attn"] = gqa_init(ks[0], cfg, dtype)
    p["ffn_norm"] = jnp.zeros((d,), dtype)
    if kind == "moe":
        p["moe"] = moe_init(ks[1], cfg, dtype)
    elif cfg.mlp_kind == "gelu":
        p["mlp"] = gelu_mlp_init(ks[1], d, cfg.d_ff, dtype)
    else:
        p["mlp"] = swiglu_mlp_init(ks[1], d, cfg.d_ff, dtype)
    if cfg.sandwich_norm:
        p["post_attn_norm"] = jnp.zeros((d,), dtype)
        p["post_ffn_norm"] = jnp.zeros((d,), dtype)
    return p


def block_apply(
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ArchConfig,
    *,
    kind: str,
    window=None,
    prefix_len=None,
    cache=None,
    mesh=None,
    data_axes=("data",),
    model_axis="model",
):
    h = rms_norm(x, p["attn_norm"])
    if cfg.mla is not None:
        a, new_cache = mla_attention(p["attn"], h, positions, cfg, cache=cache)
    else:
        a, new_cache = gqa_attention(
            p["attn"], h, positions, cfg, window=window, cache=cache,
            prefix_len=prefix_len,
        )
    if cfg.sandwich_norm:
        a = rms_norm(a, p["post_attn_norm"])
    x = x + a

    h = rms_norm(x, p["ffn_norm"])
    aux = jnp.zeros((), jnp.float32)
    if kind == "moe":
        if mesh is not None:
            ep_mode = cfg.ep_over_data
            if ep_mode:
                # serving EP: experts over every axis, activations replicated
                ep_axes = (*data_axes, model_axis)
                x_spec, out_spec = P(), P()
                reduce_axes = ()
            else:
                ep_axes = model_axis
                x_spec = out_spec = P(data_axes, None, None)
                reduce_axes = data_axes
            if cfg.activation_constraints and not ep_mode:
                h = jax.lax.with_sharding_constraint(
                    h, jax.sharding.NamedSharding(mesh, x_spec)
                )
            moe_fn = functools.partial(moe_ffn, cfg=cfg, model_axis=ep_axes)

            def wrapped(params, hx):
                out, aux_l = moe_fn(params, hx)
                if reduce_axes:
                    aux_l = jax.lax.pmean(aux_l, reduce_axes)
                return out, aux_l

            specs_in = (
                {
                    "router": P(),
                    "expert_gate": P(ep_axes, None, None),
                    "expert_up": P(ep_axes, None, None),
                    "expert_down": P(ep_axes, None, None),
                    **(
                        {
                            "shared_gate": P(None, ep_axes),
                            "shared_up": P(None, ep_axes),
                            "shared_down": P(ep_axes, None),
                        }
                        if "shared_gate" in p["moe"]
                        else {}
                    ),
                },
                x_spec,
            )
            f, aux = shard_map(
                wrapped, mesh=mesh, in_specs=specs_in,
                out_specs=(out_spec, P()),
            )(p["moe"], h)
        else:
            f, aux = moe_ffn(p["moe"], h, cfg, model_axis=None)
    elif cfg.mlp_kind == "gelu":
        f = gelu_mlp(p["mlp"], h)
    elif cfg.mlp_kind == "geglu":
        f = geglu_mlp(p["mlp"], h)
    else:
        f = swiglu_mlp(p["mlp"], h)
    if cfg.sandwich_norm:
        f = rms_norm(f, p["post_ffn_norm"])
    return x + f, new_cache, aux


# ------------------------------------------------------------- layer stacks

def stack_params(per_layer: list[dict]) -> dict:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)


def layer_windows(cfg: ArchConfig, num_layers: int, offset: int = 0) -> np.ndarray:
    """Per-layer sliding window (0 = global), as a scannable int32 array."""
    w = np.zeros(num_layers, np.int32)
    if cfg.window_pattern == "alternating" and cfg.sliding_window:
        for i in range(num_layers):
            if (i + offset) % 2 == 0:
                w[i] = cfg.sliding_window
    elif cfg.window_pattern == "hymba" and cfg.sliding_window:
        w[:] = cfg.sliding_window
        for g in (0, num_layers // 2, num_layers - 1):
            w[g] = 0
    return w


def apply_stack(
    stack: dict,
    windows: jax.Array,
    x: jax.Array,
    positions: jax.Array,
    cfg: ArchConfig,
    *,
    kind: str,
    caches=None,
    prefix_len=None,
    mesh=None,
    data_axes=("data",),
):
    """Scan (or unrolled loop) over a homogeneous layer stack."""
    num_layers = windows.shape[0]

    def body(carry, layer):
        xc, aux_acc = carry
        p_l, w_l, cache_l = layer
        out, new_cache, aux = block_apply(
            p_l, xc, positions, cfg, kind=kind, window=w_l, cache=cache_l,
            prefix_len=prefix_len, mesh=mesh, data_axes=data_axes,
        )
        if mesh is not None and cfg.activation_constraints:
            out = jax.lax.with_sharding_constraint(
                out, jax.sharding.NamedSharding(mesh, P(data_axes, None, None))
            )
        return (out, aux_acc + aux), new_cache

    if cfg.scan_layers:
        wrapped = jax.checkpoint(body) if cfg.remat else body
        (x, aux), new_caches = jax.lax.scan(
            wrapped, (x, jnp.zeros((), jnp.float32)), (stack, windows, caches)
        )
    else:
        wrapped = jax.checkpoint(body) if (cfg.remat and caches is None) else body
        aux = jnp.zeros((), jnp.float32)
        new_list = []
        for i in range(num_layers):
            p_l = jax.tree.map(lambda a: a[i], stack)
            cache_l = jax.tree.map(lambda a: a[i], caches) if caches is not None else None
            (x, aux), nc = wrapped((x, aux), (p_l, windows[i], cache_l))
            new_list.append(nc)
        new_caches = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *new_list)
            if new_list and new_list[0] is not None
            else None
        )
    return x, aux, new_caches


# ----------------------------------------------------------- decoder LM

def _lm_init(rng, cfg: ArchConfig):
    ks = jax.random.split(rng, 8)
    n_dense = cfg.num_dense_layers if cfg.moe else cfg.num_layers
    n_moe = cfg.num_layers - n_dense if cfg.moe else 0
    params: dict = {
        "embed": embed_param(ks[0], cfg.vocab, cfg.d_model, cfg.dtype),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_param(ks[1], cfg.d_model, cfg.vocab, cfg.dtype)
    if n_dense:
        params["dense_stack"] = stack_params(
            [block_init(k, cfg, "dense") for k in jax.random.split(ks[2], n_dense)]
        )
    if n_moe:
        params["moe_stack"] = stack_params(
            [block_init(k, cfg, "moe") for k in jax.random.split(ks[3], n_moe)]
        )
    if cfg.mtp:
        params["mtp_proj"] = dense_param(ks[4], 2 * cfg.d_model, cfg.d_model, cfg.dtype)
        params["mtp_block"] = block_init(ks[5], cfg, "dense")
        params["mtp_norm"] = jnp.zeros((cfg.d_model,), cfg.dtype)
    if cfg.num_meta_tokens:
        params["meta_tokens"] = (
            jax.random.normal(ks[6], (cfg.num_meta_tokens, cfg.d_model), jnp.float32) * 0.02
        ).astype(cfg.dtype)
    return params


def _stacks(cfg: ArchConfig):
    n_dense = cfg.num_dense_layers if cfg.moe else cfg.num_layers
    n_moe = cfg.num_layers - n_dense if cfg.moe else 0
    out = []
    if n_dense:
        out.append(("dense_stack", "dense", n_dense, 0))
    if n_moe:
        out.append(("moe_stack", "moe", n_moe, n_dense))
    return out


def _embed(params, cfg, tokens):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = (x.astype(jnp.float32) * cfg.d_model**0.5).astype(x.dtype)
    return x


def _unembed(params, cfg, x):
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return softcap(logits, cfg.final_softcap)


def lm_forward(
    params, cfg: ArchConfig, tokens, *, caches=None, positions=None,
    mesh=None, data_axes=("data",), prefix_embeds=None,
):
    """Shared trunk: embeddings -> stacks -> hidden states (+ new caches)."""
    b, s = tokens.shape
    x = _embed(params, cfg, tokens)
    # prefixes (meta tokens / frontend embeds) are prepended on parallel
    # passes (train & prefill, s > 1); during decode they already sit in cache
    if params.get("meta_tokens") is not None and s > 1:
        meta = jnp.broadcast_to(
            params["meta_tokens"][None], (b, cfg.num_meta_tokens, cfg.d_model)
        ).astype(x.dtype)
        x = jnp.concatenate([meta, x], axis=1)
    if prefix_embeds is not None and s > 1:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    s_eff = x.shape[1]
    if positions is None:
        positions = jnp.arange(s_eff)
    prefix_len = (s_eff - s) if (cfg.prefix_lm and s_eff > s) else None
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: dict = {}
    for stack_name, kind, n_layers, offset in _stacks(cfg):
        windows = jnp.asarray(layer_windows(cfg, n_layers, offset))
        stack_caches = caches.get(stack_name) if caches is not None else None
        x, aux, nc = apply_stack(
            params[stack_name], windows, x, positions, cfg, kind=kind,
            caches=stack_caches, prefix_len=prefix_len, mesh=mesh,
            data_axes=data_axes,
        )
        aux_total += aux
        new_caches[stack_name] = nc
    return x, aux_total, (new_caches if caches is not None else None)


def lm_loss(params, cfg: ArchConfig, batch, *, mesh=None, data_axes=("data",)):
    tokens, labels = batch["tokens"], batch["labels"]
    prefix = batch.get("prefix_embeds")
    x, aux, _ = lm_forward(
        params, cfg, tokens, mesh=mesh, data_axes=data_axes, prefix_embeds=prefix,
    )
    # strip any prefix (meta tokens / frontend embeds) before the LM head
    strip = x.shape[1] - tokens.shape[1]
    if strip:
        x = x[:, strip:]
    logits = _unembed(params, cfg, x)
    loss = cross_entropy_loss(logits, labels)
    metrics = {"ce": loss, "aux": aux}
    if cfg.mtp:
        h = x[:, :-1]
        nxt = _embed(params, cfg, tokens[:, 1:])
        m_in = jnp.concatenate([h, nxt], axis=-1) @ params["mtp_proj"]
        m_in = rms_norm(m_in, params["mtp_norm"])
        pos = jnp.arange(m_in.shape[1])
        m_out = block_apply(
            params["mtp_block"], m_in, pos, cfg, kind="dense",
            mesh=mesh, data_axes=data_axes,
        )[0]
        mtp_logits = _unembed(params, cfg, m_out)
        mtp_loss = cross_entropy_loss(mtp_logits[:, :-1], labels[:, 2:])
        loss = loss + cfg.mtp_weight * mtp_loss
        metrics["mtp"] = mtp_loss
    loss = loss + aux
    return loss, metrics


# ----------------------------------------------------------- serve paths

def lm_make_caches(params, cfg: ArchConfig, batch: int, cache_len: int):
    caches = {}
    for stack_name, kind, n_layers, _ in _stacks(cfg):
        if cfg.mla is not None:
            one = make_mla_cache(cfg, batch, cache_len, cfg.dtype)
        else:
            one = make_kv_cache(cfg, batch, cache_len, cfg.dtype)
        caches[stack_name] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_layers, *a.shape)), one
        )
    return caches


def lm_decode_step(
    params, cfg: ArchConfig, token, caches, pos, *, mesh=None, data_axes=("data",)
):
    """One decode step: token [B,1] + caches at absolute position `pos`."""
    positions = jnp.reshape(jnp.asarray(pos), (1,))
    x, _, new_caches = lm_forward(
        params, cfg, token, caches=caches, positions=positions,
        mesh=mesh, data_axes=data_axes,
    )
    logits = _unembed(params, cfg, x)[:, -1]
    return logits, new_caches


def lm_prefill(params, cfg: ArchConfig, tokens, cache_len, *, mesh=None,
               data_axes=("data",)):
    """Parallel prefill that also fills decode caches: the whole prompt's k/v
    block is written at cache offset 0 in one dynamic_update_slice per layer
    (positions = arange(s), so attention is causal within the prompt)."""
    caches = lm_make_caches(params, cfg, tokens.shape[0], cache_len)
    x, _, new_caches = lm_forward(
        params, cfg, tokens, caches=caches, mesh=mesh, data_axes=data_axes,
    )
    logits = _unembed(params, cfg, x)[:, -1]
    return logits, new_caches

"""Attention variants for the model zoo: GQA (+qk-norm, sliding window,
softcap) and Multi-head Latent Attention (DeepSeek-V2/V3 MLA).

All functions are pure; caches are explicit (carried through serve steps).
The dense jnp path is the default (portable + SPMD-partitionable by XLA);
kernels/flash_attention.py is the TPU hot-path drop-in for train/prefill
(selected via cfg.use_flash_kernel on real hardware).

Cache layouts (decode):
  GQA: k,v [batch, kv_heads, cache_len, head_dim]   (cache_len shardable)
  MLA: c_kv [batch, cache_len, kv_lora + rope_dim]  (compressed, per paper)
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import dense_param, rms_norm, rope, softcap


class KVCache(NamedTuple):
    k: jax.Array
    v: jax.Array


class MLACache(NamedTuple):
    c_kv: jax.Array   # [batch, cache, kv_lora + rope_dim]


# --------------------------------------------------------------------- GQA

def gqa_init(rng, cfg, layer_dtype) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 6)
    p = {
        "w_q": dense_param(ks[0], d, hq * hd, layer_dtype),
        "w_k": dense_param(ks[1], d, hkv * hd, layer_dtype),
        "w_v": dense_param(ks[2], d, hkv * hd, layer_dtype),
        "w_o": dense_param(ks[3], hq * hd, d, layer_dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), layer_dtype)
        p["k_norm"] = jnp.zeros((hd,), layer_dtype)
    return p


def _mask_bias(q_pos, k_pos, *, causal: bool, window, prefix_len=None) -> jax.Array:
    """Additive mask [q, k] in f32; `window` may be a traced scalar (<=0 means
    no window) so alternating local/global layers can share one scanned body.
    `prefix_len` enables prefix-LM masking (bidirectional within the prefix —
    paligemma's image+prompt region)."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        w = jnp.asarray(window)
        in_window = (q_pos[:, None] - k_pos[None, :]) < w
        ok &= in_window | (w <= 0)
    if prefix_len is not None:
        both_prefix = (q_pos[:, None] < prefix_len) & (k_pos[None, :] < prefix_len)
        ok |= both_prefix
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


_BLOCKED_ATTN_THRESHOLD = 16 * 2**20   # s_q * s_k above which we block
_Q_BLOCK = 512


def _blocked_scores_attention(
    qg, k, v, q_pos, k_pos, *, scale, attn_softcap, causal, window, prefix_len,
    valid,
):
    """Flash-pattern attention in pure jnp: scan over query blocks so only
    [q_block, s_k] scores materialise (XLA/SPMD-friendly; the Pallas kernel
    kernels/flash_attention.py is the TPU drop-in). qg: [b, hkv, g, s, d]."""
    b, hkv, g, s, d = qg.shape
    qb = _Q_BLOCK
    pad = (-s) % qb
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad), constant_values=q_pos[-1])
    nb = qg.shape[3] // qb
    qg = qg.reshape(b, hkv, g, nb, qb, d)
    q_pos_b = q_pos.reshape(nb, qb)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def body(_, inputs):
        q_blk, qp = inputs                       # [b,hkv,g,qb,d], [qb]
        s_blk = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk.astype(jnp.float32), kf)
        s_blk = s_blk * scale
        s_blk = softcap(s_blk, attn_softcap)
        bias = _mask_bias(qp, k_pos, causal=causal, window=window,
                          prefix_len=prefix_len)
        if valid is not None:
            bias = bias + jnp.where(valid, 0.0, -1e30)[None, :]
        p = jax.nn.softmax(s_blk + bias, axis=-1)
        return None, jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)

    xs = (qg.transpose(3, 0, 1, 2, 4, 5), q_pos_b)
    _, out = jax.lax.scan(jax.checkpoint(body), None, xs)
    dv = v.shape[-1]  # may differ from the qk head dim (MLA)
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, g, nb * qb, dv)
    return out[:, :, :, :s]


def gqa_attention(
    params: dict,
    x: jax.Array,                  # [batch, seq, d_model]
    positions: jax.Array,          # [seq] (absolute)
    cfg,
    *,
    causal: bool = True,
    window=None,                   # None | int | traced scalar (<=0 => global)
    prefix_len=None,               # prefix-LM bidirectional region
    cache: KVCache | None = None,  # decode: append & attend over cache
    cross_kv: tuple | None = None, # encoder K/V for cross-attention
) -> tuple[jax.Array, KVCache | None]:
    b, s, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["w_q"]).reshape(b, s, hq, hd).transpose(0, 2, 1, 3)
    if cross_kv is None:
        k = (x @ params["w_k"]).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
        v = (x @ params["w_v"]).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    else:
        k, v = cross_kv
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        if cross_kv is None:
            k = rms_norm(k, params["k_norm"])
    if cfg.use_rope and cross_kv is None:
        q = rope(q, positions[None, None, :], theta=cfg.rope_theta)
        k = rope(k, positions[None, None, :], theta=cfg.rope_theta)

    new_cache = None
    if cache is not None and cross_kv is None:
        # decode (s=1) or prefill (s=seq): write k/v block at positions[0]
        idx = positions[0]
        k_full = jax.lax.dynamic_update_slice(cache.k, k, (0, 0, idx, 0))
        v_full = jax.lax.dynamic_update_slice(cache.v, v, (0, 0, idx, 0))
        new_cache = KVCache(k_full, v_full)
        k, v = k_full, v_full
        k_pos = jnp.arange(k.shape[2])
        valid = k_pos <= positions[-1]
    else:
        k_pos = positions if cross_kv is None else jnp.arange(k.shape[2])
        valid = None

    group = hq // k.shape[1]
    qg = q.reshape(b, k.shape[1], group, s, hd)
    scale = cfg.head_dim**-0.5 if cfg.attn_scale is None else cfg.attn_scale
    eff_causal = causal and cross_kv is None
    eff_window = window if cross_kv is None else None
    eff_prefix = prefix_len if cross_kv is None else None
    if s * k.shape[2] >= _BLOCKED_ATTN_THRESHOLD and s > 1:
        out = _blocked_scores_attention(
            qg, k, v, positions, k_pos,
            scale=scale, attn_softcap=cfg.attn_softcap,
            causal=eff_causal, window=eff_window, prefix_len=eff_prefix,
            valid=valid,
        )
    else:
        scores = jnp.einsum(
            "bhgqd,bhkd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32)
        ) * scale
        scores = softcap(scores, cfg.attn_softcap)
        bias = _mask_bias(positions, k_pos, causal=eff_causal,
                          window=eff_window, prefix_len=eff_prefix)
        if valid is not None:
            bias = bias + jnp.where(valid, 0.0, -1e30)[None, :]
        scores = scores + bias
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v.astype(jnp.float32))
    out = out.reshape(b, hq, s, hd).transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    return (out.astype(x.dtype) @ params["w_o"]), new_cache


def make_kv_cache(cfg, batch: int, cache_len: int, dtype) -> KVCache:
    shape = (batch, cfg.num_kv_heads, cache_len, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


# --------------------------------------------------------------------- MLA

def mla_init(rng, cfg, dtype) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    m = cfg.mla
    ks = jax.random.split(rng, 8)
    qk_head = m.qk_nope_dim + m.rope_dim
    p = {
        # query path (low-rank)
        "w_dq": dense_param(ks[0], d, m.q_lora, dtype),
        "q_norm": jnp.zeros((m.q_lora,), dtype),
        "w_uq": dense_param(ks[1], m.q_lora, h * qk_head, dtype),
        # kv path (compressed latent + decoupled rope key)
        "w_dkv": dense_param(ks[2], d, m.kv_lora + m.rope_dim, dtype),
        "kv_norm": jnp.zeros((m.kv_lora,), dtype),
        "w_uk": dense_param(ks[3], m.kv_lora, h * m.qk_nope_dim, dtype),
        "w_uv": dense_param(ks[4], m.kv_lora, h * m.v_dim, dtype),
        "w_o": dense_param(ks[5], h * m.v_dim, d, dtype),
    }
    return p


def mla_attention(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg,
    *,
    cache: MLACache | None = None,
) -> tuple[jax.Array, MLACache | None]:
    """DeepSeek MLA: queries/keys split into a latent 'nope' part and a shared
    rope part; only the compressed latent + rope key is cached (576/token for
    V3) — the property that makes long-context decode caches small."""
    b, s, d = x.shape
    h, m = cfg.num_heads, cfg.mla

    cq = rms_norm(x @ params["w_dq"], params["q_norm"])
    q = (cq @ params["w_uq"]).reshape(b, s, h, m.qk_nope_dim + m.rope_dim)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    q_rope = rope(
        q_rope.transpose(0, 2, 1, 3), positions[None, None, :], theta=cfg.rope_theta
    ).transpose(0, 2, 1, 3)

    ckv_full = x @ params["w_dkv"]                     # [b, s, kv_lora+rope]
    c_kv, k_rope = ckv_full[..., : m.kv_lora], ckv_full[..., m.kv_lora :]
    k_rope = rope(k_rope[:, None], positions[None, None, :], theta=cfg.rope_theta)[
        :, 0
    ]                                                   # [b, s, rope] shared

    new_cache = None
    if cache is not None:
        idx = positions[0]
        packed = jnp.concatenate([c_kv, k_rope], axis=-1)
        full = jax.lax.dynamic_update_slice(cache.c_kv, packed, (0, idx, 0))
        new_cache = MLACache(full)
        c_kv, k_rope = full[..., : m.kv_lora], full[..., m.kv_lora :]
        k_pos = jnp.arange(c_kv.shape[1])
        valid = k_pos <= positions[-1]
    else:
        k_pos = positions
        valid = None

    c_kv = rms_norm(c_kv, params["kv_norm"])
    t = c_kv.shape[1]
    k_nope = (c_kv @ params["w_uk"]).reshape(b, t, h, m.qk_nope_dim)
    v = (c_kv @ params["w_uv"]).reshape(b, t, h, m.v_dim)

    scale = (m.qk_nope_dim + m.rope_dim) ** -0.5
    if s * t >= _BLOCKED_ATTN_THRESHOLD and s > 1:
        # fold the shared rope key into the head dim and reuse the blocked path
        q_cat = jnp.concatenate([q_nope, q_rope], -1)          # [b,s,h,dk]
        k_cat = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None], (b, t, h, m.rope_dim))],
            -1,
        )
        qg = q_cat.transpose(0, 2, 1, 3)[:, :, None]           # [b,h,1,s,dk]
        out = _blocked_scores_attention(
            qg, k_cat.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            positions, k_pos,
            scale=scale, attn_softcap=None, causal=True, window=None,
            prefix_len=None, valid=valid,
        )                                                       # [b,h,1,s,vd]
        out = out[:, :, 0].transpose(0, 2, 1, 3)                # [b,s,h,vd]
    else:
        scores = (
            jnp.einsum("bqhd,bkhd->bhqk", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32))
            + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32))
        ) * scale
        bias = _mask_bias(positions, k_pos, causal=True, window=None)
        if valid is not None:
            bias = bias + jnp.where(valid, 0.0, -1e30)[None, :]
        probs = jax.nn.softmax(scores + bias, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    out = out.reshape(b, s, h * m.v_dim).astype(x.dtype)
    return out @ params["w_o"], new_cache


def make_mla_cache(cfg, batch: int, cache_len: int, dtype) -> MLACache:
    m = cfg.mla
    return MLACache(jnp.zeros((batch, cache_len, m.kv_lora + m.rope_dim), dtype))

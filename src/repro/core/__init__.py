"""Core library: monomorphism-based CGRA mapping via space/time decoupling.

The paper's contribution lives here: schedule.py (ASAP/ALAP/MobS/KMS/mII),
time_smt.py (SMT time solution), space_backends/ (pluggable space solution:
exact bitset monomorphism + annealing/clustered placement, DESIGN.md §13),
mapper.py (the decoupled pipeline), baseline.py (joint SAT-MapIt-style
comparison target), benchsuite.py (Table III DFG suite), simulate.py
(functional validation), placement.py (the same algorithm placing model stage
graphs onto TPU pod meshes), arch/ (declarative heterogeneous architecture
specs: capability classes, topology families, memory ports — DESIGN.md §10).
"""

from .arch import ArchSpec, get_preset, list_presets, resolve_arch
from .cgra import CAP_CLASSES, CGRA, MRRG, op_class
from .dfg import DFG, Edge, Route, running_example, splice_routes
from .mapper import Mapping, MapResult, map_dfg
from .mono import check_monomorphism, check_routes, find_monomorphism
from .schedule import (
    KMS,
    MobilitySchedule,
    alap_schedule,
    asap_schedule,
    min_ii,
    mobility_schedule,
    rec_ii,
    res_ii,
)
from .space_backends import (
    SpaceBudget,
    available_space_backends,
    resolve_space_backend,
)
from .time_smt import (
    TimeSolution,
    TimeSolver,
    available_backends,
    check_time_solution,
)

__all__ = [
    "ArchSpec", "get_preset", "list_presets", "resolve_arch",
    "CAP_CLASSES", "op_class",
    "CGRA", "MRRG", "DFG", "Edge", "Route", "running_example", "splice_routes",
    "Mapping", "MapResult", "map_dfg",
    "check_monomorphism", "check_routes", "find_monomorphism",
    "SpaceBudget", "available_space_backends", "resolve_space_backend",
    "KMS", "MobilitySchedule", "alap_schedule", "asap_schedule",
    "min_ii", "mobility_schedule", "rec_ii", "res_ii",
    "TimeSolution", "TimeSolver", "check_time_solution", "available_backends",
]

"""Data-flow graph (DFG) representation for CGRA mapping.

A DFG models one loop body after LLVM-style extraction: nodes are single-cycle
operations (loads, ALU ops, stores), edges are data dependencies. Loop-carried
dependencies close recurrence cycles with an iteration *distance* (usually 1).

The paper (§IV-A) ultimately treats the DFG as an *undirected, labelled* graph
once a time solution is found; we keep the directed + distance-annotated form as
the source of truth and derive the undirected view on demand.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Iterable, Mapping, Sequence

# Operation kinds understood by the functional simulator (core/simulate.py) and
# the cgra_sim Pallas kernel. Arity is used by DFG validation.
OP_ARITY = {
    "input": 0,   # live-in (loop invariant or streamed input)
    "const": 0,
    "load": 1,    # load base+offset (address operand)
    "store": 1,   # value operand (address folded into the op immediate)
    "add": 2,
    "sub": 2,
    "mul": 2,
    "div": 2,
    "and": 2,
    "or": 2,
    "xor": 2,
    "shl": 2,
    "shr": 2,
    "min": 2,
    "max": 2,
    "neg": 1,
    "not": 1,
    "abs": 1,
    "mov": 1,     # copy / route-through
    "phi": 2,     # loop-carried merge
    "cmp": 2,
}


@dataclass(frozen=True)
class Edge:
    """Directed dependency src -> dst.

    distance == 0: intra-iteration data dependency.
    distance >= 1: loop-carried dependency (value produced `distance`
    iterations before it is consumed).

    ``port`` pins the edge to an explicit operand slot of ``dst`` (0 = first
    operand). -1 (the default) means "unpinned": the canonical operand order
    is then ``(distance, src)``, which is what every frontend produces. The
    route-through rewrite (:func:`splice_routes`) pins ports on the consumers
    it touches so replacing a producer with a ``mov`` chain cannot reorder
    the operands of a non-commutative op.
    """

    src: int
    dst: int
    distance: int = 0
    port: int = -1

    def __post_init__(self) -> None:
        if self.distance < 0:
            raise ValueError(f"negative dependency distance on edge {self}")
        if self.port < -1:
            raise ValueError(f"invalid operand port on edge {self}")

    def _operand_key(self) -> tuple:
        # pinned ports order first among themselves; unpinned edges keep the
        # historical (distance, src) order — a node's in-edges are either all
        # pinned (route-through rewrite) or all unpinned (frontends)
        return (0, self.port) if self.port >= 0 else (1, self.distance, self.src)


@dataclass
class DFG:
    """A directed data-flow graph with loop-carried distances.

    The compiler's input: one loop body whose nodes are single-cycle ops and
    whose edges carry an iteration *distance* (0 = intra-iteration,
    ≥1 = loop-carried). A mapping assigns each node an absolute time
    (*label* ``t mod II`` + *fold* ``t div II``, DESIGN.md §1) and a PE.

    Example — a 2-node accumulator with a distance-1 recurrence::

        from repro.core import DFG, Edge

        dfg = DFG(num_nodes=2, ops=["input", "add"],
                  edges=[Edge(0, 1), Edge(1, 1, distance=1)],
                  name="acc")
        dfg.validate()              # intra-iteration part must be a DAG
        assert dfg.rec_ii() == 1    # 1-edge cycle / distance 1
        text = dfg.to_json()        # round-trips via DFG.from_json
        assert DFG.from_json(text).stable_hash() == dfg.stable_hash()

    ``stable_hash()`` is the content address used by both mapping-cache
    layers; ``name`` and ``imms`` are deliberately excluded from it.
    """

    num_nodes: int
    edges: list[Edge]
    ops: list[str] = field(default_factory=list)
    name: str = "dfg"
    # Optional per-node immediate (e.g. constant value / address offset).
    imms: list[float] = field(default_factory=list)

    def __post_init__(self) -> None:
        if not self.ops:
            self.ops = ["add"] * self.num_nodes
        if not self.imms:
            self.imms = [0.0] * self.num_nodes
        if len(self.ops) != self.num_nodes or len(self.imms) != self.num_nodes:
            raise ValueError(f"{self.name}: ops/imms length mismatch with num_nodes")
        for e in self.edges:
            if not (0 <= e.src < self.num_nodes and 0 <= e.dst < self.num_nodes):
                raise ValueError(f"{self.name}: edge {e} out of range")

    # ------------------------------------------------------------------ views
    @property
    def nodes(self) -> range:
        return range(self.num_nodes)

    def predecessors(self, v: int, *, carried: bool | None = None) -> list[Edge]:
        return [
            e
            for e in self.edges
            if e.dst == v
            and (carried is None or (e.distance > 0) == carried)
        ]

    def successors(self, v: int, *, carried: bool | None = None) -> list[Edge]:
        return [
            e
            for e in self.edges
            if e.src == v
            and (carried is None or (e.distance > 0) == carried)
        ]

    def operands(self, v: int) -> list[Edge]:
        """The canonical operand order of node ``v``.

        Single source of truth shared by the scalar oracle
        (``simulate._operands``) and the Pallas program builder
        (``kernels/ops.py``): explicit ``Edge.port`` pins win, unpinned edges
        fall back to the historical ``(distance, src)`` order.
        """
        return sorted(self.predecessors(v), key=Edge._operand_key)

    def undirected_adjacency(self) -> list[set[int]]:
        """Paper §IV-B: after scheduling, edge direction is dropped."""
        adj: list[set[int]] = [set() for _ in self.nodes]
        for e in self.edges:
            if e.src != e.dst:
                adj[e.src].add(e.dst)
                adj[e.dst].add(e.src)
        return adj

    def intra_edges(self) -> list[Edge]:
        return [e for e in self.edges if e.distance == 0]

    def carried_edges(self) -> list[Edge]:
        return [e for e in self.edges if e.distance > 0]

    # ------------------------------------------------------------- validation
    def validate(self) -> None:
        """Check the intra-iteration subgraph is a DAG and arities are sane."""
        indeg = [0] * self.num_nodes
        adj: list[list[int]] = [[] for _ in self.nodes]
        for e in self.intra_edges():
            adj[e.src].append(e.dst)
            indeg[e.dst] += 1
        frontier = [v for v in self.nodes if indeg[v] == 0]
        seen = 0
        while frontier:
            v = frontier.pop()
            seen += 1
            for w in adj[v]:
                indeg[w] -= 1
                if indeg[w] == 0:
                    frontier.append(w)
        if seen != self.num_nodes:
            raise ValueError(f"{self.name}: intra-iteration dependency cycle (needs distance>=1)")
        for v in self.nodes:
            op = self.ops[v]
            if op not in OP_ARITY:
                raise ValueError(f"{self.name}: unknown op {op!r} at node {v}")
            np_ = len(self.predecessors(v))
            if op in ("input", "const") and np_ != 0:
                raise ValueError(f"{self.name}: node {v} ({op}) must have no inputs")
            if OP_ARITY[op] > 0 and np_ > OP_ARITY[op]:
                raise ValueError(
                    f"{self.name}: node {v} ({op}) has {np_} inputs > arity {OP_ARITY[op]}"
                )

    # ---------------------------------------------------------- recurrence II
    def rec_ii(self) -> int:
        """RecII = max over dependence cycles of ceil(length/distance).

        Single-cycle ops => cycle length = #edges in the cycle. Computed with a
        Bellman-Ford style iteration: for a candidate II, edge (u,v,dist) imposes
        t_v >= t_u + 1 - II*dist; a positive cycle in that constraint graph means
        II is infeasible. RecII is the smallest feasible II. DFG sizes here are
        tens of nodes, so the O(V*E*II) search is trivial.
        """
        if not self.edges:
            return 1
        max_ii = max(2, self.num_nodes + 1)
        for ii in range(1, max_ii + 1):
            if self._feasible_ii(ii):
                return ii
        return max_ii

    def _feasible_ii(self, ii: int) -> bool:
        dist = [0] * self.num_nodes
        for _ in range(self.num_nodes):
            changed = False
            for e in self.edges:
                w = 1 - ii * e.distance
                if dist[e.src] + w > dist[e.dst]:
                    dist[e.dst] = dist[e.src] + w
                    changed = True
            if not changed:
                return True
        # one more relaxation round: still-changing => positive cycle
        for e in self.edges:
            if dist[e.src] + (1 - ii * e.distance) > dist[e.dst]:
                return False
        return True

    def stable_hash(self) -> str:
        """Content hash over the mapping-relevant structure (nodes + edges).

        Used as the mapping-cache key (core/mapper.py): two DFGs with the same
        hash admit exactly the same space-time mappings. ``imms``/``name`` are
        excluded — they do not affect mapping feasibility.
        """
        import hashlib

        payload = json.dumps(
            {
                "n": self.num_nodes,
                "ops": self.ops,
                "edges": sorted((e.src, e.dst, e.distance) for e in self.edges),
            },
            sort_keys=True,
            separators=(",", ":"),
        )
        return hashlib.sha1(payload.encode()).hexdigest()

    # ------------------------------------------------------------------- I/O
    def to_json(self) -> str:
        return json.dumps(
            {
                "name": self.name,
                "num_nodes": self.num_nodes,
                "ops": self.ops,
                "imms": self.imms,
                "edges": [
                    [e.src, e.dst, e.distance] if e.port < 0
                    else [e.src, e.dst, e.distance, e.port]
                    for e in self.edges
                ],
            },
            indent=2,
        )

    @classmethod
    def from_json(cls, text: str) -> "DFG":
        d = json.loads(text)
        return cls(
            num_nodes=d["num_nodes"],
            edges=[Edge(*e) for e in d["edges"]],
            ops=d.get("ops", []),
            imms=d.get("imms", []),
            name=d.get("name", "dfg"),
        )

    @classmethod
    def from_edge_list(
        cls,
        num_nodes: int,
        edges: Iterable[tuple[int, int] | tuple[int, int, int]],
        *,
        ops: Sequence[str] | None = None,
        name: str = "dfg",
    ) -> "DFG":
        es = [Edge(*((*e, 0)[:3])) for e in edges]
        return cls(num_nodes=num_nodes, edges=es, ops=list(ops or []), name=name)


# ------------------------------------------------------- route-through rewrite

@dataclass(frozen=True)
class Route:
    """Provenance of one route-through rewrite (DESIGN.md §12.2).

    The original edge ``src -> dst`` (with its loop-carried ``distance``) was
    replaced by the chain ``src -> movs[0] -> ... -> movs[-1] -> dst``; every
    intermediate is a ``mov`` node appended to the rewritten DFG, and only the
    final chain edge keeps the original distance. Mapping results carry these
    so consumers can report placements of *original* nodes (ids below
    ``Route.movs`` are unchanged by construction) and both cache layers can
    reconstruct the rewritten DFG from ``(src, dst, distance, len(movs))``.
    """

    src: int
    dst: int
    distance: int
    movs: tuple[int, ...]

    def spec(self) -> tuple[int, int, int, int]:
        """The compact JSON-able form both mapping caches store."""
        return (self.src, self.dst, self.distance, len(self.movs))


def splice_routes(
    dfg: DFG, specs: Sequence[tuple[int, int, int, int]]
) -> tuple[DFG, list[Route]]:
    """Rewrite ``dfg`` by splicing ``mov`` chains onto the given edges.

    ``specs`` is a sequence of ``(src, dst, distance, n_movs)`` — one per
    rewritten edge, each matching a distinct existing edge (duplicated edges
    are consumed first-to-last). Mov node ids are allocated contiguously from
    ``dfg.num_nodes`` in spec order, so original node ids (and therefore
    input/store identities) are preserved. Operand order of every touched
    consumer is pinned via explicit edge ports *before* the rewrite, so the
    rewritten DFG computes exactly what the original does (the movs are
    identity ops) — including non-commutative consumers.

    Returns ``(routed_dfg, routes)``; raises ValueError when a spec matches
    no remaining edge or asks for zero movs.
    """
    edges = list(dfg.edges)
    consumed: set[int] = set()
    ops = list(dfg.ops)
    imms = list(dfg.imms)
    routes: list[Route] = []
    next_id = dfg.num_nodes

    # pin operand order on every dst a rewrite touches (ports reflect the
    # original canonical order, so untouched consumers keep their semantics)
    touched = {dst for (_s, dst, _d, _n) in specs}
    port_of: dict[int, int] = {}        # edge index -> pinned port
    for v in touched:
        idxs = [i for i, e in enumerate(edges) if e.dst == v]
        idxs.sort(key=lambda i: edges[i]._operand_key())
        for slot, i in enumerate(idxs):
            port_of[i] = slot
    for i, slot in port_of.items():
        e = edges[i]
        edges[i] = Edge(e.src, e.dst, e.distance, port=slot)

    new_edges: list[Edge] = []
    for src, dst, distance, n_movs in specs:
        if n_movs < 1:
            raise ValueError(f"route on edge ({src},{dst},{distance}) has no movs")
        idx = next(
            (i for i, e in enumerate(edges)
             if i not in consumed
             and (e.src, e.dst, e.distance) == (src, dst, distance)),
            None,
        )
        if idx is None:
            raise ValueError(
                f"no unrouted edge ({src},{dst},{distance}) in {dfg.name!r}"
            )
        consumed.add(idx)
        movs = tuple(range(next_id, next_id + n_movs))
        next_id += n_movs
        ops.extend("mov" for _ in movs)
        imms.extend(0.0 for _ in movs)
        prev = src
        for m in movs:
            new_edges.append(Edge(prev, m, 0))
            prev = m
        # the final hop keeps the original distance and the pinned port
        edges[idx] = Edge(prev, dst, distance, port=edges[idx].port)
        routes.append(Route(src=src, dst=dst, distance=distance, movs=movs))

    routed = DFG(
        num_nodes=next_id,
        edges=edges + new_edges,
        ops=ops,
        imms=imms,
        name=dfg.name,
    )
    return routed, routes


def running_example() -> DFG:
    """The paper's 14-node running example (Fig. 2a), reconstructed.

    Exact edge identities in the figure are partially illegible in the text;
    we reconstruct a 14-node DFG whose ASAP/ALAP/MobS match Tab. I exactly
    (verified in tests/test_schedule.py) and whose RecII = 4, giving
    mII = max(ceil(14/4), 4) = 4 on a 2x2 CGRA as in the paper.
    """
    # ASAP rows (Tab. I): t0: 0 1 2 3 4 | t1: 5 11 | t2: 6 12 | t3: 7 8 13 | t4: 9 | t5: 10
    # ALAP rows:          t0: 4 | t1: 3 5 | t2: 0 2 6 | t3: 1 8 11 | t4: 7 9 12 | t5: 10 13
    edges = [
        # intra-iteration data dependencies (black edges)
        Edge(4, 5),    # 4 alap0 -> 5 (asap1, alap1)
        Edge(5, 6), Edge(3, 6),         # 6: asap2, alap2; pins alap(3)=1
        Edge(6, 7), Edge(1, 7),         # 7: asap3, alap4; pins alap(1)=3
        Edge(6, 8), Edge(2, 8),         # 8: asap3, alap3; pins alap(2)=2
        Edge(8, 9),                     # 9: asap4, alap4
        Edge(9, 10), Edge(7, 10),       # 10: asap5, alap5 (sink)
        Edge(0, 11), Edge(11, 12), Edge(12, 13),  # 11..13 side chain; pins alap(0)=2
        # loop-carried dependencies (red edges); close RecII=4 cycle 5-6-8-9
        Edge(9, 5, 1),
        Edge(13, 11, 1),
    ]
    ops = [
        "input", "input", "input", "input", "input",
        "phi", "add", "mul", "sub", "add",
        "add", "phi", "mul", "add",
    ]
    return DFG(num_nodes=14, edges=edges, ops=ops, name="running_example")

"""CGRA architecture model and MRRG construction (paper §III, §IV-A).

The target architecture (paper §V, and its §V-3 limitation) is an R×C grid of
PEs where every PE can read the register files of its mesh neighbours and its
own. A produced value persists in the producer's register file, so a dependency
u→v is spatially routable iff PE(u) is PE(v) itself or a neighbour — regardless
of the time gap (modulo the II wrap for loop-carried deps). This is what makes
the paper's space/time decoupling sound, and it is the architecture we model.

``topology`` extends the paper's mesh with a torus option, used when the same
machinery places computation stage graphs onto TPU pod slices (ICI is a torus);
see core/placement.py.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property


@dataclass(frozen=True)
class CGRA:
    """An R×C grid of single-cycle PEs with neighbour-readable register files.

    This is the spatial half of every mapping: the monomorphism search embeds
    a labelled DFG into ``MRRG(cgra, II)``, and a dependency u→v is routable
    iff ``placement[u]`` is closed-adjacent to ``placement[v]`` (DESIGN.md
    §2). Instances are frozen (hashable, picklable across service workers)
    and precompute their adjacency as bitmasks (DESIGN.md §5).

    Example::

        from repro.core import CGRA

        cgra = CGRA(4, 4)                   # paper's mesh
        assert cgra.num_pes == 16
        assert cgra.connectivity_degree == 5    # D_M: self + 4 neighbours
        torus = CGRA(4, 4, topology="torus")    # TPU-ICI-shaped variant
        assert all(len(n) == 4 for n in torus.neighbors)
    """

    rows: int
    cols: int
    topology: str = "mesh"          # "mesh" (paper) | "torus" (TPU ICI)
    registers_per_pe: int = 8       # modelled but unconstrained by default (§V-3)

    def __post_init__(self) -> None:
        if self.rows < 1 or self.cols < 1:
            raise ValueError("CGRA must have at least one PE")
        if self.topology not in ("mesh", "torus"):
            raise ValueError(f"unknown topology {self.topology!r}")

    @property
    def num_pes(self) -> int:
        return self.rows * self.cols

    def pe_index(self, r: int, c: int) -> int:
        return r * self.cols + c

    def pe_coords(self, pe: int) -> tuple[int, int]:
        return divmod(pe, self.cols)

    @cached_property
    def neighbors(self) -> tuple[tuple[int, ...], ...]:
        """Mesh/torus neighbours of each PE, *excluding* the PE itself."""
        out: list[tuple[int, ...]] = []
        for pe in range(self.num_pes):
            r, c = self.pe_coords(pe)
            nbrs: set[int] = set()
            for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                rr, cc = r + dr, c + dc
                if self.topology == "torus":
                    rr %= self.rows
                    cc %= self.cols
                    if (rr, cc) != (r, c):
                        nbrs.add(self.pe_index(rr, cc))
                elif 0 <= rr < self.rows and 0 <= cc < self.cols:
                    nbrs.add(self.pe_index(rr, cc))
            out.append(tuple(sorted(nbrs)))  # sorted for determinism
        return tuple(out)

    @cached_property
    def adjacency(self) -> tuple[tuple[bool, ...], ...]:
        """Closed adjacency (self-loop included): routability predicate."""
        adj = [[False] * self.num_pes for _ in range(self.num_pes)]
        for pe in range(self.num_pes):
            adj[pe][pe] = True
            for nb in self.neighbors[pe]:
                adj[pe][nb] = True
        return tuple(tuple(row) for row in adj)

    @cached_property
    def closed_masks(self) -> tuple[int, ...]:
        """Closed neighbourhood of each PE as a bitmask (bit p = PE p).

        The layout contract shared with core/mono.py (DESIGN.md §5): PE p is
        bit ``1 << p``, so candidate-set intersection, occupancy tests and
        free-slot counting are word-level AND/ANDN/popcount instead of
        per-element Python set operations.
        """
        out: list[int] = []
        for pe in range(self.num_pes):
            m = 1 << pe
            for nb in self.neighbors[pe]:
                m |= 1 << nb
            out.append(m)
        return tuple(out)

    @property
    def connectivity_degree(self) -> int:
        """Paper's D_M: max closed neighbourhood size (self + mesh neighbours).

        D_M = 3 for 2x2, 5 for 3x3 and larger meshes, matching §IV-B3.
        """
        return max(len(n) for n in self.neighbors) + 1

    def __str__(self) -> str:  # pragma: no cover
        return f"CGRA({self.rows}x{self.cols},{self.topology})"


@dataclass(frozen=True)
class MRRG:
    """Modulo Routing Resource Graph: II stacked copies of the CGRA (§IV-A).

    Vertices are (pe, t) with t in [0, II). l_M((pe, t)) = t. Spatial edges
    connect PEs adjacent in the CGRA at equal time; time edges connect a PE's
    closed neighbourhood across consecutive steps (values persisting in
    register files make any time gap routable, which we encode directly in the
    ``routable`` predicate used by the monomorphism search instead of
    materialising the transitive closure).
    """

    cgra: CGRA
    ii: int

    @property
    def num_vertices(self) -> int:
        return self.cgra.num_pes * self.ii

    def vertex(self, pe: int, t: int) -> int:
        return t * self.cgra.num_pes + pe

    def vertex_pe_time(self, v: int) -> tuple[int, int]:
        t, pe = divmod(v, self.cgra.num_pes)
        return pe, t

    def label(self, v: int) -> int:
        return v // self.cgra.num_pes

    def routable(self, pe_u: int, pe_v: int) -> bool:
        """Edge-existence predicate used by mono3: closed mesh adjacency."""
        return self.cgra.adjacency[pe_u][pe_v]

    def edges(self):
        """Materialised undirected edge set {(pe,t),(pe',t')} per the paper.

        Spatial edges at each step + time edges between consecutive steps
        (including the II wrap, since the kernel repeats). Only used by tests
        and visualisation; the search uses ``routable``.
        """
        n = self.cgra.num_pes
        for t in range(self.ii):
            for pe in range(n):
                for nb in self.cgra.neighbors[pe]:
                    if pe < nb:
                        yield (self.vertex(pe, t), self.vertex(nb, t))
            t2 = (t + 1) % self.ii
            if t2 == t:
                continue
            for pe in range(n):
                # self-loop across time + neighbour reads across time
                yield (self.vertex(pe, t), self.vertex(pe, t2))
                for nb in self.cgra.neighbors[pe]:
                    yield (self.vertex(pe, t), self.vertex(nb, t2))

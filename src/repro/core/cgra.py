"""CGRA architecture model and MRRG construction (paper §III, §IV-A).

The target architecture (paper §V, and its §V-3 limitation) is an R×C grid of
PEs where every PE can read the register files of its mesh neighbours and its
own. A produced value persists in the producer's register file, so a dependency
u→v is spatially routable iff PE(u) is PE(v) itself or a neighbour — regardless
of the time gap (modulo the II wrap for loop-carried deps). This is what makes
the paper's space/time decoupling sound, and it is the architecture we model.

``topology`` extends the paper's mesh with three variants: ``torus`` (used
when the same machinery places computation stage graphs onto TPU pod slices —
ICI is a torus; see core/placement.py), ``diagonal`` (king-move mesh: the
4-neighbourhood plus diagonals, as in SAT-MapIt-style CGRAs) and ``one-hop``
(mesh plus distance-2 row/column links).

Heterogeneity (paper §V-3's flagged assumption, lifted here): each PE carries
a set of *capability classes* — ``alu`` (plain arithmetic/logic), ``mem``
(loads/stores), ``mul`` (multiply/divide) — and a grid-level memory-port
count bounds how many memory ops may fire per cycle. The default
``CGRA(r, c)`` stays the paper's homogeneous grid (every PE every class, no
port bound); declarative specs live in ``core/arch`` (DESIGN.md §10).
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass
from functools import cached_property

# ---------------------------------------------------------------- op classes

#: The capability-class universe. A PE executes an op iff the op's class is in
#: the PE's class set; ``core/arch`` presets compose grids from these.
CAP_CLASSES = ("alu", "mem", "mul")

# op -> capability class. Anything not listed (arith/logic/moves/phi/inputs)
# is plain "alu" work every PE can do.
_OP_CLASS = {"load": "mem", "store": "mem", "mul": "mul", "div": "mul"}


def op_class(op: str) -> str:
    """Capability class an op needs: ``mem`` | ``mul`` | ``alu``."""
    return _OP_CLASS.get(op, "alu")


class _AdjacencyRow:
    """One lazy row of the closed-adjacency predicate: bool per PE."""

    __slots__ = ("_mask", "_n")

    def __init__(self, mask: int, n: int) -> None:
        self._mask = mask
        self._n = n

    def __getitem__(self, pe: int) -> bool:
        if not 0 <= pe < self._n:
            raise IndexError(pe)
        return bool(self._mask >> pe & 1)

    def __len__(self) -> int:
        return self._n

    def __iter__(self):
        m, n = self._mask, self._n
        return (bool(m >> p & 1) for p in range(n))


class _AdjacencyView:
    """Lazy ``adjacency[u][v]`` view over ``closed_masks`` (no N×N table)."""

    __slots__ = ("_masks",)

    def __init__(self, masks: tuple[int, ...]) -> None:
        self._masks = masks

    def __getitem__(self, pe: int) -> _AdjacencyRow:
        return _AdjacencyRow(self._masks[pe], len(self._masks))

    def __len__(self) -> int:
        return len(self._masks)

    def __iter__(self):
        return (self[p] for p in range(len(self._masks)))


_TOPOLOGIES = ("mesh", "torus", "diagonal", "one-hop")

# neighbour offsets per non-torus topology (torus wraps the mesh offsets)
_OFFSETS = {
    "mesh": ((1, 0), (-1, 0), (0, 1), (0, -1)),
    "diagonal": (
        (1, 0), (-1, 0), (0, 1), (0, -1),
        (1, 1), (1, -1), (-1, 1), (-1, -1),
    ),
    "one-hop": (
        (1, 0), (-1, 0), (0, 1), (0, -1),
        (2, 0), (-2, 0), (0, 2), (0, -2),
    ),
}


@dataclass(frozen=True)
class CGRA:
    """An R×C grid of single-cycle PEs with neighbour-readable register files.

    This is the spatial half of every mapping: the monomorphism search embeds
    a labelled DFG into ``MRRG(cgra, II)``, and a dependency u→v is routable
    iff ``placement[u]`` is closed-adjacent to ``placement[v]`` (DESIGN.md
    §2). Instances are frozen (hashable, picklable across service workers)
    and precompute their adjacency as bitmasks (DESIGN.md §5).

    ``pe_classes`` makes the grid heterogeneous: entry p is the tuple of
    capability classes PE p supports (see ``CAP_CLASSES``), and ``mem_ports``
    optionally bounds memory ops per cycle grid-wide. ``None`` (the default)
    means the paper's homogeneous machine — every PE supports every class —
    so all pre-existing callers are unchanged. Build heterogeneous instances
    through :mod:`repro.core.arch` rather than by hand.

    Example::

        from repro.core import CGRA

        cgra = CGRA(4, 4)                   # paper's mesh
        assert cgra.num_pes == 16
        assert cgra.connectivity_degree == 5    # D_M: self + 4 neighbours
        torus = CGRA(4, 4, topology="torus")    # TPU-ICI-shaped variant
        assert all(len(n) == 4 for n in torus.neighbors)
        king = CGRA(4, 4, topology="diagonal")  # adds diagonal links
        assert king.connectivity_degree == 9 and not king.triangle_free
    """

    rows: int
    cols: int
    topology: str = "mesh"          # "mesh" (paper) | "torus" | "diagonal" | "one-hop"
    registers_per_pe: int = 8       # enforced by Mapping.validate's pressure probe
    # per-PE capability classes; None = homogeneous (every PE, every class)
    pe_classes: tuple[tuple[str, ...], ...] | None = None
    # max memory ops per cycle grid-wide; None = one port per mem-capable PE
    mem_ports: int | None = None
    # per-capability-class register-file override, ((class, count), ...);
    # a dict is accepted and normalised. None = the scalar registers_per_pe
    registers_by_class: tuple[tuple[str, int], ...] | None = None

    def __post_init__(self) -> None:
        if self.rows < 1 or self.cols < 1:
            raise ValueError("CGRA must have at least one PE")
        if self.topology not in _TOPOLOGIES:
            raise ValueError(f"unknown topology {self.topology!r}")
        if self.registers_by_class is not None:
            # normalise dicts (and unsorted tuples) so equality/hashing work
            items = (self.registers_by_class.items()
                     if isinstance(self.registers_by_class, dict)
                     else self.registers_by_class)
            norm = tuple(sorted((str(c), int(n)) for c, n in items))
            for c, n in norm:
                if c not in CAP_CLASSES:
                    raise ValueError(
                        f"registers_by_class: unknown capability class {c!r}"
                    )
                if n < 1:
                    raise ValueError(
                        f"registers_by_class[{c!r}] must be >= 1, got {n}"
                    )
            object.__setattr__(self, "registers_by_class", norm)
        if self.pe_classes is not None:
            if len(self.pe_classes) != self.num_pes:
                raise ValueError(
                    f"pe_classes has {len(self.pe_classes)} entries for "
                    f"{self.num_pes} PEs"
                )
            for p, classes in enumerate(self.pe_classes):
                if not classes:
                    raise ValueError(f"PE {p} has no capability classes")
                for c in classes:
                    if c not in CAP_CLASSES:
                        raise ValueError(f"PE {p}: unknown capability class {c!r}")
        if self.mem_ports is not None and self.mem_ports < 0:
            raise ValueError("mem_ports must be >= 0")

    @property
    def num_pes(self) -> int:
        return self.rows * self.cols

    def pe_index(self, r: int, c: int) -> int:
        return r * self.cols + c

    def pe_coords(self, pe: int) -> tuple[int, int]:
        return divmod(pe, self.cols)

    @cached_property
    def neighbors(self) -> tuple[tuple[int, ...], ...]:
        """Topology neighbours of each PE, *excluding* the PE itself."""
        offsets = _OFFSETS["mesh" if self.topology == "torus" else self.topology]
        out: list[tuple[int, ...]] = []
        for pe in range(self.num_pes):
            r, c = self.pe_coords(pe)
            nbrs: set[int] = set()
            for dr, dc in offsets:
                rr, cc = r + dr, c + dc
                if self.topology == "torus":
                    rr %= self.rows
                    cc %= self.cols
                    if (rr, cc) != (r, c):
                        nbrs.add(self.pe_index(rr, cc))
                elif 0 <= rr < self.rows and 0 <= cc < self.cols:
                    nbrs.add(self.pe_index(rr, cc))
            out.append(tuple(sorted(nbrs)))  # sorted for determinism
        return tuple(out)

    @cached_property
    def adjacency(self) -> "_AdjacencyView":
        """Closed adjacency (self-loop included): routability predicate.

        Indexed like the historical dense matrix (``adjacency[u][v]`` is a
        bool) but evaluated lazily over ``closed_masks`` — a 100×100 fabric
        would need a 10⁸-entry materialised matrix, which is what capped the
        supported fabric size before the space-backend split (DESIGN.md §13).
        """
        return _AdjacencyView(self.closed_masks)

    @cached_property
    def closed_masks(self) -> tuple[int, ...]:
        """Closed neighbourhood of each PE as a bitmask (bit p = PE p).

        The layout contract shared with core/mono.py (DESIGN.md §5): PE p is
        bit ``1 << p``, so candidate-set intersection, occupancy tests and
        free-slot counting are word-level AND/ANDN/popcount instead of
        per-element Python set operations.
        """
        out: list[int] = []
        for pe in range(self.num_pes):
            m = 1 << pe
            for nb in self.neighbors[pe]:
                m |= 1 << nb
            out.append(m)
        return tuple(out)

    @cached_property
    def _reach_cache(self) -> dict[int, tuple[int, ...]]:
        return {1: self.closed_masks}

    def reach_masks(self, hops: int) -> tuple[int, ...]:
        """Closed ≤``hops``-step reachability masks (same §5 bit layout).

        ``reach_masks(1)`` is exactly ``closed_masks``; ``reach_masks(h)[p]``
        is every PE reachable from p by chaining at most ``h`` closed-adjacency
        steps. This is the relaxed routability predicate of the route-through
        space search (DESIGN.md §12): an edge placed at hop distance ``h > 1``
        is later realised by splicing ``h - 1`` ``mov`` nodes onto the path.
        """
        if hops < 1:
            raise ValueError(f"hops must be >= 1, got {hops}")
        cache = self._reach_cache
        if hops not in cache:
            prev = self.reach_masks(hops - 1)
            closed = self.closed_masks
            out: list[int] = []
            for pe in range(self.num_pes):
                m, acc = prev[pe], prev[pe]
                while m:
                    b = m & -m
                    acc |= closed[b.bit_length() - 1]
                    m ^= b
                out.append(acc)
            cache[hops] = tuple(out)
        return cache[hops]

    def reach_degree(self, hops: int) -> int:
        """Max closed ≤``hops``-step neighbourhood size: the D_M analogue the
        time phase must use when route-through is allowed (DESIGN.md §12.3)."""
        return max(m.bit_count() for m in self.reach_masks(hops))

    @property
    def connectivity_degree(self) -> int:
        """Paper's D_M: max closed neighbourhood size (self + mesh neighbours).

        D_M = 3 for 2x2, 5 for 3x3 and larger meshes, matching §IV-B3.
        Diagonal and one-hop grids have larger closed neighbourhoods (up to 9).
        """
        return max(len(n) for n in self.neighbors) + 1

    @cached_property
    def triangle_free(self) -> bool:
        """True iff the PE graph has no 3-clique.

        The strict-mode triangle exclusion (DESIGN.md §7) is only sound on
        triangle-free PE graphs: plain meshes are bipartite, but diagonal
        (king-move) grids, one-hop grids, and tori with a ring of length 3
        all contain triangles, so three mutually adjacent DFG nodes *can*
        share a kernel step there. Computed from the actual neighbour lists
        rather than the topology name so every current and future family is
        handled by construction.
        """
        for pe in range(self.num_pes):
            nbrs = self.neighbors[pe]
            for i, a in enumerate(nbrs):
                if a < pe:
                    continue
                for b in nbrs[i + 1:]:
                    if a in self.neighbors[b]:
                        return False
        return True

    # -------------------------------------------------------------- capability
    @property
    def heterogeneous(self) -> bool:
        """True when capabilities or memory ports deviate from the paper model."""
        return self.pe_classes is not None or self.mem_ports is not None

    @cached_property
    def capability_masks(self) -> dict[str, int]:
        """Per capability class, the bitmask of capable PEs (bit p = PE p).

        Shares the DESIGN.md §5 layout contract with ``closed_masks`` so the
        space engine can intersect a node's candidate set with its op-class
        mask in one AND. Homogeneous grids map every class to the full mask.
        """
        full = (1 << self.num_pes) - 1
        if self.pe_classes is None:
            return {c: full for c in CAP_CLASSES}
        masks = {c: 0 for c in CAP_CLASSES}
        for pe, classes in enumerate(self.pe_classes):
            for c in classes:
                masks[c] |= 1 << pe
        return masks

    def capable(self, pe: int, cls: str) -> bool:
        """Can PE ``pe`` execute ops of capability class ``cls``?"""
        return bool(self.capability_masks[cls] >> pe & 1)

    def class_capacity(self, cls: str) -> int:
        """Per-kernel-step capacity of a class: capable-PE count, and for
        ``mem`` additionally clamped by the grid's memory-port count."""
        cap = self.capability_masks[cls].bit_count()
        if cls == "mem" and self.mem_ports is not None:
            cap = min(cap, self.mem_ports)
        return cap

    @cached_property
    def _registers_at(self) -> tuple[int, ...]:
        overrides = dict(self.registers_by_class or ())
        out = []
        for pe in range(self.num_pes):
            classes = (CAP_CLASSES if self.pe_classes is None
                       else self.pe_classes[pe])
            out.append(max(
                overrides.get(c, self.registers_per_pe) for c in classes
            ))
        return tuple(out)

    def registers_at(self, pe: int) -> int:
        """Register-file size of PE ``pe``.

        ``registers_by_class`` (core/arch: SAT-MapIt-style machines size
        memory-PE buffers differently) overrides the scalar
        ``registers_per_pe`` per capability class; a PE carrying several
        classes gets the largest file its classes demand. Without overrides
        every PE answers ``registers_per_pe`` — the paper's machine.
        """
        return self._registers_at[pe]

    def unsupported_ops(self, dfg) -> list[str]:
        """Ops of ``dfg`` that no PE (or port budget) can ever execute.

        The mapper fails fast on a non-empty result instead of exhausting
        its (II, slack) window sweep on a structurally impossible target.
        """
        errs: list[str] = []
        seen: set[str] = set()
        for v in range(dfg.num_nodes):
            cls = op_class(dfg.ops[v])
            if cls in seen:
                continue
            seen.add(cls)
            if self.class_capacity(cls) == 0:
                errs.append(
                    f"op {dfg.ops[v]!r} (class {cls!r}) has no capable PE on {self}"
                )
        return errs

    def arch_token(self) -> str | None:
        """Cache-key component identifying the heterogeneous architecture.

        ``None`` for the paper's homogeneous grid (dims/topology already key
        those), a short digest of the capability layout otherwise — folded
        into both mapping-cache keys (DESIGN.md §9) so heterogeneous and
        homogeneous mappings of the same DFG never alias.
        """
        if not self.heterogeneous:
            return None
        payload = json.dumps(
            {
                "classes": [sorted(c) for c in self.pe_classes or []],
                "mem_ports": self.mem_ports,
            },
            sort_keys=True,
            separators=(",", ":"),
        )
        return hashlib.sha1(payload.encode()).hexdigest()[:16]

    def pressure_token(self, max_register_pressure: int | None):
        """Cache-key component for the *effective* per-PE register bounds.

        The mapper's ``max_register_pressure`` guarantee is per-PE:
        ``min(max_register_pressure, registers_at(pe))`` for every PE. Two
        grids of the same shape but different register sizing therefore admit
        different mappings under the same scalar limit, so the scalar alone
        must never key the mapping caches (the PR-4 bug this closes).
        ``None`` when the guarantee is off (mappings are then
        register-agnostic); the scalar bound when every PE's effective bound
        collapses to one value; a digest of the full bound vector otherwise.
        """
        if max_register_pressure is None:
            return None
        bounds = tuple(
            min(max_register_pressure, r) for r in self._registers_at
        )
        if len(set(bounds)) == 1:
            return bounds[0]
        payload = json.dumps(list(bounds), separators=(",", ":"))
        return hashlib.sha1(payload.encode()).hexdigest()[:16]

    def __str__(self) -> str:  # pragma: no cover
        het = ",hetero" if self.heterogeneous else ""
        return f"CGRA({self.rows}x{self.cols},{self.topology}{het})"


@dataclass(frozen=True)
class MRRG:
    """Modulo Routing Resource Graph: II stacked copies of the CGRA (§IV-A).

    Vertices are (pe, t) with t in [0, II). l_M((pe, t)) = t. Spatial edges
    connect PEs adjacent in the CGRA at equal time; time edges connect a PE's
    closed neighbourhood across consecutive steps (values persisting in
    register files make any time gap routable, which we encode directly in the
    ``routable`` predicate used by the monomorphism search instead of
    materialising the transitive closure).
    """

    cgra: CGRA
    ii: int

    @property
    def num_vertices(self) -> int:
        return self.cgra.num_pes * self.ii

    def vertex(self, pe: int, t: int) -> int:
        return t * self.cgra.num_pes + pe

    def vertex_pe_time(self, v: int) -> tuple[int, int]:
        t, pe = divmod(v, self.cgra.num_pes)
        return pe, t

    def label(self, v: int) -> int:
        return v // self.cgra.num_pes

    def routable(self, pe_u: int, pe_v: int) -> bool:
        """Edge-existence predicate used by mono3: closed mesh adjacency."""
        return self.cgra.adjacency[pe_u][pe_v]

    def edges(self):
        """Materialised undirected edge set {(pe,t),(pe',t')} per the paper.

        Spatial edges at each step + time edges between consecutive steps
        (including the II wrap, since the kernel repeats). Only used by tests
        and visualisation; the search uses ``routable``.
        """
        n = self.cgra.num_pes
        for t in range(self.ii):
            for pe in range(n):
                for nb in self.cgra.neighbors[pe]:
                    if pe < nb:
                        yield (self.vertex(pe, t), self.vertex(nb, t))
            t2 = (t + 1) % self.ii
            if t2 == t:
                continue
            for pe in range(n):
                # self-loop across time + neighbour reads across time
                yield (self.vertex(pe, t), self.vertex(pe, t2))
                for nb in self.cgra.neighbors[pe]:
                    yield (self.vertex(pe, t), self.vertex(nb, t2))

"""Pluggable space backends (DESIGN.md §13).

Importing this package registers both engines; resolve by name (or pass an
instance straight through)::

    from repro.core.space_backends import resolve_space_backend
    backend = resolve_space_backend("auto", cgra)   # exact <=400 PEs, else anneal
    sol = backend.place(dfg, cgra, labels, ii, budget=SpaceBudget(timeout_s=2.0))
"""

from .base import (
    AUTO_EXACT_MAX_PES,
    MaterializedRoute,
    SpaceBackend,
    SpaceBudget,
    SpaceSolution,
    SpaceStats,
    available_space_backends,
    check_monomorphism,
    check_routes,
    create_space_backend,
    register_space_backend,
    resolve_space_backend,
    resolve_space_backend_name,
)
from .anneal import AnnealSpaceBackend
from .exact import ExactSpaceBackend, find_monomorphism

__all__ = [
    "AUTO_EXACT_MAX_PES",
    "AnnealSpaceBackend",
    "ExactSpaceBackend",
    "MaterializedRoute",
    "SpaceBackend",
    "SpaceBudget",
    "SpaceSolution",
    "SpaceStats",
    "available_space_backends",
    "check_monomorphism",
    "check_routes",
    "create_space_backend",
    "find_monomorphism",
    "register_space_backend",
    "resolve_space_backend",
    "resolve_space_backend_name",
]

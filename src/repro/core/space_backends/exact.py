"""Exact space backend: monomorphism bitset engine (paper §IV-C).

Given a time solution (kernel label per DFG node), find an injective,
label-preserving, edge-preserving embedding of the undirected DFG into the
MRRG. Under the register-file architecture (see core/cgra.py) an MRRG edge
exists between (pe_u, t_u) and (pe_v, t_v) iff pe_u equals-or-neighbours pe_v,
so the search reduces to placing each node on a PE such that

  * at each kernel step, every PE hosts at most one node   (mono1 + mono2)
  * G-adjacent nodes land on closed-adjacent PEs           (mono3)

The search is a VF2/RI-style backtracking specialised to the label structure:
connected expansion order (most-placed-neighbours first), candidate sets from
the intersection of placed neighbours' closed neighbourhoods, forward checking
(every placed node must retain enough free adjacent slots per step for its
unplaced neighbours), and randomised restarts — the classic recipe that gives
VF3-class robustness [29,30] while exploiting the time labels, which partition
the injectivity constraint by step and keep the search shallow.

All PE sets are int bitmasks (bit p = PE p; layout contract in DESIGN.md §5,
masks precomputed in ``CGRA.closed_masks``): candidate intersection is a chain
of ANDs maintained incrementally per node, occupancy per kernel step is one
word, and forward checking is popcount over ``closed & ~occ`` — O(words) per
check instead of O(|set|), which is what lets 20x20 grids (400-bit words)
search millions of candidates per second in pure Python.

Budgets: ``timeout_s`` (wall clock) and/or ``node_budget`` (deterministic
visited-node cap, used by tests and the mapper's deterministic mode).
"""

from __future__ import annotations

import random
import time as _time

from ... import obs
from ..cgra import CGRA, op_class
from ..dfg import DFG
from .base import (
    MaterializedRoute,
    SpaceBudget,
    SpaceSolution,
    SpaceStats,
    _RouteContext,
    register_space_backend,
)


def find_monomorphism(
    dfg: DFG,
    cgra: CGRA,
    labels: list[int],
    ii: int,
    *,
    timeout_s: float | None = 4.0,
    node_budget: int | None = None,
    restarts: int = 6,
    seed: int = 0,
    stats: SpaceStats | None = None,
    t_abs: list[int] | None = None,
    max_route_hops: int = 0,
) -> SpaceSolution | None:
    """Randomised-restart wrapper around one backtracking dive per seed.

    With ``timeout_s=None`` and a ``node_budget``, the search is fully
    deterministic: identical inputs always visit the identical tree prefix.

    ``max_route_hops > 0`` enables route-through repair (DESIGN.md §12):
    G-adjacent nodes may then land up to ``1 + max_route_hops`` closed-
    adjacency steps apart, and every non-direct edge of a complete placement
    is realised as a chain of ``mov`` nodes over free (PE, step) slots —
    returned in ``SpaceSolution.routes``. This needs the absolute schedule
    (``t_abs``): an edge's hop allowance is bounded by its time gap, and the
    movs' firing times are picked inside it. ``max_route_hops=0`` (default)
    is bit-identical to the historical direct-only search.
    """
    stats = stats if stats is not None else SpaceStats()
    route_ctx = (
        _RouteContext(dfg, cgra, labels, t_abs, ii, max_route_hops)
        if max_route_hops > 0 else None
    )
    start = _time.perf_counter()
    budget = timeout_s if timeout_s is not None else float("inf")
    n_restarts = max(1, restarts)
    # geometric restart schedule: cheap early probes, one deep final dive —
    # weights 1,1,2,4,...  (the last restart gets ~half the total budget)
    weights = [1] + [1 << min(r, 30) for r in range(n_restarts - 1)]
    total_w = sum(weights)
    traced = obs.enabled()
    for r in range(n_restarts):
        remaining = budget - (_time.perf_counter() - start)
        if remaining <= 0:
            break
        stats.restarts += 1
        frac = weights[r] / total_w
        n0, b0 = stats.nodes_visited, stats.backtracks
        sol = _search_once(
            dfg, cgra, labels, ii,
            deadline=(
                _time.perf_counter() + min(budget * frac, remaining)
                if budget != float("inf") else None
            ),
            node_budget=(
                max(1, int(node_budget * frac)) if node_budget is not None else None
            ),
            rng=random.Random(seed * 7919 + r),
            shuffle=r > 0,   # first dive is deterministic greedy
            stats=stats,
            route_ctx=route_ctx,
        )
        if traced:
            # restart-boundary telemetry only (DESIGN.md §15): the dive
            # itself stays untouched — the golden 4x4 pins its search path
            # bit-for-bit. prune_rate = backtracks per visited node; a high
            # rate means the candidate masks are paying for themselves.
            nodes = stats.nodes_visited - n0
            backtracks = stats.backtracks - b0
            obs.event(
                "space.exact.restart", ii=ii, restart=r, nodes=nodes,
                backtracks=backtracks, found=sol is not None,
                prune_rate=round(backtracks / nodes, 4) if nodes else None,
            )
        if sol is not None:
            placement, routes = sol
            stats.search_time_s += _time.perf_counter() - start
            return SpaceSolution(ii=ii, placement=placement, routes=routes)
    stats.search_time_s += _time.perf_counter() - start
    return None


def _search_once(
    dfg: DFG,
    cgra: CGRA,
    labels: list[int],
    ii: int,
    *,
    deadline: float | None,
    node_budget: int | None,
    rng: random.Random,
    shuffle: bool,
    stats: SpaceStats,
    route_ctx: _RouteContext | None = None,
) -> tuple[list[int], tuple[MaterializedRoute, ...]] | None:
    n = dfg.num_nodes
    adj_sets = dfg.undirected_adjacency()
    adj = [tuple(sorted(s)) for s in adj_sets]
    num_pes = cgra.num_pes
    closed = cgra.closed_masks
    full = (1 << num_pes) - 1

    if n > num_pes * ii:
        return None
    for v in range(n):
        if not 0 <= labels[v] < ii:
            raise ValueError(f"label out of range for node {v}: {labels[v]}")

    # Capability pruning (DESIGN.md §10): a node may only sit on a PE whose
    # class set covers its op — seed each candidate mask with the op-class
    # mask so incapable placements vanish at the bitset layer instead of
    # being discovered (and backtracked out of) by the search. Homogeneous
    # grids keep the full mask, leaving the search path bit-identical.
    if cgra.heterogeneous:
        cap_masks = cgra.capability_masks
        node_mask = [cap_masks[op_class(dfg.ops[v])] for v in range(n)]
        if not all(node_mask):
            return None            # some op has no capable PE at all
    else:
        node_mask = [full] * n

    degs = [len(adj[v]) for v in range(n)]
    # static value-order rank: interior PEs (largest closed nbhd) first keeps
    # future intersections large; jitter on restarts
    pe_rank = sorted(range(num_pes), key=lambda p: -closed[p].bit_count())
    if shuffle:
        rng.shuffle(pe_rank)
    rank_of = [0] * num_pes
    for i, p in enumerate(pe_rank):
        rank_of[p] = i

    placement = [-1] * n
    occ = [0] * ii                       # occupied-PE mask per kernel step
    # candidate mask per node: op-class mask AND placed neighbours' closed masks
    cand = list(node_mask)
    placed_nbrs = [0] * n
    # unplaced-neighbour demand per (node, step), updated incrementally
    need = [[0] * ii for _ in range(n)]
    for v in range(n):
        for u in adj[v]:
            need[v][labels[u]] += 1

    budget_left = node_budget if node_budget is not None else -1
    check_tick = 0

    # route-through relaxation: a placed node's reachable area for forward
    # checking, and the routes of the accepted placement (repair loop)
    if route_ctx is not None:
        node_reach = [
            route_ctx.reach[route_ctx.node_allow[v]] for v in range(n)
        ]
    found_routes: list[MaterializedRoute] = []

    def complete() -> bool:
        """Accept a full placement; under routing, movs must materialise."""
        if route_ctx is None:
            return True
        routes = route_ctx.materialize(placement, occ)
        if routes is None:
            stats.route_failures += 1
            return False
        found_routes[:] = routes
        return True

    def forward_ok(u: int) -> bool:
        """Placed node u must keep enough free adjacent slots per step."""
        if route_ctx is None:
            cu = closed[placement[u]]
        else:
            cu = node_reach[u][placement[u]]
        nu = need[u]
        for step in range(ii):
            want = nu[step]
            if want and (cu & ~occ[step]).bit_count() < want:
                return False
        return True

    def seed_candidates(v: int) -> list[int]:
        free = node_mask[v] & ~occ[labels[v]]
        return [p for p in pe_rank if (1 << p) & free]

    def cand_list(v: int) -> list[int]:
        m = cand[v] & ~occ[labels[v]]
        out = []
        while m:
            b = m & -m
            out.append(b.bit_length() - 1)
            m ^= b
        out.sort(key=rank_of.__getitem__)   # per-restart jitter lives in pe_rank
        return out

    def place(v: int, p: int) -> list[tuple[int, int]]:
        placement[v] = p
        occ[labels[v]] |= 1 << p
        cp = closed[p]
        undo: list[tuple[int, int]] = []
        lv = labels[v]
        for u in adj[v]:
            need[u][lv] -= 1
            if placement[u] < 0:
                old = cand[u]
                if route_ctx is None:
                    new = old & cp
                else:
                    # per-pair reach: how far u may sit from v is bounded by
                    # the routable hop allowance of their connecting edges
                    new = old & route_ctx.pair_masks(u, v)[p]
                if new != old:
                    undo.append((u, old))
                    cand[u] = new
            placed_nbrs[u] += 1
        return undo

    def unplace(v: int, p: int, undo: list[tuple[int, int]]) -> None:
        lv = labels[v]
        for u in adj[v]:
            need[u][lv] += 1
            placed_nbrs[u] -= 1
        for u, old in undo:
            cand[u] = old
        occ[labels[v]] &= ~(1 << p)
        placement[v] = -1

    def select_var() -> tuple[int, list[int]] | None:
        """Dynamic MRV: among frontier nodes (>=1 placed neighbour), pick the
        one with the fewest candidate PEs; empty frontier seeds a component."""
        best_v, best_c = -1, -1
        for v in range(n):
            if placement[v] >= 0 or not placed_nbrs[v]:
                continue
            c = (cand[v] & ~occ[labels[v]]).bit_count()
            if c == 0:
                return (v, [])          # dead end: fail fast
            if best_v < 0 or (c, -degs[v]) < (best_c, -degs[best_v]):
                best_v, best_c = v, c
                if c == 1:
                    break
        if best_v >= 0:
            return best_v, cand_list(best_v)
        # new component seed: highest-degree unplaced node
        seeds = [v for v in range(n) if placement[v] < 0]
        if not seeds:
            return None
        v = max(seeds, key=lambda u: (degs[u], rng.random() if shuffle else 0))
        return v, seed_candidates(v)

    def rec(placed_count: int) -> int:
        """1 = solved, 0 = subtree exhausted, -1 = budget/deadline abort."""
        nonlocal budget_left, check_tick
        if placed_count == n:
            return 1 if complete() else 0
        check_tick += 1
        if deadline is not None and not check_tick & 0xFF:
            if _time.perf_counter() > deadline:
                return -1
        sel = select_var()
        if sel is None:
            return 1 if complete() else 0
        v, cands = sel
        lv = labels[v]
        for p in cands:
            stats.nodes_visited += 1
            if budget_left >= 0:
                budget_left -= 1
                if budget_left < 0:
                    return -1
            undo = place(v, p)
            # arc check: every unplaced neighbour must retain a candidate
            ok = all(
                cand[u] & ~occ[labels[u]]
                for u in adj[v]
                if placement[u] < 0
            )
            if ok and forward_ok(v):
                ok = all(
                    forward_ok(u) for u in adj[v] if placement[u] >= 0
                )
            if ok:
                r = rec(placed_count + 1)
                if r:
                    if r > 0:
                        return 1
                    unplace(v, p, undo)
                    return -1
            stats.backtracks += 1
            unplace(v, p, undo)
        return 0

    if rec(0) > 0:
        return list(placement), tuple(found_routes)
    return None


class ExactSpaceBackend:
    """Registry adapter over :func:`find_monomorphism`.

    A thin forwarding shim, deliberately: the golden 4×4 suite pins the
    engine's search path bit-for-bit, so ``place`` must add nothing beyond
    unpacking the :class:`SpaceBudget`.
    """

    name = "exact"

    def place(
        self,
        dfg: DFG,
        cgra: CGRA,
        labels: list[int],
        ii: int,
        *,
        t_abs: list[int] | None = None,
        max_route_hops: int = 0,
        budget: SpaceBudget | None = None,
        seed: int = 0,
        stats: SpaceStats | None = None,
        should_stop=None,
    ) -> SpaceSolution | None:
        b = budget if budget is not None else SpaceBudget()
        return find_monomorphism(
            dfg, cgra, labels, ii,
            timeout_s=b.timeout_s,
            node_budget=b.node_budget,
            restarts=b.restarts,
            seed=seed,
            stats=stats,
            t_abs=t_abs,
            max_route_hops=max_route_hops,
        )


register_space_backend("exact", ExactSpaceBackend, aliases=("mono", "bitset"))

"""Space-backend protocol, shared datatypes and registry (DESIGN.md §13).

The space phase — embed a time-labelled DFG into the MRRG — is pluggable,
mirroring the time phase's ``time_backends`` registry: a backend is anything
with a ``place`` method turning one label partition into a
:class:`SpaceSolution` (or None within its budget). Two engines register
here:

* ``exact`` (space_backends/exact.py) — the paper's bitset monomorphism
  search, complete up to its node budget; the quality anchor.
* ``anneal`` (space_backends/anneal.py) — clustered placement + simulated
  annealing for very large fabrics (50×50 and beyond), where the exact
  engine's word width makes each visited node expensive.

This module also hosts what every backend shares: the solution/stats
datatypes, the placement validators (``check_monomorphism``/
``check_routes``), and the route-repair machinery (``_RouteContext``) that
materialises non-direct edges as ``mov`` chains (DESIGN.md §12.1) — the
legalization pass both engines hand off to.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Protocol

from ... import obs
from ..cgra import CGRA, op_class
from ..dfg import DFG
from ..time_backends.base import mov_slot_headroom

#: ``"auto"`` resolution threshold: fabrics with at most this many PEs use
#: the exact engine (complete, bit-identical to the paper's search); larger
#: ones use the annealing backend, whose per-move cost does not grow with
#: the bitmask word width. 400 = the 20×20 grid of the paper's Fig. 5 sweep.
AUTO_EXACT_MAX_PES = 400


@dataclass(frozen=True)
class MaterializedRoute:
    """One realised route-through: the original edge, the intermediate PEs,
    and the absolute firing times of the movs that will occupy them."""

    edge: tuple[int, int, int]     # (src, dst, distance) of the routed edge
    path: tuple[int, ...]          # intermediate PEs, src side first
    times: tuple[int, ...]         # absolute mov times, strictly increasing


@dataclass
class SpaceSolution:
    ii: int
    placement: list[int]  # node -> PE index
    # route-throughs materialised by the repair loop; empty = direct embedding
    routes: tuple[MaterializedRoute, ...] = ()


@dataclass
class SpaceStats:
    search_time_s: float = 0.0
    nodes_visited: int = 0         # backtracking nodes / annealing moves
    backtracks: int = 0
    restarts: int = 0
    route_failures: int = 0        # complete placements whose movs didn't fit


@dataclass(frozen=True)
class SpaceBudget:
    """How much work one ``place`` call may spend.

    ``timeout_s=None`` with a ``node_budget`` is the deterministic contract:
    identical inputs take the identical search path regardless of load.
    """

    timeout_s: float | None = 4.0
    node_budget: int | None = None
    restarts: int = 6


class SpaceBackend(Protocol):  # pragma: no cover - typing only
    name: str

    def place(
        self,
        dfg: DFG,
        cgra: CGRA,
        labels: list[int],
        ii: int,
        *,
        t_abs: list[int] | None = None,
        max_route_hops: int = 0,
        budget: SpaceBudget | None = None,
        seed: int = 0,
        stats: SpaceStats | None = None,
        should_stop: Callable[[], bool] | None = None,
    ) -> SpaceSolution | None: ...


@dataclass
class _BackendSpec:
    name: str
    factory: Callable[[], "SpaceBackend"]
    aliases: tuple[str, ...] = ()


_REGISTRY: dict[str, _BackendSpec] = {}
_ALIASES: dict[str, str] = {}


def register_space_backend(
    name: str,
    factory: Callable[[], "SpaceBackend"],
    *,
    aliases: tuple[str, ...] = (),
) -> None:
    spec = _BackendSpec(name, factory, aliases)
    _REGISTRY[name] = spec
    for a in aliases:
        _ALIASES[a] = name


def resolve_space_backend_name(name: str, cgra: CGRA | None = None) -> str:
    """Canonicalise an alias/auto request to a concrete registered backend.

    ``"auto"`` needs the target fabric: exact up to
    :data:`AUTO_EXACT_MAX_PES` PEs, anneal above (DESIGN.md §13.3).
    """
    if name == "auto":
        if cgra is None:
            raise ValueError(
                "resolving the 'auto' space backend needs the target CGRA"
            )
        return "exact" if cgra.num_pes <= AUTO_EXACT_MAX_PES else "anneal"
    name = _ALIASES.get(name, name)
    if name not in _REGISTRY:
        raise ValueError(f"unknown space backend {name!r}")
    return name


def available_space_backends() -> dict[str, bool]:
    """Backend name -> available (space backends are dependency-free, so
    every registered engine is importable; the dict shape mirrors
    ``time_backends.available_backends`` for diagnostics symmetry)."""
    return {n: True for n in _REGISTRY}


def create_space_backend(name: str, cgra: CGRA | None = None) -> "SpaceBackend":
    name = resolve_space_backend_name(name, cgra)
    return _REGISTRY[name].factory()


def resolve_space_backend(backend, cgra: CGRA | None = None) -> "SpaceBackend":
    """Name-or-instance resolution: a string goes through the registry
    (``"auto"`` needs ``cgra``), anything exposing ``place`` passes through
    — the hook custom placement strategies use without registering."""
    if isinstance(backend, str):
        return create_space_backend(backend, cgra)
    if hasattr(backend, "place"):
        return backend
    raise TypeError(
        f"space backend must be a name or an object with place(), "
        f"got {type(backend).__name__}"
    )


class _RouteContext:
    """Per-search route-through state (DESIGN.md §12.1).

    Precomputes, from the time solution, how far apart each adjacent node
    pair may be placed: an edge with absolute-time gap ``g`` (``t_dst -
    t_src + II*distance``) can absorb at most ``g - 1`` movs, each of which
    needs a strictly intermediate firing time, so the pair's placement may
    sit at closed-reach distance ``min(1 + max_hops, g)``. The search relaxes
    its candidate masks accordingly; :meth:`materialize` then realises every
    non-direct edge as a concrete mov chain over free (PE, step) slots — or
    fails, sending the search back to try another placement (the repair
    loop).
    """

    def __init__(
        self,
        dfg: DFG,
        cgra: CGRA,
        labels: list[int],
        t_abs: list[int],
        ii: int,
        max_hops: int,
    ) -> None:
        if t_abs is None:
            raise ValueError("route-through search needs the absolute schedule")
        self.dfg = dfg
        self.cgra = cgra
        self.labels = labels
        self.t_abs = t_abs
        self.ii = ii
        self.max_hops = max_hops
        self.closed = cgra.closed_masks
        self.alu_mask = cgra.capability_masks["alu"]
        # reach tables for every allowed hop level, 1-indexed by hop count
        self.reach = [None] + [
            cgra.reach_masks(h) for h in range(1, max_hops + 2)
        ]
        # per adjacent pair, the allowed placement reach (min over the
        # directed edges between the pair: every edge must be realisable)
        allow: dict[tuple[int, int], int] = {}
        for e in dfg.edges:
            if e.src == e.dst:
                continue
            gap = t_abs[e.dst] - t_abs[e.src] + ii * e.distance
            h = max(1, min(1 + max_hops, gap))
            key = (e.src, e.dst) if e.src < e.dst else (e.dst, e.src)
            allow[key] = min(allow.get(key, h), h)
        self.pair_allow = allow
        # widest allowance per node (conservative forward-checking mask)
        node_allow = [1] * dfg.num_nodes
        for (u, v), h in allow.items():
            node_allow[u] = max(node_allow[u], h)
            node_allow[v] = max(node_allow[v], h)
        self.node_allow = node_allow

    def pair_masks(self, u: int, v: int):
        """Reach-mask table governing where ``u`` may sit relative to ``v``."""
        key = (u, v) if u < v else (v, u)
        return self.reach[self.pair_allow[key]]

    # ------------------------------------------------------- materialization
    def materialize(
        self, placement: list[int], occ: list[int]
    ) -> list[MaterializedRoute] | None:
        """Realise every non-direct edge as a mov chain, or return None.

        Deterministic greedy-with-path-backtracking per edge (edges in DFG
        order, paths in ascending-PE order, times earliest-first); movs claim
        (PE, step) slots against both the placed nodes (``occ``) and each
        other. The shared slot accounting (time_backends.base.
        ``mov_slot_headroom``) fast-fails steps with no capacity left.
        """
        closed, ii = self.closed, self.ii
        num_pes = self.cgra.num_pes
        headroom = mov_slot_headroom(self.labels, ii, num_pes)
        extra = [0] * ii                      # mov occupancy per kernel step
        routes: list[MaterializedRoute] = []
        for e in self.dfg.edges:
            if e.src == e.dst:
                continue
            p_src, p_dst = placement[e.src], placement[e.dst]
            if (closed[p_src] >> p_dst) & 1:
                continue                      # direct edge, no movs
            gap = self.t_abs[e.dst] - self.t_abs[e.src] + ii * e.distance
            route = self._route_edge(e, p_src, p_dst, gap, occ, extra, headroom)
            if route is None:
                obs.event("space.route", ok=False, ii=ii,
                          edge=f"{e.src}->{e.dst}", routed=len(routes))
                return None
            for pe, t in zip(route.path, route.times):
                extra[t % ii] |= 1 << pe
                headroom[t % ii] -= 1
            routes.append(route)
        if routes:
            obs.event("space.route", ok=True, ii=ii, routed=len(routes),
                      movs=sum(len(r.path) for r in routes))
        return routes

    def _route_edge(
        self, e, p_src: int, p_dst: int, gap: int,
        occ: list[int], extra: list[int], headroom: list[int],
    ) -> MaterializedRoute | None:
        ii = self.ii
        t_lo = self.t_abs[e.src]              # movs fire strictly after this
        t_hi = t_lo + gap                     # ... and strictly before this
        max_movs = min(self.max_hops, gap - 1)
        closed, alu = self.closed, self.alu_mask

        def assign_times(path: tuple[int, ...]) -> tuple[int, ...] | None:
            k = len(path)
            ts: list[int] = []
            t_prev = t_lo
            for j, pe in enumerate(path):
                t = t_prev + 1
                limit = t_hi - (k - j)        # leave room for the tail movs
                while t <= limit and ((occ[t % ii] | extra[t % ii]) >> pe) & 1:
                    t += 1
                if t > limit:
                    return None
                ts.append(t)
                t_prev = t
            return tuple(ts)

        budget = 256                          # path attempts per edge
        free_total = sum(h for h in headroom if h > 0)
        for k in range(1, max_movs + 1):
            # a chain of k movs needs k free slots (steps may host several)
            if free_total < k:
                return None
            # DFS over intermediate PEs: step j must stay within closed reach
            # of its predecessor and within (k - j) hops of the destination
            stack: list[tuple[int, tuple[int, ...]]] = [(p_src, ())]
            while stack and budget > 0:
                prev, path = stack.pop()
                j = len(path)
                if j == k:
                    budget -= 1
                    ts = assign_times(path)
                    if ts is not None:
                        return MaterializedRoute(
                            edge=(e.src, e.dst, e.distance),
                            path=path, times=ts,
                        )
                    continue
                cand = closed[prev] & alu & self.reach[k - j][p_dst]
                pes: list[int] = []
                while cand:
                    b = cand & -cand
                    pes.append(b.bit_length() - 1)
                    cand ^= b
                # LIFO stack: push descending so lowest PE is explored first
                for pe in reversed(pes):
                    stack.append((pe, path + (pe,)))
        return None


def check_routes(
    dfg: DFG, cgra: CGRA, t_abs: list[int], placement: list[int],
    ii: int, routes,
) -> list[str]:
    """Independent validator of route-through provenance (DESIGN.md §12.2).

    ``dfg`` is the *rewritten* DFG and ``routes`` its ``dfg.Route`` records.
    Every structural property (slot exclusivity, chain adjacency, dependency
    ordering) is already covered by ``check_monomorphism``/
    ``check_time_solution`` on the rewritten graph; this re-checks the
    route-specific contract — movs really are movs, chains connect their
    endpoints through closed-adjacent PEs, and firing times sit strictly
    inside the routed edge's time window.
    """
    errs: list[str] = []
    for r in routes:
        chain = (r.src, *r.movs, r.dst)
        for m in r.movs:
            if not 0 <= m < dfg.num_nodes or dfg.ops[m] != "mov":
                errs.append(f"route {r.src}->{r.dst}: node {m} is not a mov")
        for a, b in zip(chain, chain[1:]):
            if not cgra.adjacency[placement[a]][placement[b]]:
                errs.append(
                    f"route {r.src}->{r.dst}: hop {a}->{b} maps to "
                    f"non-adjacent PEs {placement[a]},{placement[b]}"
                )
        lo, hi = t_abs[r.src], t_abs[r.dst] + ii * r.distance
        times = [t_abs[m] for m in r.movs]
        if not all(x < y for x, y in zip([lo, *times], [*times, hi])):
            errs.append(
                f"route {r.src}->{r.dst}: mov times {times} not strictly "
                f"inside ({lo}, {hi})"
            )
    return errs


def check_monomorphism(
    dfg: DFG, cgra: CGRA, labels: list[int], placement: list[int], ii: int
) -> list[str]:
    """Independent validator of mono1/mono2/mono3; returns violations."""
    errs: list[str] = []
    seen: dict[tuple[int, int], int] = {}
    for v in dfg.nodes:
        key = (placement[v], labels[v])
        if key in seen:
            errs.append(f"mono1: nodes {seen[key]} and {v} share MRRG vertex {key}")
        seen[key] = v
        if not 0 <= placement[v] < cgra.num_pes:
            errs.append(f"node {v} placed out of range: {placement[v]}")
            continue
        if cgra.heterogeneous:
            cls = op_class(dfg.ops[v])
            if not cgra.capable(placement[v], cls):
                errs.append(
                    f"capability: node {v} ({dfg.ops[v]}, class {cls!r}) "
                    f"placed on incapable PE {placement[v]}"
                )
    adj = dfg.undirected_adjacency()
    for v in dfg.nodes:
        for u in adj[v]:
            if u < v:
                continue
            if not cgra.adjacency[placement[u]][placement[v]]:
                errs.append(
                    f"mono3: edge {{{u},{v}}} maps to non-adjacent PEs "
                    f"{placement[u]},{placement[v]}"
                )
    return errs

"""Annealing space backend: clustered placement for very large fabrics.

The exact engine (space_backends/exact.py) pays for its completeness in word
width: every candidate intersection is an ``num_pes``-bit AND, so a 100×100
fabric makes each visited node ~60× more expensive than at 4×4 while the
search tree keeps its depth. This backend trades completeness for per-move
cost that is independent of fabric size, the classic two-phase
cluster-then-anneal placement shape (DESIGN.md §13.2):

1. **Cluster** the time-partitioned DFG: k-means-style grouping over
   undirected DFG hop distance (farthest-point seeding, multi-source BFS
   assignment, one medoid refinement), so tightly coupled nodes travel
   together.
2. **Seed** cluster centroids on a coarse tile grid over the fabric, then
   place each node greedily on the nearest free capable (PE, step) slot to
   its cluster centre (nudged toward already-placed neighbours).
3. **Anneal**: simulated annealing at fixed time labels, min-conflicts
   flavoured — most moves pick a *violated* edge and drop one endpoint into
   the other's allowance neighbourhood (swapping with any occupant), with a
   small exploration share of blind relocates/swaps. The energy is
   topology-exact grid distance — Manhattan (mesh), wrapped Manhattan
   (torus), Chebyshev (diagonal), ``ceil(|dr|/2) + ceil(|dc|/2)``
   (one-hop) — which equals true closed-adjacency hop distance on every
   supported topology, so "every edge within its allowance" is exactly the
   monomorphism condition without any bitset work.
4. **Legalise/deblock**: when route-through is enabled, a zero-violation
   placement still has to realise its long edges as ``mov`` chains; the
   shared repair machinery (``_RouteContext.materialize``) does that, and a
   failure kicks a few nodes loose and resumes annealing (deblocking)
   instead of restarting cold.

Determinism contract matches the exact engine: ``timeout_s=None`` plus a
``node_budget`` (interpreted as total SA moves) makes the search a pure
function of its inputs and seed.
"""

from __future__ import annotations

import math
import random
import time as _time
from collections import deque

from ... import obs
from ..cgra import CGRA, op_class
from ..dfg import DFG
from .base import (
    SpaceBudget,
    SpaceSolution,
    SpaceStats,
    _RouteContext,
    check_monomorphism,
    register_space_backend,
)

# default SA moves per restart when the caller sets neither budget knob
_DEFAULT_MOVES = 20_000
# materialization attempts per restart before giving up on this start
_MAX_ROUTE_ATTEMPTS = 25
# share of moves that repair a violated edge (rest explore blindly)
_REPAIR_PROB = 0.85


def _grid_dist(topology: str, rows: int, cols: int):
    """Topology-exact hop distance between PEs, O(1) per query."""
    if topology == "mesh":
        def d(ar, ac, br, bc):
            return abs(ar - br) + abs(ac - bc)
    elif topology == "torus":
        def d(ar, ac, br, bc):
            dr, dc = abs(ar - br), abs(ac - bc)
            return min(dr, rows - dr) + min(dc, cols - dc)
    elif topology == "diagonal":
        def d(ar, ac, br, bc):
            return max(abs(ar - br), abs(ac - bc))
    else:  # one-hop: cardinal strides of 1 and 2
        def d(ar, ac, br, bc):
            return (abs(ar - br) + 1) // 2 + (abs(ac - bc) + 1) // 2
    return d


def _cluster(dfg: DFG) -> tuple[list[int], int]:
    """k-means-style clustering over DFG hop distance.

    Returns (cluster id per node, k). Fully deterministic: farthest-point
    seeding from the highest-degree node, nearest-seed assignment (ties to
    the lower cluster id), one medoid-refinement pass.
    """
    n = dfg.num_nodes
    adj = dfg.undirected_adjacency()
    k = max(1, min(n, round(math.sqrt(n))))

    def bfs(src: int) -> list[int]:
        dist = [-1] * n
        dist[src] = 0
        q = deque([src])
        while q:
            v = q.popleft()
            for u in adj[v]:
                if dist[u] < 0:
                    dist[u] = dist[v] + 1
                    q.append(u)
        return dist

    degs = [len(adj[v]) for v in range(n)]
    seeds = [max(range(n), key=lambda v: (degs[v], -v))]
    seed_dist = [bfs(seeds[0])]
    far = n + 1                      # unreachable sorts farthest: spread
    while len(seeds) < k:            # across DFG components first
        def spread(v: int) -> int:
            return min(far if d[v] < 0 else d[v] for d in seed_dist)
        v = max(
            (v for v in range(n) if v not in seeds),
            key=lambda v: (spread(v), degs[v], -v),
        )
        seeds.append(v)
        seed_dist.append(bfs(v))

    def assign() -> list[int]:
        return [
            min(
                range(len(seeds)),
                key=lambda i: (far if seed_dist[i][v] < 0 else seed_dist[i][v], i),
            )
            for v in range(n)
        ]

    clusters = assign()
    # one medoid refinement: re-centre each cluster on its min-eccentricity
    # member, then re-assign
    for i in range(len(seeds)):
        members = [v for v in range(n) if clusters[v] == i]
        if not members:
            continue
        best, best_ecc = seeds[i], None
        for v in members:
            d = bfs(v)
            ecc = max(far if d[u] < 0 else d[u] for u in members)
            if best_ecc is None or (ecc, v) < (best_ecc, best):
                best, best_ecc = v, ecc
        if best != seeds[i]:
            seeds[i] = best
            seed_dist[i] = bfs(best)
    return assign(), len(seeds)


class AnnealSpaceBackend:
    """Clustered placement + simulated annealing (DESIGN.md §13.2)."""

    name = "anneal"

    def place(
        self,
        dfg: DFG,
        cgra: CGRA,
        labels: list[int],
        ii: int,
        *,
        t_abs: list[int] | None = None,
        max_route_hops: int = 0,
        budget: SpaceBudget | None = None,
        seed: int = 0,
        stats: SpaceStats | None = None,
        should_stop=None,
    ) -> SpaceSolution | None:
        b = budget if budget is not None else SpaceBudget()
        stats = stats if stats is not None else SpaceStats()
        n = dfg.num_nodes
        num_pes = cgra.num_pes
        rows, cols = cgra.rows, cgra.cols
        if n > num_pes * ii:
            return None
        for v in range(n):
            if not 0 <= labels[v] < ii:
                raise ValueError(f"label out of range for node {v}: {labels[v]}")

        full = (1 << num_pes) - 1
        if cgra.heterogeneous:
            cap_masks = cgra.capability_masks
            node_mask = [cap_masks[op_class(dfg.ops[v])] for v in range(n)]
            if not all(node_mask):
                return None
        else:
            node_mask = [full] * n

        route_ctx = (
            _RouteContext(dfg, cgra, labels, t_abs, ii, max_route_hops)
            if max_route_hops > 0 else None
        )
        dist_rc = _grid_dist(cgra.topology, rows, cols)

        def dist_pe(pu: int, pv: int) -> int:
            return dist_rc(pu // cols, pu % cols, pv // cols, pv % cols)

        # undirected pair list with per-pair hop allowance; incident index
        pair_allow: dict[tuple[int, int], int] = {}
        for e in dfg.edges:
            if e.src == e.dst:
                continue
            key = (e.src, e.dst) if e.src < e.dst else (e.dst, e.src)
            a = route_ctx.pair_allow[key] if route_ctx is not None else 1
            pair_allow[key] = a
        pairs = sorted(pair_allow.items())    # deterministic iteration order
        inc: list[list[tuple[int, int]]] = [[] for _ in range(n)]
        for (u, v), a in pairs:
            inc[u].append((v, a))
            inc[v].append((u, a))

        def edge_cost(pu: int, pv: int, allow: int) -> tuple[int, float]:
            d = dist_pe(pu, pv)
            over = d - allow
            if over > 0:
                return over, over * over + 0.01 * d
            return 0, 0.01 * d

        # allowance-neighbourhood offsets, cached per allowance level: the
        # cells a repair move may drop an endpoint into
        _nbhd_cache: dict[int, tuple[tuple[int, int], ...]] = {}

        def nbhd_offsets(a: int) -> tuple[tuple[int, int], ...]:
            offs = _nbhd_cache.get(a)
            if offs is None:
                s = 2 * a if cgra.topology == "one-hop" else a
                offs = tuple(
                    (dr, dc)
                    for dr in range(-s, s + 1)
                    for dc in range(-s, s + 1)
                    if dist_rc(0, 0, abs(dr), abs(dc)) <= a
                )
                _nbhd_cache[a] = offs
            return offs

        def nbhd_cells(pe: int, a: int) -> list[int]:
            pr, pc = pe // cols, pe % cols
            out: list[int] = []
            for dr, dc in nbhd_offsets(a):
                nr, nc = pr + dr, pc + dc
                if cgra.topology == "torus":
                    nr %= rows
                    nc %= cols
                elif not (0 <= nr < rows and 0 <= nc < cols):
                    continue
                out.append(nr * cols + nc)
            return out

        _ring_cache: dict[int, tuple[tuple[int, int], ...]] = {}

        def nearest_free(target_pe: int, free: int) -> int:
            """First free-capable PE by expanding metric rings from target.

            O(cells inspected) instead of a full ``num_pes``-bit mask scan —
            the near-empty huge-fabric case finds a slot within a few rings.
            """
            tr, tc = target_pe // cols, target_pe % cols
            for a in range(diam + 1):
                ring = _ring_cache.get(a)
                if ring is None:
                    s = 2 * a if cgra.topology == "one-hop" else a
                    ring = tuple(
                        (dr, dc)
                        for dr in range(-s, s + 1)
                        for dc in range(-s, s + 1)
                        if dist_rc(0, 0, abs(dr), abs(dc)) == a
                    )
                    _ring_cache[a] = ring
                for dr, dc in ring:
                    nr, nc = tr + dr, tc + dc
                    if cgra.topology == "torus":
                        nr %= rows
                        nc %= cols
                    elif not (0 <= nr < rows and 0 <= nc < cols):
                        continue
                    pe = nr * cols + nc
                    if (free >> pe) & 1:
                        return pe
            return -1

        start = _time.perf_counter()
        wall = b.timeout_s if b.timeout_s is not None else float("inf")
        n_restarts = max(1, b.restarts)
        weights = [1] + [1 << min(r, 30) for r in range(n_restarts - 1)]
        total_w = sum(weights)

        clusters, k = _cluster(dfg)
        # coarse tile grid for the k cluster centroids, packed into a compact
        # window at the fabric centre: a legal embedding only ever spans a
        # few cells per time step (every edge must close to within its hop
        # allowance), so on a huge fabric the extra area is pure noise —
        # seeding compactly makes 100×100 behave like 20×20
        g = max(1, math.ceil(math.sqrt(k)))
        span_r = min(rows, max(2 * g, math.ceil(math.sqrt(n)) + g))
        span_c = min(cols, max(2 * g, math.ceil(math.sqrt(n)) + g))
        off_r, off_c = (rows - span_r) / 2, (cols - span_c) / 2
        centroid = [
            (off_r + (i // g + 0.5) * span_r / g,
             off_c + (i % g + 0.5) * span_c / g)
            for i in range(k)
        ]

        # deterministic init order: clusters in id order, BFS inside each
        adj = dfg.undirected_adjacency()
        order: list[int] = []
        seen = [False] * n
        for ci in range(k):
            for s in sorted(v for v in range(n) if clusters[v] == ci):
                if seen[s]:
                    continue
                seen[s] = True
                q = deque([s])
                while q:
                    v = q.popleft()
                    order.append(v)
                    for u in sorted(adj[v]):
                        if not seen[u] and clusters[u] == ci:
                            seen[u] = True
                            q.append(u)

        diam = dist_rc(0, 0, rows - 1, cols - 1) or 1

        for r in range(n_restarts):
            remaining = wall - (_time.perf_counter() - start)
            if remaining <= 0:
                break
            if should_stop is not None and should_stop():
                break
            stats.restarts += 1
            rng = random.Random(seed * 7919 + r)
            frac = weights[r] / total_w
            deadline = (
                _time.perf_counter() + min(wall * frac, remaining)
                if wall != float("inf") else None
            )
            if b.node_budget is not None:
                moves_budget = max(500, int(b.node_budget * frac))
            else:
                moves_budget = _DEFAULT_MOVES

            # ---------------- initial placement: nearest free capable slot
            placement = [-1] * n
            occ = [0] * ii
            owner: list[dict[int, int]] = [dict() for _ in range(ii)]
            failed = False
            for v in order:
                tr, tc = centroid[clusters[v]]
                placed_nb = [placement[u] for u, _ in inc[v] if placement[u] >= 0]
                if placed_nb:
                    tr = sum(p // cols for p in placed_nb) / len(placed_nb)
                    tc = sum(p % cols for p in placed_nb) / len(placed_nb)
                if r > 0:                 # restart diversity: jitter targets
                    tr += rng.uniform(-span_r / 4, span_r / 4)
                    tc += rng.uniform(-span_c / 4, span_c / 4)
                tri = min(rows - 1, max(0, round(tr)))
                tci = min(cols - 1, max(0, round(tc)))
                best = nearest_free(
                    tri * cols + tci, node_mask[v] & ~occ[labels[v]]
                )
                if best < 0:
                    failed = True         # no capable free slot at this step
                    break
                placement[v] = best
                occ[labels[v]] |= 1 << best
                owner[labels[v]][best] = v
            if failed:
                return None               # capacity infeasible, rng-independent

            viol = 0
            energy = 0.0
            bad: set[tuple[int, int]] = set()
            for (u, v), a in pairs:
                o, c = edge_cost(placement[u], placement[v], a)
                viol += o
                energy += c
                if o:
                    bad.add((u, v))

            def node_cost(v: int) -> tuple[int, float]:
                o_sum, c_sum = 0, 0.0
                pv = placement[v]
                for u, a in inc[v]:
                    o, c = edge_cost(pv, placement[u], a)
                    o_sum += o
                    c_sum += c
                return o_sum, c_sum

            def refresh_bad(v: int) -> None:
                for u, a in inc[v]:
                    key = (u, v) if u < v else (v, u)
                    if edge_cost(placement[u], placement[v], a)[0]:
                        bad.add(key)
                    else:
                        bad.discard(key)

            def move_to(v: int, pe: int) -> None:
                lv = labels[v]
                old = placement[v]
                occ[lv] = (occ[lv] & ~(1 << old)) | (1 << pe)
                del owner[lv][old]
                owner[lv][pe] = v
                placement[v] = pe

            def try_finish() -> SpaceSolution | None:
                """viol==0: certify (and, under routing, materialise)."""
                if route_ctx is None:
                    if check_monomorphism(dfg, cgra, labels, placement, ii):
                        return None       # metric/validator disagree: reject
                    return SpaceSolution(ii=ii, placement=list(placement))
                routes = route_ctx.materialize(placement, occ)
                if routes is None:
                    stats.route_failures += 1
                    return None
                return SpaceSolution(
                    ii=ii, placement=list(placement), routes=tuple(routes)
                )

            def rand_near(pe: int) -> int:
                """Random PE within the embedding-scale window around ``pe``."""
                nr = pe // cols + rng.randint(-span_r, span_r)
                nc = pe % cols + rng.randint(-span_c, span_c)
                if cgra.topology == "torus":
                    return nr % rows * cols + nc % cols
                nr = min(rows - 1, max(0, nr))
                nc = min(cols - 1, max(0, nc))
                return nr * cols + nc

            route_attempts = 0
            # energy-curve telemetry (DESIGN.md §15, ROADMAP "anneal quality
            # tuning"): purely observational — counters and obs events only,
            # never an rng draw, so traced and untraced runs take the
            # identical search path
            traced = obs.enabled()
            accepts = proposals = 0

            def emit_restart(found: bool) -> None:
                # per-restart energy-curve summary: how the restart ended
                # (energy/violations left, realised accept rate) — the data
                # the anneal-quality tuning reads back out of traces
                if traced:
                    obs.event(
                        "space.anneal.restart", ii=ii, restart=r, found=found,
                        energy=round(energy, 3), viol=viol,
                        accepts=accepts, proposals=proposals,
                        accept_rate=(round(accepts / proposals, 4)
                                     if proposals else None),
                        route_attempts=route_attempts,
                    )

            if viol == 0:
                sol = try_finish()
                if sol is not None:
                    emit_restart(found=True)
                    stats.search_time_s += _time.perf_counter() - start
                    return sol
                route_attempts += 1

            # ---------------- min-conflicts simulated annealing
            by_label: dict[int, list[int]] = {}
            for v in range(n):
                by_label.setdefault(labels[v], []).append(v)
            t0 = 2.0
            t_min = 0.02
            alpha = (t_min / t0) ** (1.0 / max(1, moves_budget))
            temp = t0
            aborted = False
            for step in range(moves_budget):
                temp *= alpha
                if not step & 0xFF:
                    if should_stop is not None and should_stop():
                        aborted = True
                        break
                    if deadline is not None and _time.perf_counter() > deadline:
                        break
                    if traced and not step & 0xFFF:
                        obs.event(
                            "space.anneal.sample", ii=ii, restart=r,
                            step=step, energy=round(energy, 3), viol=viol,
                            temperature=round(temp, 5),
                            accept_rate=(round(accepts / proposals, 4)
                                         if proposals else None),
                        )
                stats.nodes_visited += 1

                # -------- propose: repair a violated edge, or explore
                x = w = -1                # mover and (optional) swap partner
                target = -1
                if bad and rng.random() < _REPAIR_PROB:
                    key = sorted(bad)[rng.randrange(len(bad))]
                    x, y = key if rng.random() < 0.5 else key[::-1]
                    cells = nbhd_cells(placement[y], pair_allow[key])
                    pe = cells[rng.randrange(len(cells))]
                    if pe == placement[x] or not (node_mask[x] >> pe) & 1:
                        continue
                    z = owner[labels[x]].get(pe, -1)
                    if z >= 0:
                        if not (node_mask[z] >> placement[x]) & 1:
                            continue
                        w = z
                    target = pe
                else:
                    x = rng.randrange(n)
                    lx = labels[x]
                    peers = by_label[lx]
                    if len(peers) > 1 and rng.random() < 0.5:
                        z = peers[rng.randrange(len(peers))]
                        if z == x:
                            continue
                        if not (
                            (node_mask[x] >> placement[z]) & 1
                            and (node_mask[z] >> placement[x]) & 1
                        ):
                            continue
                        w, target = z, placement[z]
                    else:
                        px = placement[x]
                        for _ in range(8):
                            nr = px // cols + rng.randint(-3, 3)
                            nc = px % cols + rng.randint(-3, 3)
                            if cgra.topology == "torus":
                                nr %= rows
                                nc %= cols
                            elif not (0 <= nr < rows and 0 <= nc < cols):
                                continue
                            pe = nr * cols + nc
                            if (node_mask[x] >> pe) & 1 and not (occ[lx] >> pe) & 1:
                                target = pe
                                break
                        if target < 0:
                            for _ in range(16):
                                pe = rand_near(px)
                                if (node_mask[x] >> pe) & 1 and not (occ[lx] >> pe) & 1:
                                    target = pe
                                    break
                        if target < 0:
                            continue

                # -------- evaluate delta (x moves to target; w takes x's slot)
                proposals += 1
                px = placement[x]
                if w >= 0:
                    o0, c0 = node_cost(x)[0] + node_cost(w)[0], node_cost(x)[1] + node_cost(w)[1]
                    placement[x], placement[w] = target, px
                    o1 = node_cost(x)[0] + node_cost(w)[0]
                    c1 = node_cost(x)[1] + node_cost(w)[1]
                    # x–w edges are counted from both sides in both states,
                    # so the doubled terms cancel in the delta
                    d_o, d_c = o1 - o0, c1 - c0
                    if d_c <= 0 or rng.random() < math.exp(-d_c / temp):
                        lx, lw = labels[x], labels[w]
                        owner[lx][target] = x
                        owner[lw][px] = w
                        viol += d_o
                        energy += d_c
                        refresh_bad(x)
                        refresh_bad(w)
                        accepts += 1
                    else:
                        placement[x], placement[w] = px, target
                        stats.backtracks += 1
                        continue
                else:
                    o0, c0 = node_cost(x)
                    placement[x] = target
                    o1, c1 = node_cost(x)
                    d_o, d_c = o1 - o0, c1 - c0
                    if d_c <= 0 or rng.random() < math.exp(-d_c / temp):
                        placement[x] = px
                        move_to(x, target)
                        viol += d_o
                        energy += d_c
                        refresh_bad(x)
                        accepts += 1
                    else:
                        placement[x] = px
                        stats.backtracks += 1
                        continue

                if viol == 0:
                    sol = try_finish()
                    if sol is not None:
                        emit_restart(found=True)
                        stats.search_time_s += _time.perf_counter() - start
                        return sol
                    route_attempts += 1
                    if route_attempts > _MAX_ROUTE_ATTEMPTS:
                        break
                    # deblock: kick a few nodes loose and keep annealing warm
                    for _ in range(max(2, n // 10)):
                        v = rng.randrange(n)
                        lv = labels[v]
                        for _ in range(16):
                            pe = rand_near(placement[v])
                            if (node_mask[v] >> pe) & 1 and not (occ[lv] >> pe) & 1:
                                move_to(v, pe)
                                break
                    viol, energy = 0, 0.0
                    bad.clear()
                    for (u, v), a in pairs:
                        o, c = edge_cost(placement[u], placement[v], a)
                        viol += o
                        energy += c
                        if o:
                            bad.add((u, v))
                    temp = max(temp, t0 / 4)
            emit_restart(found=False)
            if aborted:
                break
        stats.search_time_s += _time.perf_counter() - start
        return None


register_space_backend("anneal", AnnealSpaceBackend, aliases=("sa", "cluster"))

"""ASAP/ALAP/Mobility/Kernel-Mobility schedules and mII (paper §III-B, §IV-B).

All ops are single-cycle (the paper's machine model). ASAP/ALAP are computed on
the intra-iteration (acyclic) subgraph; loop-carried dependencies enter later
as modulo constraints in the SMT formulation.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from .cgra import CGRA
from .dfg import DFG


def asap_schedule(dfg: DFG) -> list[int]:
    order = _topo_order(dfg)
    t = [0] * dfg.num_nodes
    for v in order:
        for e in dfg.predecessors(v, carried=False):
            t[v] = max(t[v], t[e.src] + 1)
    return t


def alap_schedule(dfg: DFG, length: int | None = None) -> list[int]:
    asap = asap_schedule(dfg)
    horizon = length if length is not None else max(asap, default=0)
    t = [horizon] * dfg.num_nodes
    for v in reversed(_topo_order(dfg)):
        for e in dfg.successors(v, carried=False):
            t[v] = min(t[v], t[e.dst] - 1)
    if any(t[v] < asap[v] for v in dfg.nodes):
        raise ValueError("ALAP horizon shorter than critical path")
    return t


@dataclass(frozen=True)
class MobilitySchedule:
    """MobS: per time step, the set of nodes whose [asap, alap] covers it."""

    asap: tuple[int, ...]
    alap: tuple[int, ...]

    @property
    def length(self) -> int:
        return max(self.alap, default=0) + 1

    def rows(self) -> list[list[int]]:
        return [
            [v for v in range(len(self.asap)) if self.asap[v] <= t <= self.alap[v]]
            for t in range(self.length)
        ]

    def mobility(self, v: int) -> int:
        return self.alap[v] - self.asap[v]


def mobility_schedule(dfg: DFG) -> MobilitySchedule:
    return MobilitySchedule(tuple(asap_schedule(dfg)), tuple(alap_schedule(dfg)))


@dataclass(frozen=True)
class KMS:
    """Kernel Mobility Schedule: MobS folded by II (paper §IV-B).

    Entry (v, it) at kernel row t means node v of fold/iteration ``it`` may be
    scheduled at kernel step t, i.e. at absolute time ``t + it*II`` within the
    MobS window. The KMS is the superset of all schedules for a given II.
    """

    mobs: MobilitySchedule
    ii: int

    @property
    def num_folds(self) -> int:
        return math.ceil(self.mobs.length / self.ii)

    def rows(self) -> list[list[tuple[int, int]]]:
        out: list[list[tuple[int, int]]] = [[] for _ in range(self.ii)]
        for t, row in enumerate(self.mobs.rows()):
            fold, kt = divmod(t, self.ii)
            out[kt].extend((v, fold) for v in row)
        return out

    def slots(self, v: int) -> list[tuple[int, int]]:
        """All (kernel_step, fold) options for node v."""
        return [
            divmod(t, self.ii)[::-1]
            for t in range(self.mobs.asap[v], self.mobs.alap[v] + 1)
        ]


def modulo_windows(
    dfg: DFG, ii: int, horizon: int
) -> tuple[list[int], list[int]] | None:
    """Modulo-aware [asap, alap] windows (iterative-modulo-scheduling style).

    Every edge (u→v, distance d) imposes t_v >= t_u + 1 - II*d, including the
    loop-carried ones the plain DAG ASAP/ALAP ignore. Longest-path fixpoints
    over this cyclic constraint graph (Bellman-Ford; no positive cycles when
    II >= RecII) tighten the windows substantially for recurrence-heavy DFGs,
    shrinking the SMT encoding. Returns None if infeasible at this (II,
    horizon) — a free UNSAT proof.
    """
    n = dfg.num_nodes
    asap = asap_schedule(dfg)
    try:
        alap = alap_schedule(dfg, length=horizon)
    except ValueError:
        return None
    for _ in range(n + 1):
        changed = False
        for e in dfg.edges:
            lo = asap[e.src] + 1 - ii * e.distance
            if lo > asap[e.dst]:
                asap[e.dst] = lo
                changed = True
            hi = alap[e.dst] - 1 + ii * e.distance
            if hi < alap[e.src]:
                alap[e.src] = hi
                changed = True
        if not changed:
            break
    else:
        return None  # still changing after n rounds: positive cycle (II < RecII)
    if any(asap[v] > alap[v] for v in range(n)):
        return None
    return asap, alap


def res_ii(dfg: DFG, cgra: CGRA) -> int:
    """ResII = ceil(|V_G| / |PEs|), sharpened per capability class.

    On heterogeneous grids each op class only has ``class_capacity`` slots
    per kernel step (mem additionally bounded by the port count), so
    ResII = max over classes of ceil(|class members| / capacity) — the
    paper's scalar bound is the homogeneous special case. A class with no
    capable PEs is the mapper's fail-fast territory
    (``CGRA.unsupported_ops``), not a finite ResII; it is skipped here.
    """
    base = math.ceil(dfg.num_nodes / cgra.num_pes)
    if cgra.heterogeneous:
        from .cgra import op_class

        members: dict[str, int] = {}
        for v in dfg.nodes:
            cls = op_class(dfg.ops[v])
            members[cls] = members.get(cls, 0) + 1
        for cls, n in members.items():
            cap = cgra.class_capacity(cls)
            if cap > 0:
                base = max(base, math.ceil(n / cap))
    return base


def rec_ii(dfg: DFG) -> int:
    """RecII = max over dependence cycles of ceil(length/distance)."""
    return dfg.rec_ii()


def min_ii(dfg: DFG, cgra: CGRA) -> int:
    return max(res_ii(dfg, cgra), rec_ii(dfg))


def _topo_order(dfg: DFG) -> list[int]:
    indeg = [0] * dfg.num_nodes
    adj: list[list[int]] = [[] for _ in dfg.nodes]
    for e in dfg.intra_edges():
        adj[e.src].append(e.dst)
        indeg[e.dst] += 1
    stack = [v for v in dfg.nodes if indeg[v] == 0]
    order: list[int] = []
    while stack:
        v = stack.pop()
        order.append(v)
        for w in adj[v]:
            indeg[w] -= 1
            if indeg[w] == 0:
                stack.append(w)
    if len(order) != dfg.num_nodes:
        raise ValueError(f"{dfg.name}: cyclic intra-iteration dependencies")
    return order

"""Compilation service: batch mapping across processes + persistent cache.

The throughput layer over the single-shot mapper (DESIGN.md §8–§9):

* :func:`compile_many` — process-pool batch compiler (per-job deadlines,
  cooperative cancellation, deterministic mode for CI).
* :func:`map_dfg_racing` — intra-job parallelism: one mapping problem's
  (II, slack) windows raced across workers with first-winner cancellation.
* :class:`DiskMappingCache` — content-addressed on-disk mapping store the
  in-memory LRU layers over; ``$REPRO_CACHE_DIR`` enables it globally.

CLI front-end: ``python -m repro.compile`` (see ``repro/compile.py``).
"""

from .batch import (
    CompileJob,
    CompileReport,
    JobReport,
    compile_many,
    map_dfg_racing,
)
from .cache import CACHE_VERSION, CacheStats, DiskMappingCache, resolve_cache_dir

__all__ = [
    "CompileJob",
    "CompileReport",
    "JobReport",
    "compile_many",
    "map_dfg_racing",
    "CACHE_VERSION",
    "CacheStats",
    "DiskMappingCache",
    "resolve_cache_dir",
]

"""Process-pool batch compilation service (DESIGN.md §8).

Two axes of parallelism over the portfolio mapper (``core/mapper.py``):

* **Inter-job** — :func:`compile_many` maps many independent DFGs across a
  process pool (the search core is pure Python, so threads would serialise on
  the GIL). Each job carries its own per-job deadline; a shared stop event
  gives cooperative cancellation of in-flight work, and ``jobs<=1`` degrades
  to a fully in-process sequential run (used by deterministic CI smoke).
* **Intra-job** — :func:`map_dfg_racing` races ONE hard mapping problem by
  striping the canonical (II, slack) window order across workers
  (``window_offset``/``window_stride`` in ``map_dfg``). The first worker to
  finish with a mapping sets the stop event; the rest observe it at their
  next budget check and return their best-so-far (*first-winner
  cancellation*). The lowest II among the returned results wins.

Both layers reuse the round/budget logic of ``map_dfg`` unchanged — workers
run the ordinary portfolio search, just on a subset of windows — and both
share work across runs through the persistent disk cache (DESIGN.md §9).
"""

from __future__ import annotations

import os
import time as _time
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait
from concurrent.futures.process import BrokenProcessPool
from dataclasses import dataclass, field
from typing import Sequence

from ... import obs
from ..cgra import CGRA
from ..dfg import DFG
from ..mapper import MapResult, map_dfg


def _as_mapper_kwargs(options) -> dict:
    """Normalise per-job/batch options: a plain kwarg dict passes through, a
    typed ``repro.api.CompileOptions`` contributes its mapper fields.

    Duck-typed on ``mapper_kwargs`` rather than importing the api layer —
    ``repro.api`` imports this module, so a type import would cycle.
    """
    if options is None:
        return {}
    if isinstance(options, dict):
        return dict(options)
    return options.mapper_kwargs()

# Worker-side stop event, installed by the pool initializer. Lives in a
# module global because multiprocessing primitives can only be inherited at
# process creation, not pickled per task.
_STOP_EVENT = None


def _pool_init(stop_event) -> None:
    global _STOP_EVENT
    _STOP_EVENT = stop_event


def _should_stop():
    ev = _STOP_EVENT
    return None if ev is None else ev.is_set


# ------------------------------------------------------------------- jobs

@dataclass
class CompileJob:
    """One unit of batch work: a DFG, a target CGRA, per-job overrides.

    ``options`` is forwarded to :func:`repro.core.mapper.map_dfg` and wins
    over the batch-level defaults: either a kwarg dict (e.g.
    ``{"max_slack": 2, "max_register_pressure": 8}``) or a typed
    :class:`repro.api.CompileOptions` (its mapper fields are used).
    """

    dfg: DFG
    cgra: CGRA
    name: str = ""
    options: dict = field(default_factory=dict)  # or repro.api.CompileOptions

    def __post_init__(self) -> None:
        if not self.name:
            self.name = self.dfg.name


@dataclass
class JobReport:
    """Per-job outcome row of a :class:`CompileReport` (JSON-friendly).

    Carries the full mapper telemetry (phase timings, search trace) plus —
    when the job succeeded — the raw ``t_abs``/``placement`` arrays, so the
    API layer (``repro.api.CompileResult.from_job_report``) can reconstruct
    the complete :class:`~repro.core.mapper.Mapping` on the caller's side of
    the process boundary without re-solving.
    """

    name: str
    ok: bool
    ii: int | None
    m_ii: int
    wall_s: float
    cache_hit: bool = False
    disk_cache_hit: bool = False
    backend: str = ""
    space_backend: str = ""
    reason: str = ""
    cancelled: bool = False
    time_phase_s: float = 0.0
    space_phase_s: float = 0.0
    validate_s: float = 0.0
    mono_failures: int = 0
    res_ii: int = -1
    rec_ii: int = -1
    rounds: int = 0
    windows_opened: int = 0
    time_solutions_tried: int = 0
    space_nodes_visited: int = 0
    # solver/cache telemetry mirrored from MapperStats (DESIGN.md §15.3) so
    # the api layer builds an identical ``CompileResult.metrics`` block on
    # the caller's side of the process boundary
    time_steps: int = 0
    space_restarts: int = 0
    mem_cache_lookups: int = 0
    mem_cache_hits: int = 0
    disk_cache_lookups: int = 0
    disk_cache_hits: int = 0
    disk_cache_promotions: int = 0
    # the mapping itself (success only); excluded from as_dict row payloads.
    # ``routes`` is the route-through spec (src, dst, distance, n_movs) rows
    # needed to rebuild the rewritten DFG caller-side (DESIGN.md §12.2).
    t_abs: list[int] | None = None
    placement: list[int] | None = None
    routes: list[list[int]] | None = None

    @property
    def solved(self) -> bool:
        """True when the mapper actually searched (neither cache layer hit)."""
        return self.ok and not (self.cache_hit or self.disk_cache_hit)

    def as_dict(self) -> dict:
        return {
            "name": self.name,
            "ok": self.ok,
            "ii": self.ii,
            "mII": self.m_ii,
            "wall_s": round(self.wall_s, 4),
            "cache_hit": self.cache_hit,
            "disk_cache_hit": self.disk_cache_hit,
            "backend": self.backend,
            "space_backend": self.space_backend,
            "reason": self.reason,
            "cancelled": self.cancelled,
            "time_phase_s": round(self.time_phase_s, 4),
            "space_phase_s": round(self.space_phase_s, 4),
            "mono_failures": self.mono_failures,
        }


@dataclass
class CompileReport:
    """Batch outcome: per-job rows + aggregate cache/wall counters."""

    jobs: list[JobReport]
    wall_s: float
    num_workers: int

    @property
    def ok(self) -> bool:
        return all(j.ok for j in self.jobs)

    @property
    def cache_counters(self) -> dict:
        return {
            "memory_hits": sum(j.cache_hit for j in self.jobs),
            "disk_hits": sum(j.disk_cache_hit for j in self.jobs),
            "solved": sum(j.solved for j in self.jobs),
            "failed": sum(not j.ok for j in self.jobs),
        }

    def as_dict(self) -> dict:
        return {
            "wall_s": round(self.wall_s, 4),
            "num_workers": self.num_workers,
            "ok": self.ok,
            "cache": self.cache_counters,
            "jobs": [j.as_dict() for j in self.jobs],
        }


def _job_report(job: CompileJob, res: MapResult, wall_s: float) -> JobReport:
    return JobReport(
        name=job.name,
        ok=res.ok,
        ii=res.mapping.ii if res.ok else None,
        m_ii=res.stats.m_ii,
        wall_s=wall_s,
        cache_hit=res.stats.cache_hit,
        disk_cache_hit=res.stats.disk_cache_hit,
        backend=res.stats.backend,
        space_backend=res.stats.space_backend,
        reason=res.reason,
        time_phase_s=res.stats.time_phase_s,
        space_phase_s=res.stats.space_phase_s,
        validate_s=res.stats.validate_s,
        mono_failures=res.stats.mono_failures,
        res_ii=res.stats.res_ii,
        rec_ii=res.stats.rec_ii,
        rounds=res.stats.rounds,
        windows_opened=res.stats.windows_opened,
        time_solutions_tried=res.stats.time_solutions_tried,
        space_nodes_visited=res.stats.space_nodes_visited,
        time_steps=res.stats.time_steps,
        space_restarts=res.stats.space_restarts,
        mem_cache_lookups=res.stats.mem_cache_lookups,
        mem_cache_hits=res.stats.mem_cache_hits,
        disk_cache_lookups=res.stats.disk_cache_lookups,
        disk_cache_hits=res.stats.disk_cache_hits,
        disk_cache_promotions=res.stats.disk_cache_promotions,
        t_abs=list(res.mapping.t_abs) if res.ok else None,
        placement=list(res.mapping.placement) if res.ok else None,
        routes=[list(r) for r in res.mapping.routes_spec()] if res.ok else None,
    )


def _cancelled_report(job: CompileJob, reason: str) -> JobReport:
    return JobReport(
        name=job.name, ok=False, ii=None, m_ii=-1, wall_s=0.0,
        reason=reason, cancelled=True,
    )


def _run_job(job: CompileJob, defaults: dict, stop=None,
             trace_dir: str | None = None) -> JobReport:
    """Run one job and build its report; shared by the inline and pool paths.

    ``stop`` is a zero-arg cancellation predicate (or None). In pool workers
    it is derived from the inherited stop event (:func:`_run_job_pooled`); in
    the inline path it is the caller's ``cancel.is_set``.

    ``trace_dir``: when set and no tracer is already active in this process
    (the pool path), the job runs under a local tracer whose events are
    appended to a per-pid shard file and merged caller-side (DESIGN.md
    §15.2). With a tracer already active (the inline path) spans record into
    it directly and no shard is written.
    """
    active = obs.get_tracer()
    if trace_dir is not None and (active is None
                                  or active.pid != os.getpid()):
        # pool worker — note a forked child *inherits* the parent's tracer
        # object, but events recorded on that copy die with the process, so
        # detect it by pid and trace into a fresh local tracer persisted as
        # a per-pid shard instead
        tracer = obs.Tracer(process_name=f"repro-worker-{os.getpid()}")
        with obs.tracing(tracer):
            rep = _run_job(job, defaults, stop=stop)
        obs.append_shard(trace_dir, tracer.events, tracer.counters)
        return rep
    opts = {**defaults, **_as_mapper_kwargs(job.options)}
    if stop is not None:
        if stop():
            return _cancelled_report(job, "cancelled before start")
        opts.setdefault("should_stop", stop)
    t0 = _time.perf_counter()
    try:
        with obs.span("job", kernel=job.name) as sp:
            res = map_dfg(job.dfg, job.cgra, **opts)
            sp.set(ok=res.ok, ii=res.mapping.ii if res.ok else None)
    except Exception as exc:
        # any per-job failure (bad DFG, incompatible options, cache I/O)
        # fails its own row, never the batch
        return JobReport(name=job.name, ok=False, ii=None, m_ii=-1,
                         wall_s=_time.perf_counter() - t0,
                         reason=f"{type(exc).__name__}: {exc}")
    rep = _job_report(job, res, _time.perf_counter() - t0)
    if not res.ok and stop is not None and stop():
        rep.cancelled = True
        rep.reason = rep.reason or "cancelled"
    return rep


def _run_job_pooled(job: CompileJob, defaults: dict,
                    trace_dir: str | None = None) -> JobReport:
    """Top-level (picklable) pool entry: binds the inherited stop event."""
    return _run_job(job, defaults, stop=_should_stop(), trace_dir=trace_dir)


def compile_many(
    batch: Sequence[CompileJob],
    *,
    jobs: int | None = None,
    deadline_s: float | None = None,
    deterministic: bool = False,
    cache_dir: str | None = None,
    use_cache: bool = True,
    cancel=None,
    map_options: dict | None = None,
    trace_dir: str | None = None,
) -> CompileReport:
    """Compile a batch of DFGs concurrently across a process pool.

    Example — compile the Table III suite on a 5×5 CGRA with 4 workers and a
    warm persistent cache::

        from repro.core import CGRA
        from repro.core.benchsuite import load_suite
        from repro.core.service import CompileJob, compile_many

        cgra = CGRA(5, 5)
        batch = [CompileJob(d, cgra) for d in load_suite().values()]
        report = compile_many(batch, jobs=4, cache_dir="/tmp/maps")
        assert report.ok
        # second run: every job is a disk/memory hit, no solving
        again = compile_many(batch, jobs=4, cache_dir="/tmp/maps")
        assert again.cache_counters["solved"] == 0

    Parameters:

    * ``jobs`` — worker processes (default ``os.cpu_count()``). ``jobs<=1``
      runs inline in this process: no pool, bit-identical to a hand loop —
      the mode CI's deterministic smoke exercises.
    * ``deadline_s`` — per-job wall budget, enforced *inside* the worker as
      the mapper's ``time_budget_s`` (a job that exceeds it returns its best
      mapping so far or a budget-exhausted failure; the pool is never killed).
      Ignored when ``deterministic`` (step budgets replace wall clocks).
    * ``deterministic`` — forward ``deterministic=True`` to every job: each
      job's result is then load- and schedule-independent, so the batch
      report is reproducible regardless of pool interleaving.
    * ``cache_dir`` — persistent mapping cache directory shared by all
      workers (DESIGN.md §9); defaults to ``$REPRO_CACHE_DIR`` when set.
    * ``cancel`` — optional ``threading.Event``-like object; once set, queued
      jobs are dropped and running jobs finish early at their next budget
      check, reported with ``cancelled=True``.
    * ``map_options`` — extra ``map_dfg`` kwargs applied to every job
      (overridden by each job's own ``options``): a dict, or a typed
      :class:`repro.api.CompileOptions` whose mapper fields are forwarded.
    * ``trace_dir`` — span-shard directory for structured tracing (DESIGN.md
      §15.2): each pool worker appends its spans to ``shard-<pid>.jsonl``
      there; the caller merges the shards with :func:`repro.obs.merge_shards`
      for a single cross-process timeline.
    """
    t0 = _time.perf_counter()
    defaults: dict = _as_mapper_kwargs(map_options)
    defaults.setdefault("use_cache", use_cache)
    defaults.setdefault("cache_dir", cache_dir)
    if deterministic:
        defaults.setdefault("deterministic", True)
    elif deadline_s is not None:
        defaults.setdefault("time_budget_s", deadline_s)

    num_workers = jobs if jobs is not None else (os.cpu_count() or 1)
    if num_workers <= 1 or len(batch) <= 1:
        stop = cancel.is_set if cancel is not None else None
        reports = [_run_job(job, defaults, stop=stop, trace_dir=trace_dir)
                   for job in batch]
        return CompileReport(reports, _time.perf_counter() - t0, 1)

    import multiprocessing as mp

    ctx = mp.get_context()
    stop_event = ctx.Event()
    reports_by_idx: dict[int, JobReport] = {}
    # Worker-loss recovery (DESIGN.md §8.1): an abruptly dead worker (OOM
    # kill, segfault in a C extension, os._exit) breaks the WHOLE executor —
    # every pending future raises BrokenProcessPool, including jobs that
    # never ran. Treat those jobs as *unfinished* rather than failed, respawn
    # the pool once and rerun them; a second break (the culprit job rides
    # along on the retry) fails whatever is still unfinished with a
    # machine-readable ``worker lost`` reason (failure code "worker-lost")
    # instead of wedging or over-failing the batch.
    remaining = set(range(len(batch)))
    respawns_left = 1
    while remaining:
        broken = False
        with ProcessPoolExecutor(
            max_workers=min(num_workers, len(remaining)),
            mp_context=ctx,
            initializer=_pool_init,
            initargs=(stop_event,),
        ) as pool:
            futures = {
                pool.submit(_run_job_pooled, batch[i], defaults, trace_dir): i
                for i in sorted(remaining)
            }
            pending = set(futures)
            # poll only when there is a cancel event to observe; block otherwise
            poll_s = 0.1 if cancel is not None else None
            while pending:
                done, pending = wait(pending, timeout=poll_s,
                                     return_when=FIRST_COMPLETED)
                for fut in done:
                    i = futures[fut]
                    if fut.cancelled():
                        reports_by_idx[i] = _cancelled_report(
                            batch[i], "cancelled before start")
                        remaining.discard(i)
                        continue
                    try:
                        reports_by_idx[i] = fut.result()
                        remaining.discard(i)
                    except BrokenProcessPool:
                        # unfinished, not failed: candidates for the respawn
                        broken = True
                    except Exception as exc:
                        # per-job failure crossing the boundary (pickling
                        # error, ...) fails this row, not the batch
                        reports_by_idx[i] = JobReport(
                            name=batch[i].name, ok=False, ii=None, m_ii=-1,
                            wall_s=0.0, reason=f"{type(exc).__name__}: {exc}")
                        remaining.discard(i)
                if broken:
                    break
                if (cancel is not None and cancel.is_set()
                        and not stop_event.is_set()):
                    stop_event.set()
                    for fut in list(pending):
                        if fut.cancel():
                            i = futures[fut]
                            reports_by_idx[i] = _cancelled_report(
                                batch[i], "cancelled before start")
                            remaining.discard(i)
                            pending.discard(fut)
        if broken:
            if respawns_left > 0:
                respawns_left -= 1
                continue
            for i in sorted(remaining):
                reports_by_idx[i] = JobReport(
                    name=batch[i].name, ok=False, ii=None, m_ii=-1,
                    wall_s=0.0,
                    reason="worker lost: process pool broken twice "
                           "(worker died mid-solve; pool respawned once)")
            remaining.clear()
    reports = [reports_by_idx[i] for i in range(len(batch))]
    return CompileReport(reports, _time.perf_counter() - t0,
                         min(num_workers, len(batch)))


# ----------------------------------------------------------- window racing

def _race_worker(dfg: DFG, cgra: CGRA, offset: int, stride: int,
                 options: dict) -> MapResult:
    opts = dict(options)
    stop = _should_stop()
    if stop is not None:
        opts.setdefault("should_stop", stop)
    res = map_dfg(dfg, cgra, window_offset=offset, window_stride=stride, **opts)
    if res.ok and _STOP_EVENT is not None:
        _STOP_EVENT.set()       # first winner: laggards wrap up at next check
    return res


def map_dfg_racing(
    dfg: DFG,
    cgra: CGRA,
    *,
    workers: int = 2,
    **options,
) -> MapResult:
    """Race one mapping problem's (II, slack) windows across processes.

    Worker ``i`` of ``w`` runs the ordinary portfolio search restricted to
    every ``w``-th window (``window_offset=i, window_stride=w``) of the
    canonical smallest-II-first order, so the workers partition the search
    space instead of duplicating it. The first worker that returns a mapping
    sets the shared stop event (*first-winner cancellation*); the others
    observe it at their next budget check and return early. The best
    (lowest-II) result wins — with ties broken toward the lowest offset so
    the choice is reproducible.

    ``workers`` is clamped to the window count (no worker gets an empty
    stripe); ``workers<=1`` after clamping, or ``deterministic=True`` (whose
    contract a wall-clock race cannot honor), falls back to plain
    :func:`~repro.core.mapper.map_dfg`. Remaining keyword ``options`` are
    forwarded to ``map_dfg`` unchanged.

    When the space backend is left on ``auto`` and the fabric is large
    enough that auto resolves to ``anneal`` (DESIGN.md §13.3), the race
    additionally stripes *engines*: even-offset workers run the anneal
    favourite, odd-offset workers the exact engine. Whichever placement
    style fits the problem wins the race; small fabrics are unaffected.
    """
    from ..mapper import DEFAULT_MAX_SLACK, default_max_ii, ii_slack_windows
    from ..schedule import min_ii

    lo = min_ii(dfg, cgra)
    hi = options.get("max_ii") or default_max_ii(lo)
    n_windows = sum(
        1 for _ in ii_slack_windows(
            lo, hi, options.get("max_slack", DEFAULT_MAX_SLACK))
    )
    workers = min(workers, max(1, n_windows))
    if workers <= 1 or options.get("deterministic"):
        return map_dfg(dfg, cgra, **options)

    import multiprocessing as mp

    stripes = [options] * workers
    if options.get("space_backend", "auto") == "auto":
        from ..space_backends import resolve_space_backend_name

        if resolve_space_backend_name("auto", cgra) == "anneal":
            stripes = [
                {**options,
                 "space_backend": "anneal" if i % 2 == 0 else "exact"}
                for i in range(workers)
            ]

    t0 = _time.perf_counter()
    ctx = mp.get_context()
    stop_event = ctx.Event()
    with ProcessPoolExecutor(
        max_workers=workers,
        mp_context=ctx,
        initializer=_pool_init,
        initargs=(stop_event,),
    ) as pool:
        futs = [
            pool.submit(_race_worker, dfg, cgra, i, workers, stripes[i])
            for i in range(workers)
        ]
        results = [f.result() for f in futs]
    winners = [(r.mapping.ii, i) for i, r in enumerate(results) if r.ok]
    wall = _time.perf_counter() - t0
    if not winners:
        # deterministic pick among failures: the offset-0 stripe holds the
        # lowest-II windows, so its reason is the most informative
        res = results[0]
        res.stats.total_s = wall
        return res
    _, best_i = min(winners)
    res = results[best_i]
    res.stats.total_s = wall
    return res

"""Persistent on-disk mapping cache (DESIGN.md §9).

Finished mappings are content-addressed: the key digest covers the cache
format version, the DFG's :meth:`~repro.core.dfg.DFG.stable_hash`, the CGRA
dimensions and topology, the connectivity mode, the register-pressure limit,
and the II. Two processes compiling the same kernel therefore share work
through the filesystem — the second one reads a JSON entry instead of
re-solving — which is what makes repeated serve/bench runs cheap.

Design points (rationale in DESIGN.md §9):

* **One file per (key, II) entry.** Entries are immutable once written, so
  concurrent writers need no locking — the atomic ``os.replace`` of a
  same-content file is idempotent.
* **Versioned.** ``CACHE_VERSION`` participates in the digest, so a format
  bump orphans old entries rather than misreading them; ``prune()`` garbage-
  collects entries whose payload disagrees with the current version.
* **Corruption-tolerant.** A truncated/garbled/stale file is treated as a
  miss: the payload is parsed defensively, re-validated against the digest
  fields, and the mapping itself is re-checked by the caller before reuse.
  Bad files are deleted best-effort.

The in-memory LRU in ``core/mapper.py`` layers *over* this cache: memory is
checked first, disk second, and a disk hit is promoted into memory.
"""

from __future__ import annotations

import hashlib
import json
import os
import time as _time
from dataclasses import dataclass, field

# Bump whenever the entry payload schema or the key schema changes: old
# entries then simply stop matching (their digests embed the old version).
# v2: base key grew an arch token (heterogeneous architecture digest,
# DESIGN.md §10) — None on the paper's homogeneous grids.
# v3: base key grew the effective per-PE register-pressure token and the
# route-through hop allowance, and the payload grew a ``routes`` list
# (DESIGN.md §12) — pre-fix entries keyed on the scalar pressure limit alone
# could oversubscribe per-class register files and must never be served.
# v4: base key grew the resolved space-backend name (DESIGN.md §13.4) —
# exact and anneal placements are both valid but must never be served
# across engines, or backend provenance and benchmarks would lie.
# v5: the exact-check post-pass (DESIGN.md §14.4) now writes joint-backend
# mappings under the portfolio's own key when they strictly beat the
# portfolio II. The payload schema is unchanged, but pre-v5 entries may
# hold a provably suboptimal II for keys the adoption path would now
# overwrite; orphaning them lets certified results win deterministically.
CACHE_VERSION = 5

_ENTRY_SUFFIX = ".json"


@dataclass
class CacheStats:
    """Hit/miss counters surfaced in service reports and BENCH_* JSON."""

    hits: int = 0
    misses: int = 0
    writes: int = 0
    corrupt_dropped: int = 0
    evictions: int = 0

    def as_dict(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "writes": self.writes,
            "corrupt_dropped": self.corrupt_dropped,
            "evictions": self.evictions,
        }


@dataclass
class DiskMappingCache:
    """Content-addressed store of finished mappings under ``root``.

    Example — share mappings between two processes::

        cache = DiskMappingCache("/tmp/maps")
        key = cache.entry_key(dfg.stable_hash(), 4, 4, "mesh", "strict", None)
        cache.put(key, ii=3, t_abs=sol.t_abs, placement=space.placement)
        # ... later, any process:
        hit = cache.get(key, lo_ii=3, hi_ii=8)   # -> (3, t_abs, placement)

    ``map_dfg(..., cache_dir=...)`` wires this in automatically; the class is
    public so services can pre-warm or inspect the store directly.
    """

    root: str
    stats: CacheStats = field(default_factory=CacheStats)

    # ------------------------------------------------------------------ keys
    @staticmethod
    def entry_key(
        dfg_hash: str,
        rows: int,
        cols: int,
        topology: str,
        connectivity: str,
        max_register_pressure: int | None,
        arch_token: str | None = None,
        pressure_token=None,
        max_route_hops: int = 0,
        space_backend: str = "exact",
    ) -> tuple:
        """Canonical base key; mirrors the in-memory LRU's ``_cache_base_key``.

        ``arch_token`` is ``CGRA.arch_token()``: None for the homogeneous
        paper machine, a digest of the capability layout otherwise.
        ``pressure_token`` is ``CGRA.pressure_token(max_register_pressure)``
        — the *effective per-PE* register-bound vector the mapper guarantees
        (None when the guarantee is off); ``max_route_hops`` keys the
        route-through allowance the mapping was searched under;
        ``space_backend`` is the *resolved* placement engine name ("auto"
        never reaches a key — DESIGN.md §13.4).
        """
        return (dfg_hash, rows, cols, topology, connectivity,
                max_register_pressure, arch_token, pressure_token,
                max_route_hops, space_backend)

    def _digest(self, base_key: tuple, ii: int) -> str:
        payload = json.dumps(
            {"v": CACHE_VERSION, "key": list(base_key), "ii": ii},
            sort_keys=True,
            separators=(",", ":"),
        )
        return hashlib.sha1(payload.encode()).hexdigest()

    def _path(self, base_key: tuple, ii: int) -> str:
        d = self._digest(base_key, ii)
        return os.path.join(self.root, d[:2], d + _ENTRY_SUFFIX)

    # ------------------------------------------------------------------- get
    def get(
        self, base_key: tuple, lo_ii: int, hi_ii: int
    ) -> tuple[int, list[int], list[int], list[tuple]] | None:
        """Best (lowest-II) entry for ``base_key`` with II in [lo_ii, hi_ii].

        Returns ``(ii, t_abs, placement, routes)`` or None — ``routes`` is
        the ``(src, dst, distance, n_movs)`` route-through spec list (empty
        for direct mappings; ``dfg.splice_routes`` rebuilds the rewritten
        DFG). Scans IIs ascending so a hit is always the best cached answer,
        matching the portfolio mapper's smallest-II-first preference.
        """
        for ii in range(lo_ii, hi_ii + 1):
            entry = self._read(base_key, ii)
            if entry is not None:
                self.stats.hits += 1
                return ii, entry[0], entry[1], entry[2]
        self.stats.misses += 1
        return None

    def _read(
        self, base_key: tuple, ii: int
    ) -> tuple[list[int], list[int], list[tuple]] | None:
        path = self._path(base_key, ii)
        try:
            with open(path, "r", encoding="utf-8") as f:
                payload = json.load(f)
        except FileNotFoundError:
            return None
        except (OSError, ValueError, UnicodeDecodeError):
            self._drop(path)
            return None
        # Defensive schema check: the digest embeds the key, but a partially
        # written or hand-edited file can still hold anything.
        try:
            if payload["version"] != CACHE_VERSION:
                raise ValueError("version mismatch")
            if payload["ii"] != ii or list(payload["key"]) != list(base_key):
                raise ValueError("key mismatch")
            t_abs = [int(t) for t in payload["t_abs"]]
            placement = [int(p) for p in payload["placement"]]
            if len(t_abs) != len(placement) or not t_abs:
                raise ValueError("length mismatch")
            routes = [
                (int(s), int(d), int(dist), int(n))
                for s, d, dist, n in payload.get("routes", [])
            ]
            if sum(n for *_rest, n in routes) >= len(t_abs):
                raise ValueError("routes longer than the mapping")
        except (KeyError, TypeError, ValueError):
            self._drop(path)
            return None
        return t_abs, placement, routes

    def _drop(self, path: str) -> None:
        self.stats.corrupt_dropped += 1
        try:
            os.unlink(path)
        except OSError:
            pass

    def invalidate(self, base_key: tuple, ii: int) -> None:
        """Drop one entry (e.g. it parsed fine but failed Mapping.validate).

        Without this, a schema-valid but semantically invalid entry would be
        re-read and re-rejected on every cold lookup, permanently defeating
        the cache for its key.
        """
        self._drop(self._path(base_key, ii))

    # ------------------------------------------------------------------- put
    def put(
        self, base_key: tuple, ii: int, t_abs: list[int], placement: list[int],
        *, routes=(),
    ) -> None:
        """Atomically persist one mapping (idempotent across processes).

        ``routes`` is the route-through spec (``Mapping.routes_spec()``);
        omit/empty for direct mappings.
        """
        path = self._path(base_key, ii)
        payload = {
            "version": CACHE_VERSION,
            "key": list(base_key),
            "ii": ii,
            "t_abs": list(t_abs),
            "placement": list(placement),
            "routes": [list(r) for r in routes],
        }
        tmp = f"{path}.tmp.{os.getpid()}"
        try:
            os.makedirs(os.path.dirname(path), exist_ok=True)
            with open(tmp, "w", encoding="utf-8") as f:
                json.dump(payload, f, separators=(",", ":"))
            os.replace(tmp, path)
            self.stats.writes += 1
        except OSError:
            # cache writes are best-effort: a full/read-only disk must never
            # fail a compilation
            try:
                os.unlink(tmp)
            except OSError:
                pass

    # ------------------------------------------------------------- maintenance
    def prune(
        self, max_bytes: int | None = None, max_age_s: float | None = None,
    ) -> int:
        """Delete stale files and (optionally) bound the store's size/age.

        Always removes version-mismatched entries and orphaned ``*.tmp.<pid>``
        files (a writer killed between open and replace) — version-bumped
        entries are unreachable anyway (the digest changed), so this just
        reclaims the disk. An in-flight concurrent write losing its temp
        merely skips that best-effort write.

        With ``max_age_s``, entries whose mtime is older than that many
        seconds are evicted. With ``max_bytes``, surviving entries are
        evicted LRU-by-mtime (oldest first) until the store fits the budget
        — ``os.replace`` on a read path never touches mtime, so mtime order
        is write/refresh order, the same approximation a long-running daemon
        wants for "least recently produced". Evictions are counted in
        ``stats.evictions`` (mirroring the in-memory LRU's counter); stale/
        corrupt removals stay out of that counter. Returns the total number
        of files removed. All removals are best-effort: a concurrently
        deleted file is not an error.
        """
        removed = 0
        now = _time.time()
        survivors: list[tuple[float, int, str]] = []  # (mtime, size, path)
        for dirpath, _dirnames, filenames in os.walk(self.root):
            for fn in filenames:
                path = os.path.join(dirpath, fn)
                if f"{_ENTRY_SUFFIX}.tmp." in fn:
                    try:
                        os.unlink(path)
                        removed += 1
                    except OSError:
                        pass
                    continue
                if not fn.endswith(_ENTRY_SUFFIX):
                    continue
                try:
                    with open(path, "r", encoding="utf-8") as f:
                        payload = json.load(f)
                    ok = payload.get("version") == CACHE_VERSION
                except (OSError, ValueError, UnicodeDecodeError):
                    ok = False
                if not ok:
                    try:
                        os.unlink(path)
                        removed += 1
                    except OSError:
                        pass
                    continue
                try:
                    st = os.stat(path)
                except OSError:
                    continue
                if max_age_s is not None and now - st.st_mtime > max_age_s:
                    if self._evict(path):
                        removed += 1
                    continue
                survivors.append((st.st_mtime, st.st_size, path))
        if max_bytes is not None:
            total = sum(size for _mt, size, _p in survivors)
            for _mtime, size, path in sorted(survivors):
                if total <= max_bytes:
                    break
                if self._evict(path):
                    removed += 1
                    total -= size
        return removed

    def _evict(self, path: str) -> bool:
        try:
            os.unlink(path)
        except OSError:
            return False
        self.stats.evictions += 1
        return True

    def __len__(self) -> int:
        count = 0
        for _dirpath, _dirnames, filenames in os.walk(self.root):
            count += sum(1 for fn in filenames if fn.endswith(_ENTRY_SUFFIX))
        return count


def resolve_cache_dir(cache_dir: str | None) -> str | None:
    """Resolve the effective cache directory.

    Precedence: explicit argument > ``REPRO_CACHE_DIR`` env var > disabled.
    An empty string in either position disables the disk cache (lets CI force
    cold runs without unsetting the variable).
    """
    if cache_dir is not None:
        return cache_dir or None
    env = os.environ.get("REPRO_CACHE_DIR")
    return env or None

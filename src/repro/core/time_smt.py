"""Time-dimension SMT solver (paper §IV-B).

Finds a modulo schedule (an absolute time ``t_v`` per DFG node, equivalently a
kernel label ``l(v) = t_v mod II`` plus fold ``it_v = t_v div II``) satisfying
three constraint families:

1. *Modulo-scheduling constraints* — dependency ordering across foldings. We
   encode the standard absolute-time form ``t_dst >= t_src + 1 - II*distance``,
   which is exactly the paper's KMS case split (``t_d > t_s`` when
   ``it_s == it_d``; ``t_d <= t_s`` when ``it_s - it_d == 1``) expressed without
   the case analysis.
2. *Capacity constraints* (paper's addition) — per kernel step i, the number of
   nodes labelled i must not exceed the PE count.
3. *Connectivity constraints* (paper's addition) — for every node v and step i,
   the number of DFG-neighbours of v labelled i must not exceed the CGRA
   connectivity degree D_M (closed neighbourhood size).

``connectivity="paper"`` reproduces the constraint exactly as published.
``connectivity="strict"`` additionally requires, for neighbours scheduled at
*v's own* step, a bound of D_M - 1: same-step injectivity means v's own PE is
not available to its same-step neighbours. The published proof overlooks this
(see DESIGN.md §7 and tests/test_theorem.py, which exhibits the gap); "strict"
closes the common case, and the mapper additionally retries with blocking
clauses whenever a time solution admits no monomorphism, which makes the
overall pipeline complete regardless of mode.

Backends: Z3 (faithful to the paper, default when available) and a pure-Python
backtracking CP solver (dependency-free cross-check).
"""

from __future__ import annotations

import itertools
import time as _time
from dataclasses import dataclass, field

from .cgra import CGRA
from .dfg import DFG
from .schedule import MobilitySchedule, asap_schedule, modulo_windows

try:  # pragma: no cover - availability probed at import
    import z3  # type: ignore

    HAVE_Z3 = True
except Exception:  # pragma: no cover
    z3 = None
    HAVE_Z3 = False


@dataclass
class TimeSolution:
    """A valid time solution: absolute times + derived kernel labels."""

    ii: int
    t_abs: list[int]

    @property
    def labels(self) -> list[int]:
        return [t % self.ii for t in self.t_abs]

    @property
    def folds(self) -> list[int]:
        return [t // self.ii for t in self.t_abs]


@dataclass
class TimeSolverStats:
    solver_time_s: float = 0.0
    num_solutions_enumerated: int = 0
    backend: str = ""
    blocked: int = 0


class TimeSolver:
    """Enumerates time solutions for (dfg, cgra, II) lazily.

    ``next_solution()`` returns a fresh TimeSolution each call (blocking the
    previous one), or None when the space is exhausted — the mapper uses this
    to recover from (rare) monomorphism failures.
    """

    def __init__(
        self,
        dfg: DFG,
        cgra: CGRA,
        ii: int,
        *,
        extra_slack: int = 0,
        connectivity: str = "strict",
        backend: str = "auto",
        timeout_s: float | None = None,
        seed: int = 0,
    ) -> None:
        if connectivity not in ("paper", "strict"):
            raise ValueError(connectivity)
        self.dfg = dfg
        self.cgra = cgra
        self.ii = ii
        self.seed = seed
        self.connectivity = connectivity
        self.timeout_s = timeout_s
        self.stats = TimeSolverStats()
        horizon = max(asap_schedule(dfg), default=0) + extra_slack
        windows = modulo_windows(dfg, ii, horizon)
        if windows is None:
            # infeasible window: expose an exhausted solver
            raise ValueError(f"II={ii} infeasible within horizon {horizon}")
        self.asap, self.alap = windows
        # Analytic connectivity prechecks (save Z3 from exponential PB-UNSAT
        # proofs on high-fanout DFGs):
        #  (a) degree bound: deg(v) <= D_M*II - 1 (closed nbhd x steps - own slot)
        #  (b) window-aware: neighbours can only occupy kernel steps their
        #      [asap, alap] windows reach; per-step supply is capped at D_M
        #      (D_M - 1 at v's own step when v's window is a singleton).
        d_m = cgra.connectivity_degree
        for v, nbrs in enumerate(dfg.undirected_adjacency()):
            if not nbrs:
                continue
            if len(nbrs) > d_m * ii - 1:
                raise ValueError(
                    f"II={ii} infeasible: node {v} degree {len(nbrs)} > {d_m}*II-1"
                )
            cand = [0] * ii
            for u in nbrs:
                span = range(self.asap[u], min(self.alap[u], self.asap[u] + ii - 1) + 1)
                for k in {t % ii for t in span}:
                    cand[k] += 1
            v_span = {t % ii for t in range(self.asap[v], min(self.alap[v], self.asap[v] + ii - 1) + 1)}
            supply = sum(
                min(cand[k], d_m - (1 if (len(v_span) == 1 and k in v_span) else 0))
                for k in range(ii)
            )
            if supply < len(nbrs):
                raise ValueError(
                    f"II={ii} infeasible: node {v} neighbour supply {supply} < "
                    f"{len(nbrs)}"
                )
        self.mobs = MobilitySchedule(tuple(self.asap), tuple(self.alap))
        self.adj = dfg.undirected_adjacency()
        if backend == "auto":
            backend = "z3" if HAVE_Z3 else "python"
        if backend == "z3" and not HAVE_Z3:
            raise RuntimeError("z3 backend requested but z3 is not importable")
        self.backend = backend
        self.stats.backend = backend
        if backend == "z3":
            self._init_z3()
        else:
            self._py_iter = self._python_solutions()

    # ------------------------------------------------------------------- z3
    def _init_z3(self) -> None:
        n = self.dfg.num_nodes
        ii = self.ii
        self._solver = z3.Solver()
        if self.timeout_s is not None:
            self._solver.set("timeout", int(self.timeout_s * 1000))
        self._solver.set("random_seed", self.seed & 0xFFFF)
        self._t = [z3.Int(f"t_{v}") for v in range(n)]
        self._k = [z3.Int(f"k_{v}") for v in range(n)]
        self._f = [z3.Int(f"f_{v}") for v in range(n)]
        s = self._solver
        max_fold = max(self.alap) // ii + 1 if n else 1
        for v in range(n):
            s.add(self._t[v] >= self.asap[v], self._t[v] <= self.alap[v])
            # t = II*fold + k, 0 <= k < II  (linear decomposition; Z3 handles
            # this far better than the `mod` operator on small grids)
            s.add(self._t[v] == ii * self._f[v] + self._k[v])
            s.add(self._k[v] >= 0, self._k[v] < ii)
            s.add(self._f[v] >= 0, self._f[v] <= max_fold)
        # 1. modulo-scheduling constraints
        for e in self.dfg.edges:
            s.add(self._t[e.dst] >= self._t[e.src] + 1 - ii * e.distance)
        # 2. capacity constraints
        cap = self.cgra.num_pes
        for i in range(ii):
            s.add(
                z3.PbLe([(self._k[v] == i, 1) for v in range(n)], cap)
            )
        # 3. connectivity constraints
        d_m = self.cgra.connectivity_degree
        for v in range(n):
            nbrs = sorted(self.adj[v])
            if not nbrs:
                continue
            for i in range(ii):
                s.add(
                    z3.PbLe([(self._k[u] == i, 1) for u in nbrs], d_m)
                )
            if self.connectivity == "strict":
                # same-step neighbours can only use the open neighbourhood
                s.add(
                    z3.PbLe(
                        [(self._k[u] == self._k[v], 1) for u in nbrs], d_m - 1
                    )
                )
        if self.connectivity == "strict":
            # Mesh/torus PE graphs are bipartite => triangle-free, so three
            # mutually-adjacent DFG nodes can never share a kernel step (they
            # would need a triangle of distinct, mutually-adjacent PEs). The
            # published constraints admit such partitions; excluding them here
            # saves futile monomorphism searches (DESIGN.md §7).
            for u, v, w in _triangles(self.adj):
                s.add(z3.Or(self._k[u] != self._k[v], self._k[u] != self._k[w]))

    def next_solution(self) -> TimeSolution | None:
        start = _time.perf_counter()
        try:
            if self.backend == "z3":
                res = self._solver.check()
                if res != z3.sat:
                    return None
                model = self._solver.model()
                t_abs = [model.eval(self._t[v]).as_long() for v in range(self.dfg.num_nodes)]
                # Block the *label partition*, not just this t_abs: the space
                # search depends only on labels, so any schedule with the same
                # labels would fail the same way. This makes the mapper's
                # retry-on-mono-failure loop converge quickly.
                self._solver.add(
                    z3.Or([self._k[v] != t_abs[v] % self.ii for v in range(self.dfg.num_nodes)])
                )
                if self.stats.blocked == 0:
                    # Retry solves want *structurally* diverse label partitions
                    # (the first solve wants fast default heuristics) — flip to
                    # randomised phase selection once retries begin.
                    try:
                        self._solver.set("phase_selection", 5)
                    except z3.Z3Exception:  # pragma: no cover
                        pass
                self.stats.blocked += 1
                self.stats.num_solutions_enumerated += 1
                return TimeSolution(self.ii, t_abs)
            try:
                t_abs = next(self._py_iter)
            except StopIteration:
                return None
            self.stats.num_solutions_enumerated += 1
            return TimeSolution(self.ii, list(t_abs))
        finally:
            self.stats.solver_time_s += _time.perf_counter() - start

    # -------------------------------------------------------------- fallback
    def _python_solutions(self):
        """Backtracking CP enumeration (most-constrained-first ordering)."""
        n = self.dfg.num_nodes
        ii = self.ii
        cap = self.cgra.num_pes
        d_m = self.cgra.connectivity_degree
        order = sorted(range(n), key=lambda v: (self.alap[v] - self.asap[v], -len(self.adj[v])))
        t_abs = [-1] * n
        count_per_step = [0] * ii
        deadline = (
            _time.perf_counter() + self.timeout_s if self.timeout_s else None
        )

        out_edges: list[list] = [[] for _ in range(n)]
        in_edges: list[list] = [[] for _ in range(n)]
        for e in self.dfg.edges:
            out_edges[e.src].append(e)
            in_edges[e.dst].append(e)
        strict = self.connectivity == "strict"

        def ok(v: int, t: int) -> bool:
            k = t % ii
            if count_per_step[k] + 1 > cap:
                return False
            for e in out_edges[v]:
                if t_abs[e.dst] >= 0 and t_abs[e.dst] < t + 1 - ii * e.distance:
                    return False
            for e in in_edges[v]:
                if t_abs[e.src] >= 0 and t < t_abs[e.src] + 1 - ii * e.distance:
                    return False
            # connectivity of v: placed neighbours of v, bucketed by step
            per_step: dict[int, int] = {}
            for u in self.adj[v]:
                if t_abs[u] >= 0:
                    su = t_abs[u] % ii
                    per_step[su] = per_step.get(su, 0) + 1
            if per_step.get(k, 0) > (d_m - 1 if strict else d_m):
                return False
            if any(c > d_m for c in per_step.values()):
                return False
            if strict:
                # no mono-chromatic triangle (bipartite PE graph)
                same = [u for u in self.adj[v] if t_abs[u] >= 0 and t_abs[u] % ii == k]
                for a_i in range(len(same)):
                    for b_i in range(a_i + 1, len(same)):
                        if same[b_i] in self.adj[same[a_i]]:
                            return False
            # connectivity of each placed neighbour u: v adds one to u's step-k count
            for u in self.adj[v]:
                if t_abs[u] < 0:
                    continue
                cu = 1  # v itself
                for w in self.adj[u]:
                    if w != v and t_abs[w] >= 0 and t_abs[w] % ii == k:
                        cu += 1
                limit = d_m - 1 if strict and t_abs[u] % ii == k else d_m
                if cu > limit:
                    return False
            return True

        def rec(idx: int):
            if deadline and _time.perf_counter() > deadline:
                return
            if idx == n:
                yield tuple(t_abs)
                return
            v = order[idx]
            for t in range(self.asap[v], self.alap[v] + 1):
                if ok(v, t):
                    t_abs[v] = t
                    count_per_step[t % ii] += 1
                    yield from rec(idx + 1)
                    count_per_step[t % ii] -= 1
                    t_abs[v] = -1

        yield from rec(0)


def _triangles(adj: list[set[int]]) -> list[tuple[int, int, int]]:
    """All triangles {u<v<w} of an undirected adjacency-set list."""
    out = []
    for u in range(len(adj)):
        for v in adj[u]:
            if v <= u:
                continue
            for w in adj[u] & adj[v]:
                if w > v:
                    out.append((u, v, w))
    return out


def check_time_solution(
    dfg: DFG, cgra: CGRA, sol: TimeSolution, *, connectivity: str = "paper"
) -> list[str]:
    """Independent validator; returns a list of violated-constraint messages."""
    errs: list[str] = []
    ii = sol.ii
    labels = sol.labels
    for e in dfg.edges:
        if not sol.t_abs[e.dst] >= sol.t_abs[e.src] + 1 - ii * e.distance:
            errs.append(f"dep {e} violated: t={sol.t_abs[e.src]},{sol.t_abs[e.dst]}")
    for i in range(ii):
        c = sum(1 for v in dfg.nodes if labels[v] == i)
        if c > cgra.num_pes:
            errs.append(f"capacity exceeded at step {i}: {c} > {cgra.num_pes}")
    d_m = cgra.connectivity_degree
    adj = dfg.undirected_adjacency()
    for v in dfg.nodes:
        for i in range(ii):
            cnt = sum(1 for u in adj[v] if labels[u] == i)
            limit = d_m
            if connectivity == "strict" and i == labels[v]:
                limit = d_m - 1
            if cnt > limit:
                errs.append(f"connectivity exceeded: node {v} step {i}: {cnt} > {limit}")
    return errs

"""Time-dimension solver facade (paper §IV-B).

Finds a modulo schedule (an absolute time ``t_v`` per DFG node, equivalently a
kernel label ``l(v) = t_v mod II`` plus fold ``it_v = t_v div II``) satisfying
three constraint families:

1. *Modulo-scheduling constraints* — dependency ordering across foldings. We
   encode the standard absolute-time form ``t_dst >= t_src + 1 - II*distance``,
   which is exactly the paper's KMS case split (``t_d > t_s`` when
   ``it_s == it_d``; ``t_d <= t_s`` when ``it_s - it_d == 1``) expressed without
   the case analysis.
2. *Capacity constraints* (paper's addition) — per kernel step i, the number of
   nodes labelled i must not exceed the PE count. On heterogeneous grids
   (core/arch, DESIGN.md §10) the scalar bound is joined by one cardinality
   constraint per capability class whose capacity is below the PE count: at
   most ``class_capacity(cls)`` nodes of class ``cls`` per step (memory ops
   additionally clamped by the grid's port count).
3. *Connectivity constraints* (paper's addition) — for every node v and step i,
   the number of DFG-neighbours of v labelled i must not exceed the CGRA
   connectivity degree D_M (closed neighbourhood size).

``connectivity="paper"`` reproduces the constraint exactly as published.
``connectivity="strict"`` additionally requires, for neighbours scheduled at
*v's own* step, a bound of D_M - 1: same-step injectivity means v's own PE is
not available to its same-step neighbours. The published proof overlooks this
(see DESIGN.md §7 and tests/test_theorem.py, which exhibits the gap); "strict"
closes the common case, and the mapper additionally retries with blocking
clauses whenever a time solution admits no monomorphism, which makes the
overall pipeline complete regardless of mode.

The actual solving is delegated to the backend subsystem
(core/time_backends/): "z3" is the paper-faithful SMT encoding, "cp" (alias
"python") the dependency-free incremental CP engine, "auto" picks z3 when
importable. ``TimeSolver.stats.backend`` always reports the concrete backend
that ran — never the alias that was asked for.
"""

from __future__ import annotations

import time as _time
from dataclasses import dataclass

from .. import obs
from .cgra import CGRA
from .dfg import DFG
from .schedule import MobilitySchedule, asap_schedule, modulo_windows
from .time_backends import (
    TimeProblem,
    available_backends,
    create_backend,
    resolve_backend_name,
)
from .time_backends.base import residue_window
from .time_backends.z3_backend import HAVE_Z3  # re-exported for callers/tests

__all__ = [
    "TimeSolution",
    "TimeSolver",
    "TimeSolverStats",
    "check_time_solution",
    "available_backends",
    "HAVE_Z3",
]


@dataclass
class TimeSolution:
    """A valid time solution: absolute times + derived kernel labels."""

    ii: int
    t_abs: list[int]

    @property
    def labels(self) -> list[int]:
        return [t % self.ii for t in self.t_abs]

    @property
    def folds(self) -> list[int]:
        return [t // self.ii for t in self.t_abs]


@dataclass
class TimeSolverStats:
    solver_time_s: float = 0.0
    num_solutions_enumerated: int = 0
    backend: str = ""
    blocked: int = 0
    steps: int = 0          # cumulative backend search steps / solver calls


class TimeSolver:
    """Lazily enumerates time solutions for one (dfg, cgra, II, slack) window.

    ``next_solution()`` returns a fresh :class:`TimeSolution` each call — each
    with a *label partition* (the multiset of kernel steps ``t mod II``) never
    proposed before — or None when either the per-call budget ran out
    (``solver.exhausted`` False: call again to resume) or the window is proven
    empty (``solver.exhausted`` True). The portfolio mapper uses this to
    recover from monomorphism failures: a partition that failed to embed is
    never re-proposed (DESIGN.md §4), and ``block(labels)`` excludes one
    externally (e.g. on a register-pressure reject).

    Example — enumerate two distinct partitions for the running example::

        from repro.core import CGRA, TimeSolver, running_example

        solver = TimeSolver(running_example(), CGRA(2, 2), ii=4, backend="cp")
        a = solver.next_solution()
        b = solver.next_solution()
        assert sorted(a.labels) != sorted(b.labels) or a.labels != b.labels
        assert max(a.folds) >= 1        # 14 nodes fold over 4 kernel steps

    Raises ``ValueError`` at construction when the window is infeasible by
    analytic precheck (modulo-window collapse, degree/supply bounds) — a free
    UNSAT proof the mapper consumes to mark the window dead (DESIGN.md §3).
    """

    def __init__(
        self,
        dfg: DFG,
        cgra: CGRA,
        ii: int,
        *,
        extra_slack: int = 0,
        connectivity: str = "strict",
        backend: str = "auto",
        timeout_s: float | None = None,
        seed: int = 0,
        route_hops: int = 0,
    ) -> None:
        """``route_hops > 0`` relaxes the connectivity constraint family to
        the route-through regime (DESIGN.md §12.3): with up to ``route_hops``
        mov insertions per edge, a neighbour only needs to sit within the
        closed ``1 + route_hops``-step reach of a PE, so D_M is replaced by
        ``cgra.reach_degree(1 + route_hops)`` in the prechecks and backend
        constraints, and the strict-mode triangle exclusion is dropped (three
        mutually adjacent nodes *can* share a step once edges may ride mov
        chains). ``route_hops=0`` is bit-identical to the historical solver.
        """
        if connectivity not in ("paper", "strict"):
            raise ValueError(connectivity)
        if route_hops < 0:
            raise ValueError(f"route_hops must be >= 0, got {route_hops}")
        self.dfg = dfg
        self.cgra = cgra
        self.ii = ii
        self.seed = seed
        self.connectivity = connectivity
        self.timeout_s = timeout_s
        self.stats = TimeSolverStats()
        horizon = max(asap_schedule(dfg), default=0) + extra_slack
        windows = modulo_windows(dfg, ii, horizon)
        if windows is None:
            # infeasible window: expose an exhausted solver
            raise ValueError(f"II={ii} infeasible within horizon {horizon}")
        self.asap, self.alap = windows
        # Analytic connectivity prechecks (save the backends from exponential
        # PB-UNSAT proofs on high-fanout DFGs):
        #  (a) degree bound: deg(v) <= D_M*II - 1 (closed nbhd x steps - own slot)
        #  (b) window-aware: neighbours can only occupy kernel steps their
        #      [asap, alap] windows reach; per-step supply is capped at D_M
        #      (D_M - 1 at v's own step when v's window is a singleton).
        d_m = (cgra.connectivity_degree if route_hops == 0
               else cgra.reach_degree(1 + route_hops))
        for v, nbrs in enumerate(dfg.undirected_adjacency()):
            if not nbrs:
                continue
            if len(nbrs) > d_m * ii - 1:
                raise ValueError(
                    f"II={ii} infeasible: node {v} degree {len(nbrs)} > {d_m}*II-1"
                )
            cand = [0] * ii
            for u in nbrs:
                span = range(self.asap[u], min(self.alap[u], self.asap[u] + ii - 1) + 1)
                for k in {t % ii for t in span}:
                    cand[k] += 1
            v_span = {t % ii for t in range(self.asap[v], min(self.alap[v], self.asap[v] + ii - 1) + 1)}
            supply = sum(
                min(cand[k], d_m - (1 if (len(v_span) == 1 and k in v_span) else 0))
                for k in range(ii)
            )
            if supply < len(nbrs):
                raise ValueError(
                    f"II={ii} infeasible: node {v} neighbour supply {supply} < "
                    f"{len(nbrs)}"
                )
        # Per-op-class capacity (heterogeneous grids): emit one cardinality
        # constraint per class that is strictly tighter than the global PE
        # bound, with a free per-window UNSAT precheck — a class with more
        # members than capacity*II can never fit this window.
        class_caps: list[tuple[str, int, tuple[int, ...]]] = []
        if cgra.heterogeneous:
            from .cgra import op_class

            members: dict[str, list[int]] = {}
            for v in dfg.nodes:
                members.setdefault(op_class(dfg.ops[v]), []).append(v)
            for cls, nodes in sorted(members.items()):
                cap = cgra.class_capacity(cls)
                if cap >= cgra.num_pes:
                    continue
                if len(nodes) > cap * ii:
                    raise ValueError(
                        f"II={ii} infeasible: {len(nodes)} {cls!r} ops > "
                        f"capacity {cap} x II"
                    )
                class_caps.append((cls, cap, tuple(nodes)))
        self.mobs = MobilitySchedule(tuple(self.asap), tuple(self.alap))
        self.adj = dfg.undirected_adjacency()
        problem = TimeProblem(
            num_nodes=dfg.num_nodes,
            edges=tuple((e.src, e.dst, e.distance) for e in dfg.edges),
            adj=tuple(frozenset(s) for s in self.adj),
            ii=ii,
            asap=tuple(self.asap),
            alap=tuple(self.alap),
            cap=cgra.num_pes,
            d_m=d_m,
            strict=connectivity == "strict",
            seed=seed,
            class_caps=tuple(class_caps),
            triangle_free=cgra.triangle_free and route_hops == 0,
        )
        self.backend = resolve_backend_name(backend)
        self._engine = create_backend(self.backend, problem, timeout_s=timeout_s)
        self.stats.backend = self._engine.name

    @property
    def exhausted(self) -> bool:
        return self._engine.exhausted

    def block(self, labels: list[int]) -> None:
        """Externally exclude a label partition (e.g. register-pressure reject)."""
        self._engine.block(labels)
        self.stats.blocked += 1

    def realize_compact(
        self, sol: TimeSolution, *, nodes=None
    ) -> TimeSolution:
        """Lifetime-compacting re-realization of ``sol``'s label partition.

        Backends return the *minimal* schedule for a partition (every node as
        early as its window and residue allow), which maximises
        producer-to-consumer gaps and therefore register lifetimes. This pass
        keeps every sink at its minimal time but pushes every producer as
        late as its consumers permit (greatest fixpoint of the difference
        constraints, floor-rounded to each node's residue class) — same
        labels, same validity, shorter lifetimes. Used by the mapper's
        register-pressure-constrained retries (paper §V-3 extension).

        ``nodes`` restricts the push to a subset (the mapper passes the nodes
        placed on register-oversubscribed PEs so only the offending PEs'
        schedules move); everything else keeps its time from ``sol``, which
        stays valid because the fixpoint is pointwise >= ``sol``.
        """
        ii = self.ii
        labels = sol.labels
        n = self.dfg.num_nodes
        movable = set(range(n)) if nodes is None else set(nodes)
        has_succ = [False] * n
        for e in self.dfg.edges:
            if e.src != e.dst:
                has_succ[e.src] = True
        ub: list[int] = []
        for v in range(n):
            if not has_succ[v] or v not in movable:
                ub.append(sol.t_abs[v])     # sinks (and unselected nodes) stay
                continue
            win = residue_window(self.asap[v], self.alap[v], labels[v], ii)
            assert win is not None          # sol.t_abs[v] inhabits the class
            ub.append(win[1])
        t = list(ub)
        changed = True
        while changed:
            changed = False
            for e in self.dfg.edges:
                bound = t[e.dst] - 1 + ii * e.distance   # t_src <= bound
                if t[e.src] > bound:
                    nt = bound - ((bound - labels[e.src]) % ii)
                    t[e.src] = nt
                    changed = True
        # sol is a solution of the same system, so the greatest fixpoint is
        # pointwise >= sol and in particular within every window
        return TimeSolution(ii, t)

    def next_solution(
        self,
        *,
        deadline: float | None = None,
        step_budget: int | None = None,
    ) -> TimeSolution | None:
        start = _time.perf_counter()
        span = obs.span("time.probe", ii=self.ii, backend=self.stats.backend)
        steps0 = getattr(self._engine, "steps_total", 0)
        with span:
            try:
                t_abs = self._engine.next_solution(
                    deadline=deadline, step_budget=step_budget
                )
                if t_abs is None:
                    span.set(found=False,
                             exhausted=self._engine.exhausted,
                             steps=getattr(self._engine, "steps_total", 0) - steps0)
                    return None
                self.stats.num_solutions_enumerated += 1
                span.set(found=True,
                         steps=getattr(self._engine, "steps_total", 0) - steps0)
                return TimeSolution(self.ii, list(t_abs))
            finally:
                self.stats.solver_time_s += _time.perf_counter() - start
                self.stats.steps = getattr(self._engine, "steps_total", 0)


def check_time_solution(
    dfg: DFG, cgra: CGRA, sol: TimeSolution, *, connectivity: str = "paper"
) -> list[str]:
    """Independent validator; returns a list of violated-constraint messages."""
    errs: list[str] = []
    ii = sol.ii
    labels = sol.labels
    for e in dfg.edges:
        if not sol.t_abs[e.dst] >= sol.t_abs[e.src] + 1 - ii * e.distance:
            errs.append(f"dep {e} violated: t={sol.t_abs[e.src]},{sol.t_abs[e.dst]}")
    for i in range(ii):
        c = sum(1 for v in dfg.nodes if labels[v] == i)
        if c > cgra.num_pes:
            errs.append(f"capacity exceeded at step {i}: {c} > {cgra.num_pes}")
    if cgra.heterogeneous:
        from .cgra import op_class

        for cls in {op_class(dfg.ops[v]) for v in dfg.nodes}:
            cap = cgra.class_capacity(cls)
            if cap >= cgra.num_pes:
                continue
            for i in range(ii):
                c = sum(
                    1 for v in dfg.nodes
                    if labels[v] == i and op_class(dfg.ops[v]) == cls
                )
                if c > cap:
                    errs.append(
                        f"class capacity exceeded at step {i}: "
                        f"{c} {cls!r} ops > {cap}"
                    )
    d_m = cgra.connectivity_degree
    adj = dfg.undirected_adjacency()
    for v in dfg.nodes:
        for i in range(ii):
            cnt = sum(1 for u in adj[v] if labels[u] == i)
            limit = d_m
            if connectivity == "strict" and i == labels[v]:
                limit = d_m - 1
            if cnt > limit:
                errs.append(f"connectivity exceeded: node {v} step {i}: {cnt} > {limit}")
    return errs

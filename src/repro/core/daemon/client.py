"""Client for the compile-daemon unix socket (DESIGN.md §16.1).

A thin, dependency-free NDJSON requester::

    from repro.core.daemon import DaemonClient

    with DaemonClient("/tmp/repro.sock") as client:
        assert client.ping()
        row = client.compile(dfg, tenant="ci", deadline_s=5.0,
                             options={"max_route_hops": 1})
        assert row["ok"] or row["failure"] in ("overloaded", "cancelled")

One client holds one connection; requests on it are serialized (send a line,
read a line). Use one client per thread for concurrent load — connections
are cheap and the daemon handles each on its own thread.
"""

from __future__ import annotations

import json
import socket

from ..dfg import DFG

__all__ = ["DaemonClient", "DaemonError"]


class DaemonError(RuntimeError):
    """A transport- or protocol-level failure (NOT a failed compile row —
    shed and cancelled requests come back as ordinary rows with their
    machine-readable ``failure`` code set)."""


class DaemonClient:
    """One NDJSON connection to a :class:`~repro.core.daemon.DaemonServer`."""

    def __init__(self, socket_path: str, *, timeout_s: float | None = None):
        self.socket_path = socket_path
        self._sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        if timeout_s is not None:
            self._sock.settimeout(timeout_s)
        try:
            self._sock.connect(socket_path)
        except OSError as exc:
            self._sock.close()
            raise DaemonError(
                f"cannot connect to daemon at {socket_path}: {exc}"
            ) from None
        self._rfile = self._sock.makefile("rb")

    # ---------------------------------------------------------------- plumbing
    def request(self, msg: dict) -> dict:
        """Send one request object, return the daemon's response object.

        Raises :class:`DaemonError` on transport failure or an
        ``{"ok": false}`` protocol response.
        """
        try:
            self._sock.sendall(json.dumps(msg).encode() + b"\n")
            line = self._rfile.readline()
        except OSError as exc:
            raise DaemonError(f"daemon connection failed: {exc}") from None
        if not line:
            raise DaemonError("daemon closed the connection")
        try:
            resp = json.loads(line)
        except ValueError as exc:
            raise DaemonError(f"malformed daemon response: {exc}") from None
        if not resp.get("ok"):
            raise DaemonError(resp.get("error", "daemon request failed"))
        return resp

    def close(self) -> None:
        try:
            self._rfile.close()
        finally:
            self._sock.close()

    def __enter__(self) -> "DaemonClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------- verbs
    def ping(self) -> bool:
        return bool(self.request({"op": "ping"}).get("pong"))

    def stats(self) -> dict:
        return self.request({"op": "stats"})["stats"]

    def compile(
        self,
        dfg: DFG,
        *,
        tenant: str | None = None,
        deadline_s: float | None = None,
        options: dict | None = None,
    ) -> dict:
        """Compile one DFG; returns the full CompileResult row dict.

        ``options`` is a dict of per-request :class:`CompileOptions` field
        overrides. Admission decisions arrive as rows, not exceptions:
        check ``row["failure"]`` for ``"overloaded"`` (back off and retry)
        and ``"cancelled"`` (deadline expired before a worker was free).
        """
        msg: dict = {"op": "compile", "dfg": json.loads(dfg.to_json())}
        if tenant is not None:
            msg["tenant"] = tenant
        if deadline_s is not None:
            msg["deadline_s"] = deadline_s
        if options:
            msg["options"] = options
        return self.request(msg)["result"]

    def shutdown(self) -> bool:
        """Ask the daemon to stop; True when it acknowledged."""
        return bool(self.request({"op": "shutdown"}).get("stopping"))

"""Persistent compile daemon (DESIGN.md §16).

A long-running compile server over one :class:`~repro.api.Compiler` session:
bounded-queue admission control with machine-readable ``overloaded`` sheds,
per-tenant deadlines, in-flight coalescing of identical requests, idle-time
speculative premapping with hit attribution, unix-socket NDJSON transport,
and bounded disk-cache/trace maintenance for unbounded lifetimes.

* :class:`CompileDaemon` — the in-process server core (``server.py``)
* :class:`DaemonServer` / :func:`serve` — unix-socket transport
  (``protocol.py``)
* :class:`DaemonClient` — the matching client (``client.py``)
* ``python -m repro.daemon`` — the CLI frontend (serve / submit / stats /
  shutdown)
"""

from .client import DaemonClient, DaemonError
from .protocol import DaemonServer, serve
from .server import CompileDaemon, DaemonStats, Ticket, neighbor_options

__all__ = [
    "CompileDaemon",
    "DaemonClient",
    "DaemonError",
    "DaemonServer",
    "DaemonStats",
    "Ticket",
    "neighbor_options",
    "serve",
]

"""Unix-socket NDJSON transport for the compile daemon (DESIGN.md §16.1).

The wire format is deliberately boring: an ``AF_UNIX`` stream socket carrying
newline-delimited JSON, one object per line, one response line per request
line. A connection may pipeline any number of requests; the daemon handles
each connection on its own thread (the compile work itself is bounded by the
daemon's worker pool and admission control, so connection threads only ever
block on queue tickets, not on solves they started).

Request objects (``op`` selects the verb):

``{"op": "compile", "dfg": {...}, "tenant": ..., "deadline_s": ...,
   "options": {...}}``
    ``dfg`` is the parsed form of :meth:`repro.core.dfg.DFG.to_json`;
    ``options`` is a dict of per-request :class:`CompileOptions` overrides
    (e.g. ``{"max_route_hops": 1}``). Response: ``{"ok": true, "result":
    <CompileResult row>}`` — shed/cancelled requests are *successful
    responses* carrying a failed row (``result.failure == "overloaded"`` /
    ``"cancelled"``), so transport errors and service decisions never mix.
``{"op": "ping"}``
    Liveness probe. Response ``{"ok": true, "pong": true}``.
``{"op": "stats"}``
    Daemon counters. Response ``{"ok": true, "stats": {...}}``
    (:meth:`CompileDaemon.stats_dict`).
``{"op": "shutdown"}``
    Graceful stop: response ``{"ok": true, "stopping": true}`` is written
    first, then the server drains and exits its serve loop.

A malformed line or unknown op produces ``{"ok": false, "error": "..."}``
on that line and the connection stays usable — one bad client request must
never poison a pipelined neighbor or crash the daemon.
"""

from __future__ import annotations

import json
import os
import socket
import threading

from ..dfg import DFG
from .server import CompileDaemon

__all__ = ["DaemonServer", "serve"]

#: Per-line size cap (a DFG of thousands of nodes is ~100 KB; 32 MB is
#: generous headroom while still bounding a malicious/broken client).
MAX_LINE_BYTES = 32 * 1024 * 1024


class DaemonServer:
    """Serves one :class:`CompileDaemon` over a unix socket.

    Example::

        server = DaemonServer(daemon, "/tmp/repro.sock")
        server.start()          # background accept loop
        ...
        server.stop()           # close socket, join threads, stop the daemon
    """

    def __init__(self, daemon: CompileDaemon, socket_path: str) -> None:
        self.daemon = daemon
        self.socket_path = socket_path
        self._sock: socket.socket | None = None
        self._accept_thread: threading.Thread | None = None
        self._conn_threads: list[threading.Thread] = []
        self._stop = threading.Event()
        self._shutdown_requested = threading.Event()

    # ---------------------------------------------------------------- lifecycle
    def start(self) -> None:
        """Bind the socket and start accepting (daemon workers start too)."""
        if self._sock is not None:
            return
        # a stale socket file from a crashed daemon would make bind fail;
        # only unlink when nothing is listening behind it
        if os.path.exists(self.socket_path):
            probe = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            try:
                probe.settimeout(0.25)
                probe.connect(self.socket_path)
            except OSError:
                os.unlink(self.socket_path)
            else:
                probe.close()
                raise RuntimeError(
                    f"a daemon is already listening on {self.socket_path}")
            finally:
                probe.close()
        self.daemon.start()
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.bind(self.socket_path)
        sock.listen(64)
        sock.settimeout(0.2)  # lets the accept loop observe _stop
        self._sock = sock
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="repro-daemon-accept", daemon=True)
        self._accept_thread.start()

    def stop(self) -> None:
        """Stop accepting, join connection threads, stop the daemon."""
        self._stop.set()
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)
            self._accept_thread = None
        if self._sock is not None:
            self._sock.close()
            self._sock = None
        for t in self._conn_threads:
            t.join(timeout=5.0)
        self._conn_threads.clear()
        try:
            os.unlink(self.socket_path)
        except OSError:
            pass
        self.daemon.stop()

    def serve_forever(self) -> None:
        """Block until a client sends ``shutdown`` (the CLI serve mode)."""
        if self._sock is None:
            self.start()
        self._shutdown_requested.wait()
        self.stop()

    # ------------------------------------------------------------------- loops
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _addr = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                return  # socket closed under us during stop()
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 name="repro-daemon-conn", daemon=True)
            t.start()
            self._conn_threads.append(t)
            # opportunistic reaping keeps the list bounded on long sessions
            self._conn_threads = [x for x in self._conn_threads
                                  if x.is_alive()]

    def _serve_conn(self, conn: socket.socket) -> None:
        with conn:
            rfile = conn.makefile("rb")
            try:
                while not self._stop.is_set():
                    line = rfile.readline(MAX_LINE_BYTES + 1)
                    if not line:
                        return  # client hung up
                    if len(line) > MAX_LINE_BYTES:
                        self._send(conn, {"ok": False,
                                          "error": "request line too large"})
                        return
                    line = line.strip()
                    if not line:
                        continue
                    resp, shutdown = self._dispatch(line)
                    self._send(conn, resp)
                    if shutdown:
                        self._shutdown_requested.set()
                        return
            except OSError:
                return  # torn connection: nothing to clean up
            finally:
                rfile.close()

    @staticmethod
    def _send(conn: socket.socket, obj: dict) -> None:
        conn.sendall(json.dumps(obj).encode() + b"\n")

    # ---------------------------------------------------------------- dispatch
    def _dispatch(self, line: bytes) -> tuple[dict, bool]:
        """One request line → (response object, shutdown?). Never raises."""
        try:
            msg = json.loads(line)
            if not isinstance(msg, dict):
                raise ValueError("request must be a JSON object")
            op = msg.get("op")
            if op == "ping":
                return {"ok": True, "pong": True}, False
            if op == "stats":
                return {"ok": True, "stats": self.daemon.stats_dict()}, False
            if op == "shutdown":
                return {"ok": True, "stopping": True}, True
            if op == "compile":
                return {"ok": True, "result": self._compile(msg)}, False
            raise ValueError(f"unknown op {op!r}")
        except Exception as exc:
            return {"ok": False,
                    "error": f"{type(exc).__name__}: {exc}"}, False

    def _compile(self, msg: dict) -> dict:
        dfg = DFG.from_json(json.dumps(msg["dfg"]))
        overrides = msg.get("options") or {}
        if not isinstance(overrides, dict):
            raise ValueError("options must be an object of field overrides")
        ticket = self.daemon.submit(
            dfg,
            tenant=msg.get("tenant"),
            deadline_s=msg.get("deadline_s"),
            **overrides,
        )
        # connection threads block on tickets, never on solves they own —
        # deadline requests resolve by their deadline, the rest by budget
        return ticket.wait()


def serve(daemon: CompileDaemon, socket_path: str) -> DaemonServer:
    """Convenience: build, start, and return a :class:`DaemonServer`."""
    server = DaemonServer(daemon, socket_path)
    server.start()
    return server

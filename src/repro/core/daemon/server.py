"""The persistent compile daemon: :class:`CompileDaemon` (DESIGN.md §16).

A long-running, in-process compile server layered on the existing pieces —
the :class:`repro.api.Compiler` session, the two-layer mapping cache
(DESIGN.md §9) and the cooperative-cancellation hooks of the portfolio
mapper — so the *warm* path of a request is a memory-cache hit plus queue
bookkeeping (sub-millisecond), while the cold path pays the ordinary solve
once per (dfg, options) key for the life of the cache.

Request lifecycle::

    submit() ── admission ──> queue ──> worker thread ──> CompileResult row
         │          │                      │
         │          ├─ shed: failure="overloaded" (queue full / no
         │          │        deadline budget) — never queued, never solved
         │          └─ coalesce: identical in-flight (dfg, options) request
         │                       → attach as follower, share the one solve
         └─ Ticket.wait() → the unified CompileResult row dict

* **Admission control** — a bounded queue (``queue_limit``) plus a deadline
  budget check: a request whose own deadline is shorter than the estimated
  queue wait (EWMA of recent service times × queue depth / workers) is shed
  immediately with the machine-readable ``overloaded`` failure code rather
  than admitted to time out. Shedding never raises and never blocks.
* **Per-tenant deadlines** — each request carries ``deadline_s`` (and a
  ``tenant`` label for attribution); the remaining budget at pickup becomes
  the mapper's ``time_budget_s`` and the request's ``should_stop`` hook, so
  a deadline expiring mid-solve cancels cooperatively inside the worker. A
  request whose deadline expired while still queued finishes as
  ``cancelled`` without occupying a worker.
* **Coalescing** — concurrent identical (dfg, arch, mapper-options) requests
  share one solve: the first becomes the leader, later ones attach as
  followers and receive a copy of the leader's row (``service.coalesced``)
  the moment it finishes. This closes the cold-cache stampede window that
  per-request caching alone cannot (N concurrent misses → N solves).
* **Speculative premapping** — a background thread that runs only while the
  queue is empty and all workers are idle, warming both cache layers for
  *neighboring* option variants (±1 ``max_route_hops``, relaxed register
  pressure) of recently requested kernels. Warmed keys are remembered; a
  later real request served from a speculatively warmed key is attributed
  ``speculative`` provenance in ``metrics.cache`` and the daemon's
  ``speculative_hits`` counter, so the policy's payoff is measurable
  (``benchmarks/bench_service.py`` gates it in CI).

Workers are *threads*, not processes: the warm path (cache hit) never
touches the GIL-bound solver, and cold solves inherit the process-wide
memory LRU + disk cache directly. The solver itself is pure Python, so
concurrent cold solves time-slice; daemons fronting heavy cold traffic
should pre-warm via ``repro.compile`` / speculation (DESIGN.md §16.6).
"""

from __future__ import annotations

import itertools
import json
import os
import threading
import time as _time
from collections import OrderedDict, deque
from dataclasses import dataclass, field

from ... import obs
from ...api import CompileOptions, Compiler, CompileResult
from ...api.result import classify_failure
from ..dfg import DFG
from ..mapper import _cache_base_key
from ..space_backends import resolve_space_backend_name

__all__ = ["CompileDaemon", "DaemonStats", "Ticket", "neighbor_options"]

#: How many recently completed request keys feed the speculator.
_RECENT_LIMIT = 64
#: Default cap on remembered speculative-attempt keys (dedup, bounded).
_ATTEMPT_LIMIT = 4096


@dataclass
class DaemonStats:
    """Daemon-lifetime counters (all guarded by the daemon lock)."""

    submitted: int = 0
    completed: int = 0
    shed: int = 0
    coalesced: int = 0
    cancelled_in_queue: int = 0
    solves: int = 0
    warm_memory: int = 0
    warm_disk: int = 0
    failed: int = 0
    speculative_attempts: int = 0
    speculative_warms: int = 0
    speculative_hits: int = 0
    cache_prunes: int = 0
    cache_evictions: int = 0

    def as_dict(self) -> dict:
        warm = self.warm_memory + self.warm_disk
        done = self.completed
        return {
            "submitted": self.submitted,
            "completed": done,
            "shed": self.shed,
            "coalesced": self.coalesced,
            "cancelled_in_queue": self.cancelled_in_queue,
            "solves": self.solves,
            "warm_memory": self.warm_memory,
            "warm_disk": self.warm_disk,
            "failed": self.failed,
            "warm_hit_rate": round(warm / done, 6) if done else None,
            "speculative": {
                "attempts": self.speculative_attempts,
                "warms": self.speculative_warms,
                "hits": self.speculative_hits,
                "hit_rate": round(self.speculative_hits / done, 6)
                            if done else None,
            },
            "cache_maintenance": {
                "prunes": self.cache_prunes,
                "evictions": self.cache_evictions,
            },
        }


class _Request:
    """One admitted compile request (leader or follower)."""

    __slots__ = ("rid", "dfg", "opts", "tenant", "deadline_s", "t_submit",
                 "done", "row", "followers", "key")

    def __init__(self, rid, dfg, opts, tenant, deadline_s, key):
        self.rid = rid
        self.dfg = dfg
        self.opts = opts
        self.tenant = tenant
        self.deadline_s = deadline_s
        self.t_submit = _time.perf_counter()
        self.done = threading.Event()
        self.row: dict | None = None
        self.followers: list[_Request] = []
        self.key = key

    def expired(self, now: float | None = None) -> bool:
        if self.deadline_s is None:
            return False
        return (now or _time.perf_counter()) - self.t_submit > self.deadline_s


class Ticket:
    """Caller handle for a submitted request: ``wait()`` → the result row.

    Shed requests return a completed ticket immediately (the overloaded row
    is already attached), so callers never need to special-case admission.
    """

    __slots__ = ("_req",)

    def __init__(self, req: _Request):
        self._req = req

    @property
    def done(self) -> bool:
        return self._req.done.is_set()

    def wait(self, timeout: float | None = None) -> dict | None:
        """Block for the CompileResult row dict (None on wait timeout)."""
        if not self._req.done.wait(timeout):
            return None
        return self._req.row


def neighbor_options(opts: CompileOptions) -> list[CompileOptions]:
    """The speculative-premap variant set of one request's options.

    Neighbors along the cache-key axes a *single-target* daemon can vary
    (DESIGN.md §16.3): the route-through hop allowance ±1 (clamped at 0) and
    the relaxed register-pressure variant (``max_register_pressure=None``)
    when the request constrained it. The arch axis is fixed per daemon — a
    daemon serves one machine, so arch neighbors would warm keys no request
    of this daemon can ever ask for.
    """
    variants: list[CompileOptions] = []
    h = opts.max_route_hops
    for nh in (h + 1, h - 1):
        if nh >= 0:
            variants.append(opts.replace(max_route_hops=nh))
    if opts.max_register_pressure is not None:
        variants.append(opts.replace(max_register_pressure=None))
    return variants


class CompileDaemon:
    """Persistent compile server over one :class:`~repro.api.Compiler`.

    Example — an in-process daemon session::

        from repro.core.daemon import CompileDaemon
        from repro.core import CGRA, running_example

        daemon = CompileDaemon(CGRA(4, 4), "fast", workers=2)
        daemon.start()
        try:
            row = daemon.submit(running_example(), tenant="t0").wait()
            assert row["ok"] and row["service"]["tenant"] == "t0"
        finally:
            daemon.stop()

    Parameters:

    * ``target`` / ``options`` — forwarded to :class:`repro.api.Compiler`
      (CGRA / ArchSpec / preset string; CompileOptions / profile name).
    * ``workers`` — compile worker threads.
    * ``queue_limit`` — max *queued* (not in-flight) requests before
      admission control sheds with ``overloaded``.
    * ``speculate`` — enable idle-time speculative premapping (forced off in
      deterministic sessions, whose mapper bypasses both caches, and when
      ``use_cache`` is off — there is nothing to warm).
    * ``speculate_budget_s`` — wall budget per speculative warm compile.
    * ``cache_max_bytes`` / ``cache_max_age_s`` — periodic
      :meth:`DiskMappingCache.prune` bounds so a long-running daemon's disk
      cache cannot grow without bound.
    * ``trace_dir`` — when set, the daemon installs a session tracer and
      rotates drained span segments into ``trace-<seq>.json`` files there
      (every ``rotate_every`` completed requests and at shutdown); each
      segment is a standalone Perfetto/``tools/trace_report.py`` document.
    """

    def __init__(
        self,
        target=None,
        options=None,
        *,
        workers: int = 2,
        queue_limit: int = 64,
        speculate: bool = True,
        speculate_budget_s: float = 10.0,
        cache_max_bytes: int | None = None,
        cache_max_age_s: float | None = None,
        prune_every: int = 64,
        trace_dir: str | None = None,
        rotate_every: int = 256,
        **overrides,
    ) -> None:
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if queue_limit < 1:
            raise ValueError(f"queue_limit must be >= 1, got {queue_limit}")
        self.compiler = Compiler(target, options, **overrides)
        self.options = self.compiler.options
        self.num_workers = workers
        self.queue_limit = queue_limit
        self.speculate = (speculate and self.options.use_cache
                          and not self.options.deterministic)
        self.speculate_budget_s = speculate_budget_s
        self.cache_max_bytes = cache_max_bytes
        self.cache_max_age_s = cache_max_age_s
        self.prune_every = max(1, prune_every)
        self.trace_dir = trace_dir
        self.rotate_every = max(1, rotate_every)
        self.stats = DaemonStats()

        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._queue: deque[_Request] = deque()
        self._inflight: dict[str, _Request] = {}   # key -> leader
        self._active = 0                           # workers mid-request
        self._rid = itertools.count(1)
        self._ewma_service_s = 0.0                 # admission wait estimate
        self._started = False
        self._stopping = False
        self._threads: list[threading.Thread] = []
        # speculation state: FIFO of pending (dfg, variant-opts), bounded
        # dedup of attempted variant keys, and the warmed-key set that
        # attributes later real hits to speculation
        self._spec_pending: deque[tuple[DFG, CompileOptions]] = deque()
        self._spec_attempted: OrderedDict[tuple, None] = OrderedDict()
        self._spec_keys: set[tuple] = set()
        self._since_prune = 0
        # trace rotation
        self._tracer: obs.Tracer | None = None
        self._tracer_prev: obs.Tracer | None = None
        self._rotate_seq = 0
        self._since_rotate = 0

    # ---------------------------------------------------------------- lifecycle
    def start(self) -> None:
        """Spawn the worker (and speculator) threads; idempotent."""
        with self._lock:
            if self._started:
                return
            self._started = True
        if self.trace_dir is not None:
            os.makedirs(self.trace_dir, exist_ok=True)
            self._tracer = obs.Tracer(process_name="repro-daemon")
            self._tracer_prev = obs.install_tracer(self._tracer)
        for i in range(self.num_workers):
            t = threading.Thread(target=self._worker_loop,
                                 name=f"repro-daemon-worker-{i}", daemon=True)
            t.start()
            self._threads.append(t)
        if self.speculate:
            t = threading.Thread(target=self._speculator_loop,
                                 name="repro-daemon-speculator", daemon=True)
            t.start()
            self._threads.append(t)

    def stop(self, timeout: float = 10.0) -> None:
        """Drain nothing, stop everything: queued requests finish as
        ``cancelled``, in-flight compiles observe ``should_stop`` at their
        next budget check, threads join, the trace session rotates out."""
        with self._cv:
            self._stopping = True
            queued = list(self._queue)
            self._queue.clear()
            self._cv.notify_all()
        for req in queued:
            self._finish(req, self._failure_row(
                req, "cancelled: daemon stopped", cancelled=True))
        for t in self._threads:
            t.join(timeout=timeout)
        self._threads.clear()
        if self._tracer is not None:
            self._rotate(force=True)
            obs.install_tracer(self._tracer_prev)
            self._tracer = None

    def __enter__(self) -> "CompileDaemon":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------------ submit
    def submit(
        self,
        dfg: DFG,
        *,
        tenant: str | None = None,
        deadline_s: float | None = None,
        **overrides,
    ) -> Ticket:
        """Admit one compile request; returns immediately with a Ticket.

        ``deadline_s`` defaults to the session's ``options.deadline_s``
        (None = no deadline); ``tenant`` defaults to ``options.tenant``.
        ``**overrides`` are per-request option changes (e.g.
        ``max_route_hops=1``) resolved against the session options — the
        same override semantics every other frontend uses.
        """
        opts = self.compiler.options
        if overrides:
            opts = opts.replace(**overrides)
            opts.validate()
        tenant = tenant if tenant is not None else opts.tenant
        deadline_s = deadline_s if deadline_s is not None else opts.deadline_s
        key = self._coalesce_key(dfg, opts)
        req = _Request(next(self._rid), dfg, opts, tenant, deadline_s, key)
        with self._cv:
            self.stats.submitted += 1
            if self._stopping or not self._started:
                if self._stopping:
                    self.stats.shed += 1
                    self._set_row(req, self._failure_row(
                        req, "overloaded: daemon is shutting down"))
                    return Ticket(req)
                # not started yet: queue freely (tests drive this mode —
                # requests admitted now run when start() is called)
            leader = self._inflight.get(key)
            if leader is not None:
                # stampede coalescing: ride the in-flight identical request
                leader.followers.append(req)
                self.stats.coalesced += 1
                return Ticket(req)
            shed_reason = self._admission_reason(req)
            if shed_reason is not None:
                self.stats.shed += 1
                obs.event("daemon.shed", kernel=dfg.name, tenant=tenant)
                self._set_row(req, self._failure_row(req, shed_reason))
                return Ticket(req)
            self._inflight[key] = req
            self._queue.append(req)
            self._cv.notify()
        return Ticket(req)

    def compile(self, dfg: DFG, **kwargs) -> dict:
        """Synchronous convenience: ``submit(...).wait()``."""
        return self.submit(dfg, **kwargs).wait()

    # ----------------------------------------------------------------- queries
    def stats_dict(self) -> dict:
        with self._lock:
            d = self.stats.as_dict()
            d["queue_depth"] = len(self._queue)
            d["active"] = self._active
            d["workers"] = self.num_workers
            d["queue_limit"] = self.queue_limit
            d["speculate"] = self.speculate
            d["ewma_service_s"] = round(self._ewma_service_s, 6)
        cache = self.compiler.cache
        if cache is not None:
            d["disk_cache"] = cache.stats.as_dict()
        return d

    # ---------------------------------------------------------------- internals
    def _coalesce_key(self, dfg: DFG, opts: CompileOptions) -> str:
        """Identity of "the same solve": DFG content + every mapper-visible
        option. Tenant/deadline deliberately excluded — they shape *service*,
        not the mapping, so requests differing only there coalesce."""
        kw = opts.mapper_kwargs()
        kw["exact_check"] = opts.exact_check
        return dfg.stable_hash() + "|" + json.dumps(
            kw, sort_keys=True, default=str)

    def _cache_key(self, dfg: DFG, opts: CompileOptions) -> tuple:
        """The mapping-cache base key this request resolves to (§9/§13.4) —
        the unit of speculative-warm attribution."""
        return _cache_base_key(
            dfg, self.compiler.cgra, opts.connectivity,
            opts.max_register_pressure, opts.max_route_hops,
            resolve_space_backend_name(opts.space_backend, self.compiler.cgra),
        )

    def _admission_reason(self, req: _Request) -> str | None:
        """Shed decision (lock held): a reason string, or None = admit."""
        depth = len(self._queue)
        if depth >= self.queue_limit:
            return (f"overloaded: queue full "
                    f"(depth {depth} >= limit {self.queue_limit})")
        if req.deadline_s is not None and self._ewma_service_s > 0:
            est_wait = ((depth + self._active)
                        * self._ewma_service_s / self.num_workers)
            if est_wait > req.deadline_s:
                return (f"overloaded: deadline budget exceeded "
                        f"(estimated queue wait {est_wait:.3f}s > "
                        f"deadline {req.deadline_s:.3f}s)")
        return None

    def _failure_row(self, req: _Request, reason: str, *,
                     cancelled: bool = False) -> dict:
        res = CompileResult(
            name=req.dfg.name, ok=False, reason=reason, cancelled=cancelled,
            failure=classify_failure(False, reason, cancelled),
        )
        res.service = self._service_block(req, coalesced=False,
                                          speculative=False)
        return res.as_dict()

    def _service_block(self, req: _Request, *, coalesced: bool,
                       speculative: bool) -> dict:
        return {
            "tenant": req.tenant,
            "deadline_s": req.deadline_s,
            "queue_s": round(_time.perf_counter() - req.t_submit, 6),
            "coalesced": coalesced,
            "speculative": speculative,
        }

    def _set_row(self, req: _Request, row: dict) -> None:
        req.row = row
        req.done.set()

    def _finish(self, req: _Request, row: dict, *,
                speculative: bool = False) -> None:
        """Deliver the leader's row to it and every coalesced follower.

        The in-flight key is retired and the follower list snapshotted in
        one critical section: a concurrent identical submit either attached
        before (delivered below) or finds no leader and becomes one itself —
        attach-after-delivery (a follower nobody would ever wake) is
        impossible by construction.
        """
        with self._cv:
            self._inflight.pop(req.key, None)
            followers = list(req.followers)
        self._set_row(req, row)
        for f in followers:
            frow = json.loads(json.dumps(row))
            frow["service"] = self._service_block(
                f, coalesced=True, speculative=speculative)
            self._set_row(f, frow)

    # ------------------------------------------------------------- worker loop
    def _next_request(self) -> _Request | None:
        with self._cv:
            while not self._stopping:
                if self._queue:
                    req = self._queue.popleft()
                    self._active += 1
                    return req
                self._cv.wait(timeout=0.2)
            return None

    def _worker_done(self, req: _Request, service_s: float | None) -> None:
        # note: the in-flight key was already retired by _finish — popping it
        # here could evict a NEW leader admitted under the same key since
        with self._cv:
            self._active -= 1
            if service_s is not None:
                # EWMA of observed service time feeds deadline admission
                a = 0.2
                self._ewma_service_s = (
                    service_s if self._ewma_service_s == 0.0
                    else (1 - a) * self._ewma_service_s + a * service_s)
            self._cv.notify_all()

    def _worker_loop(self) -> None:
        while True:
            req = self._next_request()
            if req is None:
                return
            service_s = None
            try:
                now = _time.perf_counter()
                if req.expired(now):
                    # deadline burned entirely in the queue: report cancelled
                    # without running the mapper at all
                    with self._lock:
                        self.stats.cancelled_in_queue += 1
                        self.stats.completed += 1
                    self._finish(req, self._failure_row(
                        req, "cancelled: deadline expired in queue "
                        f"({now - req.t_submit:.3f}s queued > "
                        f"deadline {req.deadline_s:.3f}s)", cancelled=True))
                    continue
                t0 = _time.perf_counter()
                self._run(req)
                service_s = _time.perf_counter() - t0
            except Exception as exc:  # a bad request must never kill a worker
                self._finish(req, self._failure_row(
                    req, f"{type(exc).__name__}: {exc}"))
                with self._lock:
                    self.stats.completed += 1
                    self.stats.failed += 1
            finally:
                self._worker_done(req, service_s)
                self._maybe_rotate()

    def _run(self, req: _Request) -> None:
        """One admitted request through the session compiler (worker side)."""
        opts = req.opts
        extra: dict = {}
        if req.deadline_s is not None and not opts.deterministic:
            # remaining deadline budget at pickup becomes the mapper's wall
            # budget — the queue wait already spent part of the deadline
            remaining = req.deadline_s - (_time.perf_counter() - req.t_submit)
            extra["time_budget_s"] = max(
                0.001, min(opts.time_budget_s, remaining))

        def should_stop() -> bool:
            return self._stopping or req.expired()

        with obs.span("daemon.request", kernel=req.dfg.name,
                      tenant=req.tenant, rid=req.rid) as sp:
            # per-request option deltas ride through the same replace/
            # validate path as every frontend (already validated in submit)
            result = self.compiler.compile(
                req.dfg, should_stop=should_stop,
                **self._delta(opts, **extra))
            speculative = (
                result.source in ("memory", "disk")
                and self._cache_key(req.dfg, opts) in self._spec_keys
            )
            result.service = self._service_block(
                req, coalesced=False, speculative=speculative)
            if isinstance(result.metrics, dict) and "cache" in result.metrics:
                # speculative provenance lives next to the layer hit rates
                result.metrics["cache"]["speculative"] = speculative
            sp.set(ok=result.ok, ii=result.ii, source=result.source,
                   speculative=speculative)
        row = result.as_dict()
        self._record_completion(req, result, speculative)
        self._finish(req, row, speculative=speculative)

    def _record_completion(self, req, result, speculative: bool) -> None:
        with self._lock:
            self.stats.completed += 1
            if not result.ok:
                self.stats.failed += 1
            elif result.source == "memory":
                self.stats.warm_memory += 1
            elif result.source == "disk":
                self.stats.warm_disk += 1
            else:
                self.stats.solves += 1
            if speculative:
                self.stats.speculative_hits += 1
            if self.speculate:
                self._queue_speculation(req)

    # ------------------------------------------------------------- speculation
    def _queue_speculation(self, req: _Request) -> None:
        """(lock held) Enqueue unattempted neighbor variants of a completed
        request for the idle-time speculator."""
        for vopts in neighbor_options(req.opts):
            akey = self._cache_key(req.dfg, vopts)
            if akey in self._spec_attempted:
                continue
            self._spec_attempted[akey] = None
            while len(self._spec_attempted) > _ATTEMPT_LIMIT:
                self._spec_attempted.popitem(last=False)
            self._spec_pending.append((req.dfg, vopts))
            while len(self._spec_pending) > _RECENT_LIMIT:
                self._spec_pending.popleft()
        self._cv.notify_all()

    def _idle(self) -> bool:
        return not self._queue and self._active == 0

    def _speculator_loop(self) -> None:
        while True:
            with self._cv:
                while not self._stopping and not (
                        self._spec_pending and self._idle()):
                    self._cv.wait(timeout=0.1)
                if self._stopping:
                    return
                dfg, vopts = self._spec_pending.popleft()
            self._speculate_one(dfg, vopts)
            self._maintain_cache()

    def _speculate_one(self, dfg: DFG, vopts: CompileOptions) -> None:
        """Warm both cache layers for one neighbor variant; abandons the
        moment real traffic arrives (the workers' queue preempts idle work).
        """
        def should_stop() -> bool:
            return self._stopping or not self._idle()

        with self._lock:
            self.stats.speculative_attempts += 1
        budget = min(self.speculate_budget_s, vopts.time_budget_s)
        with obs.span("daemon.speculate", kernel=dfg.name,
                      hops=vopts.max_route_hops) as sp:
            try:
                result = self.compiler.compile(
                    dfg, should_stop=should_stop,
                    **self._delta(vopts, time_budget_s=budget))
            except Exception:
                # speculation is best-effort by definition
                return
            sp.set(ok=result.ok, ii=result.ii)
        if result.ok:
            with self._lock:
                self._spec_keys.add(self._cache_key(dfg, vopts))
                self.stats.speculative_warms += 1

    def _delta(self, opts: CompileOptions, **extra) -> dict:
        """Field-level diff of ``opts`` vs the session options, as per-call
        compile overrides (plus ``extra``)."""
        base = self.compiler.options
        d = {
            f: getattr(opts, f)
            for f in opts.as_dict()
            if getattr(opts, f) != getattr(base, f)
        }
        d.update(extra)
        return d

    def _maintain_cache(self) -> None:
        """Periodic disk-cache bounding (DESIGN.md §16.6): prune stale files
        and enforce the byte/age budget every ``prune_every`` speculative
        cycles — piggybacked on the idle thread so it never delays a request.
        """
        if self.cache_max_bytes is None and self.cache_max_age_s is None:
            return
        cache = self.compiler.cache
        if cache is None:
            return
        self._since_prune += 1
        if self._since_prune < self.prune_every:
            return
        self._since_prune = 0
        evicted_before = cache.stats.evictions
        cache.prune(max_bytes=self.cache_max_bytes,
                    max_age_s=self.cache_max_age_s)
        with self._lock:
            self.stats.cache_prunes += 1
            self.stats.cache_evictions += (
                cache.stats.evictions - evicted_before)

    # ---------------------------------------------------------- trace rotation
    def _maybe_rotate(self) -> None:
        if self._tracer is None:
            return
        with self._lock:
            self._since_rotate += 1
            due = self._since_rotate >= self.rotate_every
            if due:
                self._since_rotate = 0
        if due:
            self._rotate()

    def _rotate(self, force: bool = False) -> None:
        tracer = self._tracer
        if tracer is None or self.trace_dir is None:
            return
        events = tracer.drain()
        if not events and not force:
            return
        with self._lock:
            seq = self._rotate_seq
            self._rotate_seq += 1
        path = os.path.join(self.trace_dir, f"trace-{seq:04d}.json")
        try:
            tracer.write_segment(path, events)
        except OSError:
            pass  # tracing must never sink the daemon

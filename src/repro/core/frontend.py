"""Tracing frontend: extract a DFG from a Python loop body.

The paper's pipeline starts from LLVM IR (unavailable offline, DESIGN.md §2);
this frontend provides the equivalent entry point for Python-described loop
kernels: write the loop body once with ordinary operators, trace it into a
DFG, map it, then validate/execute the mapping against the *same function*.

    def body(ins, carried):
        acc = carried["acc"] + ins[0] * ins[1]   # multiply-accumulate
        return [acc], {"acc": acc}               # stores, next-iteration state

    dfg = trace_loop(body, num_inputs=2, carried=["acc"])
    mapping = map_dfg(dfg, CGRA(2, 2)).mapping

Carried state becomes phi nodes closed by distance-1 loop edges (phi(init, x)
= init + x with init stream = 0-padded first iteration, matching the
simulator's accumulate semantics). Supported ops mirror the CGRA ALU.
"""

from __future__ import annotations

from typing import Callable, Sequence

from .dfg import DFG, Edge


class _Tracer:
    def __init__(self) -> None:
        self.ops: list[str] = []
        self.imms: list[float] = []
        self.edges: list[Edge] = []

    def node(self, op: str, *args: "Var", imm: float = 0.0) -> "Var":
        nid = len(self.ops)
        self.ops.append(op)
        self.imms.append(imm)
        for a in args:
            if not isinstance(a, Var) or a.tracer is not self:
                raise TypeError("operands must be Vars from the same trace")
            self.edges.append(Edge(a.nid, nid))
        return Var(self, nid)


class Var:
    """A traced value; operators append DFG nodes."""

    def __init__(self, tracer: _Tracer, nid: int) -> None:
        self.tracer = tracer
        self.nid = nid

    def _lift(self, other) -> "Var":
        if isinstance(other, Var):
            return other
        return self.tracer.node("const", imm=float(other))

    def _bin(self, op: str, other) -> "Var":
        return self.tracer.node(op, self, self._lift(other))

    def __add__(self, o):  return self._bin("add", o)
    def __radd__(self, o): return self._lift(o)._bin("add", self)
    def __sub__(self, o):  return self._bin("sub", o)
    def __rsub__(self, o): return self._lift(o)._bin("sub", self)
    def __mul__(self, o):  return self._bin("mul", o)
    def __rmul__(self, o): return self._lift(o)._bin("mul", self)
    def __truediv__(self, o):  return self._bin("div", o)
    def __and__(self, o):  return self._bin("and", o)
    def __or__(self, o):   return self._bin("or", o)
    def __xor__(self, o):  return self._bin("xor", o)
    def __lshift__(self, o): return self._bin("shl", o)
    def __rshift__(self, o): return self._bin("shr", o)
    def __neg__(self):     return self.tracer.node("neg", self)
    def __invert__(self):  return self.tracer.node("not", self)
    def __abs__(self):     return self.tracer.node("abs", self)
    def __gt__(self, o):   return self._bin("cmp", o)

    def min(self, o):      return self._bin("min", o)
    def max(self, o):      return self._bin("max", o)


def trace_loop(
    body: Callable,
    *,
    num_inputs: int,
    carried: Sequence[str] = (),
    name: str = "traced",
) -> DFG:
    """Trace `body(inputs, carried_dict) -> (stores, new_carried_dict)`."""
    tr = _Tracer()
    ins = [tr.node("input") for _ in range(num_inputs)]
    phis = {k: tr.node("phi") for k in carried}
    # phi's first (intra) operand: a zero const initialiser keeps arity valid
    # when the body uses the carried value without adding an intra input.
    stores, new_carried = body(ins, dict(phis))
    if set(new_carried) != set(carried):
        raise ValueError(f"carried keys changed: {set(new_carried)} != {set(carried)}")
    for k, phi in phis.items():
        nxt = new_carried[k]
        if not isinstance(nxt, Var):
            raise TypeError(f"carried value {k!r} must be a Var")
        tr.edges.append(Edge(nxt.nid, phi.nid, 1))   # loop-carried edge
    for s in stores:
        tr.node("store", s)
    dfg = DFG(
        num_nodes=len(tr.ops), edges=tr.edges, ops=tr.ops, imms=tr.imms,
        name=name,
    )
    dfg.validate()
    return dfg

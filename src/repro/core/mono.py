"""Monomorphism-based space search (paper §IV-C).

Given a time solution (kernel label per DFG node), find an injective,
label-preserving, edge-preserving embedding of the undirected DFG into the
MRRG. Under the register-file architecture (see core/cgra.py) an MRRG edge
exists between (pe_u, t_u) and (pe_v, t_v) iff pe_u equals-or-neighbours pe_v,
so the search reduces to placing each node on a PE such that

  * at each kernel step, every PE hosts at most one node   (mono1 + mono2)
  * G-adjacent nodes land on closed-adjacent PEs           (mono3)

The search is a VF2/RI-style backtracking specialised to the label structure:
connected expansion order (most-placed-neighbours first), candidate sets from
the intersection of placed neighbours' closed neighbourhoods, forward checking
(every placed node must retain enough free adjacent slots per step for its
unplaced neighbours), and randomised restarts — the classic recipe that gives
VF3-class robustness [29,30] while exploiting the time labels, which partition
the injectivity constraint by step and keep the search shallow.
"""

from __future__ import annotations

import random
import time as _time
from dataclasses import dataclass

from .cgra import CGRA
from .dfg import DFG


@dataclass
class SpaceSolution:
    ii: int
    placement: list[int]  # node -> PE index


@dataclass
class SpaceStats:
    search_time_s: float = 0.0
    nodes_visited: int = 0
    backtracks: int = 0
    restarts: int = 0


def find_monomorphism(
    dfg: DFG,
    cgra: CGRA,
    labels: list[int],
    ii: int,
    *,
    timeout_s: float | None = 4.0,
    restarts: int = 6,
    seed: int = 0,
    stats: SpaceStats | None = None,
) -> SpaceSolution | None:
    """Randomised-restart wrapper around one backtracking dive per seed."""
    stats = stats if stats is not None else SpaceStats()
    start = _time.perf_counter()
    budget = timeout_s if timeout_s is not None else float("inf")
    per_restart = budget / max(1, restarts)
    for r in range(max(1, restarts)):
        remaining = budget - (_time.perf_counter() - start)
        if remaining <= 0:
            break
        stats.restarts += 1
        sol = _search_once(
            dfg, cgra, labels, ii,
            deadline=_time.perf_counter() + min(per_restart, remaining),
            rng=random.Random(seed * 7919 + r),
            shuffle=r > 0,   # first dive is deterministic greedy
            stats=stats,
        )
        if sol is not None:
            stats.search_time_s += _time.perf_counter() - start
            return SpaceSolution(ii=ii, placement=sol)
    stats.search_time_s += _time.perf_counter() - start
    return None


def _search_once(
    dfg: DFG,
    cgra: CGRA,
    labels: list[int],
    ii: int,
    *,
    deadline: float,
    rng: random.Random,
    shuffle: bool,
    stats: SpaceStats,
) -> list[int] | None:
    n = dfg.num_nodes
    adj_g = dfg.undirected_adjacency()
    neighbors = cgra.neighbors
    num_pes = cgra.num_pes

    if n > num_pes * ii:
        return None
    for v in range(n):
        if not 0 <= labels[v] < ii:
            raise ValueError(f"label out of range for node {v}: {labels[v]}")

    closed: list[tuple[int, ...]] = [
        tuple(sorted((p, *neighbors[p]))) for p in range(num_pes)
    ]
    degs = [len(adj_g[v]) for v in range(n)]

    pe_order = sorted(range(num_pes), key=lambda p: -len(neighbors[p]))
    if shuffle:
        pe_order = list(pe_order)
        rng.shuffle(pe_order)

    placement = [-1] * n
    occupied: list[set[int]] = [set() for _ in range(ii)]

    # unplaced-neighbour step profile per node, updated incrementally
    unplaced_by_step: list[dict[int, int]] = [dict() for _ in range(n)]
    for v in range(n):
        for u in adj_g[v]:
            unplaced_by_step[v][labels[u]] = unplaced_by_step[v].get(labels[u], 0) + 1

    def free_slots(p: int, step: int) -> int:
        return sum(1 for q in closed[p] if q not in occupied[step])

    def forward_ok(u: int) -> bool:
        """Placed node u must keep enough free adjacent slots per step."""
        pu = placement[u]
        for step, need in unplaced_by_step[u].items():
            if need and free_slots(pu, step) < need:
                return False
        return True

    def candidates(v: int) -> list[int]:
        placed_nbr_pes = [placement[u] for u in adj_g[v] if placement[u] >= 0]
        if placed_nbr_pes:
            base: set[int] | None = None
            for pu in placed_nbr_pes:
                s = set(closed[pu])
                base = s if base is None else (base & s)
                if not base:
                    return []
            cands = [p for p in base if p not in occupied[labels[v]]]
            # interior-first keeps future intersections large; jitter on restarts
            cands.sort(key=lambda p: (-len(neighbors[p]),
                                      rng.random() if shuffle else p))
            return cands
        return [p for p in pe_order if p not in occupied[labels[v]]]

    def place(v: int, p: int) -> None:
        placement[v] = p
        occupied[labels[v]].add(p)
        for u in adj_g[v]:
            unplaced_by_step[u][labels[v]] -= 1

    def unplace(v: int, p: int) -> None:
        for u in adj_g[v]:
            unplaced_by_step[u][labels[v]] += 1
        occupied[labels[v]].discard(p)
        placement[v] = -1

    def select_var() -> tuple[int, list[int]] | None:
        """Dynamic MRV: among frontier nodes (>=1 placed neighbour), pick the
        one with the fewest candidate PEs; empty frontier seeds a component."""
        best_v, best_c = -1, None
        for v in range(n):
            if placement[v] >= 0:
                continue
            if not any(placement[u] >= 0 for u in adj_g[v]):
                continue
            c = candidates(v)
            if not c:
                return (v, [])          # dead end: fail fast
            if best_c is None or (len(c), -degs[v]) < (len(best_c), -degs[best_v]):
                best_v, best_c = v, c
                if len(c) == 1:
                    break
        if best_v >= 0:
            return best_v, best_c
        # new component seed: highest-degree unplaced node
        seeds = [v for v in range(n) if placement[v] < 0]
        if not seeds:
            return None
        v = max(seeds, key=lambda u: (degs[u], rng.random() if shuffle else 0))
        return v, candidates(v)

    def rec(placed_count: int) -> bool:
        if placed_count == n:
            return True
        if _time.perf_counter() > deadline:
            return False
        sel = select_var()
        if sel is None:
            return True
        v, cands = sel
        for p in cands:
            stats.nodes_visited += 1
            place(v, p)
            if forward_ok(v) and all(
                forward_ok(u) for u in adj_g[v] if placement[u] >= 0
            ):
                if rec(placed_count + 1):
                    return True
            stats.backtracks += 1
            unplace(v, p)
        return False

    return list(placement) if rec(0) else None


def check_monomorphism(
    dfg: DFG, cgra: CGRA, labels: list[int], placement: list[int], ii: int
) -> list[str]:
    """Independent validator of mono1/mono2/mono3; returns violations."""
    errs: list[str] = []
    seen: dict[tuple[int, int], int] = {}
    for v in dfg.nodes:
        key = (placement[v], labels[v])
        if key in seen:
            errs.append(f"mono1: nodes {seen[key]} and {v} share MRRG vertex {key}")
        seen[key] = v
        if not 0 <= placement[v] < cgra.num_pes:
            errs.append(f"node {v} placed out of range: {placement[v]}")
    adj = dfg.undirected_adjacency()
    for v in dfg.nodes:
        for u in adj[v]:
            if u < v:
                continue
            if not cgra.adjacency[placement[u]][placement[v]]:
                errs.append(
                    f"mono3: edge {{{u},{v}}} maps to non-adjacent PEs "
                    f"{placement[u]},{placement[v]}"
                )
    return errs

"""Monomorphism-based space search (paper §IV-C), bitset engine.

Given a time solution (kernel label per DFG node), find an injective,
label-preserving, edge-preserving embedding of the undirected DFG into the
MRRG. Under the register-file architecture (see core/cgra.py) an MRRG edge
exists between (pe_u, t_u) and (pe_v, t_v) iff pe_u equals-or-neighbours pe_v,
so the search reduces to placing each node on a PE such that

  * at each kernel step, every PE hosts at most one node   (mono1 + mono2)
  * G-adjacent nodes land on closed-adjacent PEs           (mono3)

The search is a VF2/RI-style backtracking specialised to the label structure:
connected expansion order (most-placed-neighbours first), candidate sets from
the intersection of placed neighbours' closed neighbourhoods, forward checking
(every placed node must retain enough free adjacent slots per step for its
unplaced neighbours), and randomised restarts — the classic recipe that gives
VF3-class robustness [29,30] while exploiting the time labels, which partition
the injectivity constraint by step and keep the search shallow.

All PE sets are int bitmasks (bit p = PE p; layout contract in DESIGN.md §5,
masks precomputed in ``CGRA.closed_masks``): candidate intersection is a chain
of ANDs maintained incrementally per node, occupancy per kernel step is one
word, and forward checking is popcount over ``closed & ~occ`` — O(words) per
check instead of O(|set|), which is what lets 20x20 grids (400-bit words)
search millions of candidates per second in pure Python.

Budgets: ``timeout_s`` (wall clock) and/or ``node_budget`` (deterministic
visited-node cap, used by tests and the mapper's deterministic mode).
"""

from __future__ import annotations

import random
import time as _time
from dataclasses import dataclass

from .cgra import CGRA, op_class
from .dfg import DFG
from .time_backends.base import mov_slot_headroom


@dataclass(frozen=True)
class MaterializedRoute:
    """One realised route-through: the original edge, the intermediate PEs,
    and the absolute firing times of the movs that will occupy them."""

    edge: tuple[int, int, int]     # (src, dst, distance) of the routed edge
    path: tuple[int, ...]          # intermediate PEs, src side first
    times: tuple[int, ...]         # absolute mov times, strictly increasing


@dataclass
class SpaceSolution:
    ii: int
    placement: list[int]  # node -> PE index
    # route-throughs materialised by the repair loop; empty = direct embedding
    routes: tuple[MaterializedRoute, ...] = ()


@dataclass
class SpaceStats:
    search_time_s: float = 0.0
    nodes_visited: int = 0
    backtracks: int = 0
    restarts: int = 0
    route_failures: int = 0        # complete placements whose movs didn't fit


class _RouteContext:
    """Per-search route-through state (DESIGN.md §12.1).

    Precomputes, from the time solution, how far apart each adjacent node
    pair may be placed: an edge with absolute-time gap ``g`` (``t_dst -
    t_src + II*distance``) can absorb at most ``g - 1`` movs, each of which
    needs a strictly intermediate firing time, so the pair's placement may
    sit at closed-reach distance ``min(1 + max_hops, g)``. The search relaxes
    its candidate masks accordingly; :meth:`materialize` then realises every
    non-direct edge as a concrete mov chain over free (PE, step) slots — or
    fails, sending the search back to try another placement (the repair
    loop).
    """

    def __init__(
        self,
        dfg: DFG,
        cgra: CGRA,
        labels: list[int],
        t_abs: list[int],
        ii: int,
        max_hops: int,
    ) -> None:
        if t_abs is None:
            raise ValueError("route-through search needs the absolute schedule")
        self.dfg = dfg
        self.cgra = cgra
        self.labels = labels
        self.t_abs = t_abs
        self.ii = ii
        self.max_hops = max_hops
        self.closed = cgra.closed_masks
        self.alu_mask = cgra.capability_masks["alu"]
        # reach tables for every allowed hop level, 1-indexed by hop count
        self.reach = [None] + [
            cgra.reach_masks(h) for h in range(1, max_hops + 2)
        ]
        # per adjacent pair, the allowed placement reach (min over the
        # directed edges between the pair: every edge must be realisable)
        allow: dict[tuple[int, int], int] = {}
        for e in dfg.edges:
            if e.src == e.dst:
                continue
            gap = t_abs[e.dst] - t_abs[e.src] + ii * e.distance
            h = max(1, min(1 + max_hops, gap))
            key = (e.src, e.dst) if e.src < e.dst else (e.dst, e.src)
            allow[key] = min(allow.get(key, h), h)
        self.pair_allow = allow
        # widest allowance per node (conservative forward-checking mask)
        node_allow = [1] * dfg.num_nodes
        for (u, v), h in allow.items():
            node_allow[u] = max(node_allow[u], h)
            node_allow[v] = max(node_allow[v], h)
        self.node_allow = node_allow

    def pair_masks(self, u: int, v: int):
        """Reach-mask table governing where ``u`` may sit relative to ``v``."""
        key = (u, v) if u < v else (v, u)
        return self.reach[self.pair_allow[key]]

    # ------------------------------------------------------- materialization
    def materialize(
        self, placement: list[int], occ: list[int]
    ) -> list[MaterializedRoute] | None:
        """Realise every non-direct edge as a mov chain, or return None.

        Deterministic greedy-with-path-backtracking per edge (edges in DFG
        order, paths in ascending-PE order, times earliest-first); movs claim
        (PE, step) slots against both the placed nodes (``occ``) and each
        other. The shared slot accounting (time_backends.base.
        ``mov_slot_headroom``) fast-fails steps with no capacity left.
        """
        closed, ii = self.closed, self.ii
        num_pes = self.cgra.num_pes
        headroom = mov_slot_headroom(self.labels, ii, num_pes)
        extra = [0] * ii                      # mov occupancy per kernel step
        routes: list[MaterializedRoute] = []
        for e in self.dfg.edges:
            if e.src == e.dst:
                continue
            p_src, p_dst = placement[e.src], placement[e.dst]
            if (closed[p_src] >> p_dst) & 1:
                continue                      # direct edge, no movs
            gap = self.t_abs[e.dst] - self.t_abs[e.src] + ii * e.distance
            route = self._route_edge(e, p_src, p_dst, gap, occ, extra, headroom)
            if route is None:
                return None
            for pe, t in zip(route.path, route.times):
                extra[t % ii] |= 1 << pe
                headroom[t % ii] -= 1
            routes.append(route)
        return routes

    def _route_edge(
        self, e, p_src: int, p_dst: int, gap: int,
        occ: list[int], extra: list[int], headroom: list[int],
    ) -> MaterializedRoute | None:
        ii = self.ii
        t_lo = self.t_abs[e.src]              # movs fire strictly after this
        t_hi = t_lo + gap                     # ... and strictly before this
        max_movs = min(self.max_hops, gap - 1)
        closed, alu = self.closed, self.alu_mask

        def assign_times(path: tuple[int, ...]) -> tuple[int, ...] | None:
            k = len(path)
            ts: list[int] = []
            t_prev = t_lo
            for j, pe in enumerate(path):
                t = t_prev + 1
                limit = t_hi - (k - j)        # leave room for the tail movs
                while t <= limit and ((occ[t % ii] | extra[t % ii]) >> pe) & 1:
                    t += 1
                if t > limit:
                    return None
                ts.append(t)
                t_prev = t
            return tuple(ts)

        budget = 256                          # path attempts per edge
        free_total = sum(h for h in headroom if h > 0)
        for k in range(1, max_movs + 1):
            # a chain of k movs needs k free slots (steps may host several)
            if free_total < k:
                return None
            # DFS over intermediate PEs: step j must stay within closed reach
            # of its predecessor and within (k - j) hops of the destination
            stack: list[tuple[int, tuple[int, ...]]] = [(p_src, ())]
            while stack and budget > 0:
                prev, path = stack.pop()
                j = len(path)
                if j == k:
                    budget -= 1
                    ts = assign_times(path)
                    if ts is not None:
                        return MaterializedRoute(
                            edge=(e.src, e.dst, e.distance),
                            path=path, times=ts,
                        )
                    continue
                cand = closed[prev] & alu & self.reach[k - j][p_dst]
                pes: list[int] = []
                while cand:
                    b = cand & -cand
                    pes.append(b.bit_length() - 1)
                    cand ^= b
                # LIFO stack: push descending so lowest PE is explored first
                for pe in reversed(pes):
                    stack.append((pe, path + (pe,)))
        return None


def find_monomorphism(
    dfg: DFG,
    cgra: CGRA,
    labels: list[int],
    ii: int,
    *,
    timeout_s: float | None = 4.0,
    node_budget: int | None = None,
    restarts: int = 6,
    seed: int = 0,
    stats: SpaceStats | None = None,
    t_abs: list[int] | None = None,
    max_route_hops: int = 0,
) -> SpaceSolution | None:
    """Randomised-restart wrapper around one backtracking dive per seed.

    With ``timeout_s=None`` and a ``node_budget``, the search is fully
    deterministic: identical inputs always visit the identical tree prefix.

    ``max_route_hops > 0`` enables route-through repair (DESIGN.md §12):
    G-adjacent nodes may then land up to ``1 + max_route_hops`` closed-
    adjacency steps apart, and every non-direct edge of a complete placement
    is realised as a chain of ``mov`` nodes over free (PE, step) slots —
    returned in ``SpaceSolution.routes``. This needs the absolute schedule
    (``t_abs``): an edge's hop allowance is bounded by its time gap, and the
    movs' firing times are picked inside it. ``max_route_hops=0`` (default)
    is bit-identical to the historical direct-only search.
    """
    stats = stats if stats is not None else SpaceStats()
    route_ctx = (
        _RouteContext(dfg, cgra, labels, t_abs, ii, max_route_hops)
        if max_route_hops > 0 else None
    )
    start = _time.perf_counter()
    budget = timeout_s if timeout_s is not None else float("inf")
    n_restarts = max(1, restarts)
    # geometric restart schedule: cheap early probes, one deep final dive —
    # weights 1,1,2,4,...  (the last restart gets ~half the total budget)
    weights = [1] + [1 << min(r, 30) for r in range(n_restarts - 1)]
    total_w = sum(weights)
    for r in range(n_restarts):
        remaining = budget - (_time.perf_counter() - start)
        if remaining <= 0:
            break
        stats.restarts += 1
        frac = weights[r] / total_w
        sol = _search_once(
            dfg, cgra, labels, ii,
            deadline=(
                _time.perf_counter() + min(budget * frac, remaining)
                if budget != float("inf") else None
            ),
            node_budget=(
                max(1, int(node_budget * frac)) if node_budget is not None else None
            ),
            rng=random.Random(seed * 7919 + r),
            shuffle=r > 0,   # first dive is deterministic greedy
            stats=stats,
            route_ctx=route_ctx,
        )
        if sol is not None:
            placement, routes = sol
            stats.search_time_s += _time.perf_counter() - start
            return SpaceSolution(ii=ii, placement=placement, routes=routes)
    stats.search_time_s += _time.perf_counter() - start
    return None


def _search_once(
    dfg: DFG,
    cgra: CGRA,
    labels: list[int],
    ii: int,
    *,
    deadline: float | None,
    node_budget: int | None,
    rng: random.Random,
    shuffle: bool,
    stats: SpaceStats,
    route_ctx: _RouteContext | None = None,
) -> tuple[list[int], tuple[MaterializedRoute, ...]] | None:
    n = dfg.num_nodes
    adj_sets = dfg.undirected_adjacency()
    adj = [tuple(sorted(s)) for s in adj_sets]
    num_pes = cgra.num_pes
    closed = cgra.closed_masks
    full = (1 << num_pes) - 1

    if n > num_pes * ii:
        return None
    for v in range(n):
        if not 0 <= labels[v] < ii:
            raise ValueError(f"label out of range for node {v}: {labels[v]}")

    # Capability pruning (DESIGN.md §10): a node may only sit on a PE whose
    # class set covers its op — seed each candidate mask with the op-class
    # mask so incapable placements vanish at the bitset layer instead of
    # being discovered (and backtracked out of) by the search. Homogeneous
    # grids keep the full mask, leaving the search path bit-identical.
    if cgra.heterogeneous:
        cap_masks = cgra.capability_masks
        node_mask = [cap_masks[op_class(dfg.ops[v])] for v in range(n)]
        if not all(node_mask):
            return None            # some op has no capable PE at all
    else:
        node_mask = [full] * n

    degs = [len(adj[v]) for v in range(n)]
    # static value-order rank: interior PEs (largest closed nbhd) first keeps
    # future intersections large; jitter on restarts
    pe_rank = sorted(range(num_pes), key=lambda p: -closed[p].bit_count())
    if shuffle:
        rng.shuffle(pe_rank)
    rank_of = [0] * num_pes
    for i, p in enumerate(pe_rank):
        rank_of[p] = i

    placement = [-1] * n
    occ = [0] * ii                       # occupied-PE mask per kernel step
    # candidate mask per node: op-class mask AND placed neighbours' closed masks
    cand = list(node_mask)
    placed_nbrs = [0] * n
    # unplaced-neighbour demand per (node, step), updated incrementally
    need = [[0] * ii for _ in range(n)]
    for v in range(n):
        for u in adj[v]:
            need[v][labels[u]] += 1

    budget_left = node_budget if node_budget is not None else -1
    check_tick = 0

    # route-through relaxation: a placed node's reachable area for forward
    # checking, and the routes of the accepted placement (repair loop)
    if route_ctx is not None:
        node_reach = [
            route_ctx.reach[route_ctx.node_allow[v]] for v in range(n)
        ]
    found_routes: list[MaterializedRoute] = []

    def complete() -> bool:
        """Accept a full placement; under routing, movs must materialise."""
        if route_ctx is None:
            return True
        routes = route_ctx.materialize(placement, occ)
        if routes is None:
            stats.route_failures += 1
            return False
        found_routes[:] = routes
        return True

    def forward_ok(u: int) -> bool:
        """Placed node u must keep enough free adjacent slots per step."""
        if route_ctx is None:
            cu = closed[placement[u]]
        else:
            cu = node_reach[u][placement[u]]
        nu = need[u]
        for step in range(ii):
            want = nu[step]
            if want and (cu & ~occ[step]).bit_count() < want:
                return False
        return True

    def seed_candidates(v: int) -> list[int]:
        free = node_mask[v] & ~occ[labels[v]]
        return [p for p in pe_rank if (1 << p) & free]

    def cand_list(v: int) -> list[int]:
        m = cand[v] & ~occ[labels[v]]
        out = []
        while m:
            b = m & -m
            out.append(b.bit_length() - 1)
            m ^= b
        out.sort(key=rank_of.__getitem__)   # per-restart jitter lives in pe_rank
        return out

    def place(v: int, p: int) -> list[tuple[int, int]]:
        placement[v] = p
        occ[labels[v]] |= 1 << p
        cp = closed[p]
        undo: list[tuple[int, int]] = []
        lv = labels[v]
        for u in adj[v]:
            need[u][lv] -= 1
            if placement[u] < 0:
                old = cand[u]
                if route_ctx is None:
                    new = old & cp
                else:
                    # per-pair reach: how far u may sit from v is bounded by
                    # the routable hop allowance of their connecting edges
                    new = old & route_ctx.pair_masks(u, v)[p]
                if new != old:
                    undo.append((u, old))
                    cand[u] = new
            placed_nbrs[u] += 1
        return undo

    def unplace(v: int, p: int, undo: list[tuple[int, int]]) -> None:
        lv = labels[v]
        for u in adj[v]:
            need[u][lv] += 1
            placed_nbrs[u] -= 1
        for u, old in undo:
            cand[u] = old
        occ[labels[v]] &= ~(1 << p)
        placement[v] = -1

    def select_var() -> tuple[int, list[int]] | None:
        """Dynamic MRV: among frontier nodes (>=1 placed neighbour), pick the
        one with the fewest candidate PEs; empty frontier seeds a component."""
        best_v, best_c = -1, -1
        for v in range(n):
            if placement[v] >= 0 or not placed_nbrs[v]:
                continue
            c = (cand[v] & ~occ[labels[v]]).bit_count()
            if c == 0:
                return (v, [])          # dead end: fail fast
            if best_v < 0 or (c, -degs[v]) < (best_c, -degs[best_v]):
                best_v, best_c = v, c
                if c == 1:
                    break
        if best_v >= 0:
            return best_v, cand_list(best_v)
        # new component seed: highest-degree unplaced node
        seeds = [v for v in range(n) if placement[v] < 0]
        if not seeds:
            return None
        v = max(seeds, key=lambda u: (degs[u], rng.random() if shuffle else 0))
        return v, seed_candidates(v)

    def rec(placed_count: int) -> int:
        """1 = solved, 0 = subtree exhausted, -1 = budget/deadline abort."""
        nonlocal budget_left, check_tick
        if placed_count == n:
            return 1 if complete() else 0
        check_tick += 1
        if deadline is not None and not check_tick & 0xFF:
            if _time.perf_counter() > deadline:
                return -1
        sel = select_var()
        if sel is None:
            return 1 if complete() else 0
        v, cands = sel
        lv = labels[v]
        for p in cands:
            stats.nodes_visited += 1
            if budget_left >= 0:
                budget_left -= 1
                if budget_left < 0:
                    return -1
            undo = place(v, p)
            # arc check: every unplaced neighbour must retain a candidate
            ok = all(
                cand[u] & ~occ[labels[u]]
                for u in adj[v]
                if placement[u] < 0
            )
            if ok and forward_ok(v):
                ok = all(
                    forward_ok(u) for u in adj[v] if placement[u] >= 0
                )
            if ok:
                r = rec(placed_count + 1)
                if r:
                    if r > 0:
                        return 1
                    unplace(v, p, undo)
                    return -1
            stats.backtracks += 1
            unplace(v, p, undo)
        return 0

    if rec(0) > 0:
        return list(placement), tuple(found_routes)
    return None


def check_routes(
    dfg: DFG, cgra: CGRA, t_abs: list[int], placement: list[int],
    ii: int, routes,
) -> list[str]:
    """Independent validator of route-through provenance (DESIGN.md §12.2).

    ``dfg`` is the *rewritten* DFG and ``routes`` its ``dfg.Route`` records.
    Every structural property (slot exclusivity, chain adjacency, dependency
    ordering) is already covered by ``check_monomorphism``/
    ``check_time_solution`` on the rewritten graph; this re-checks the
    route-specific contract — movs really are movs, chains connect their
    endpoints through closed-adjacent PEs, and firing times sit strictly
    inside the routed edge's time window.
    """
    errs: list[str] = []
    for r in routes:
        chain = (r.src, *r.movs, r.dst)
        for m in r.movs:
            if not 0 <= m < dfg.num_nodes or dfg.ops[m] != "mov":
                errs.append(f"route {r.src}->{r.dst}: node {m} is not a mov")
        for a, b in zip(chain, chain[1:]):
            if not cgra.adjacency[placement[a]][placement[b]]:
                errs.append(
                    f"route {r.src}->{r.dst}: hop {a}->{b} maps to "
                    f"non-adjacent PEs {placement[a]},{placement[b]}"
                )
        lo, hi = t_abs[r.src], t_abs[r.dst] + ii * r.distance
        times = [t_abs[m] for m in r.movs]
        if not all(x < y for x, y in zip([lo, *times], [*times, hi])):
            errs.append(
                f"route {r.src}->{r.dst}: mov times {times} not strictly "
                f"inside ({lo}, {hi})"
            )
    return errs


def check_monomorphism(
    dfg: DFG, cgra: CGRA, labels: list[int], placement: list[int], ii: int
) -> list[str]:
    """Independent validator of mono1/mono2/mono3; returns violations."""
    errs: list[str] = []
    seen: dict[tuple[int, int], int] = {}
    for v in dfg.nodes:
        key = (placement[v], labels[v])
        if key in seen:
            errs.append(f"mono1: nodes {seen[key]} and {v} share MRRG vertex {key}")
        seen[key] = v
        if not 0 <= placement[v] < cgra.num_pes:
            errs.append(f"node {v} placed out of range: {placement[v]}")
            continue
        if cgra.heterogeneous:
            cls = op_class(dfg.ops[v])
            if not cgra.capable(placement[v], cls):
                errs.append(
                    f"capability: node {v} ({dfg.ops[v]}, class {cls!r}) "
                    f"placed on incapable PE {placement[v]}"
                )
    adj = dfg.undirected_adjacency()
    for v in dfg.nodes:
        for u in adj[v]:
            if u < v:
                continue
            if not cgra.adjacency[placement[u]][placement[v]]:
                errs.append(
                    f"mono3: edge {{{u},{v}}} maps to non-adjacent PEs "
                    f"{placement[u]},{placement[v]}"
                )
    return errs

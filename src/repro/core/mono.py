"""Compatibility shim: the monomorphism engine lives in ``space_backends``.

The bitset search (paper §IV-C) moved, unchanged, to
``core/space_backends/exact.py`` when the space phase became pluggable
(DESIGN.md §13); the shared datatypes and route-repair machinery sit in
``core/space_backends/base.py``. This module keeps the historical import
surface — ``from repro.core.mono import find_monomorphism`` and friends —
working for existing callers and tests.
"""

from .space_backends.base import (  # noqa: F401
    MaterializedRoute,
    SpaceSolution,
    SpaceStats,
    _RouteContext,
    check_monomorphism,
    check_routes,
)
from .space_backends.exact import (  # noqa: F401
    _search_once,
    find_monomorphism,
)

__all__ = [
    "MaterializedRoute",
    "SpaceSolution",
    "SpaceStats",
    "check_monomorphism",
    "check_routes",
    "find_monomorphism",
]

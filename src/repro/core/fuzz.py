"""Deterministic random-DFG generation for the differential test harness.

The fuzz suite (``tests/test_differential.py``, DESIGN.md §14.5) needs a
stream of small, *valid* DFGs that exercise every structural feature the
mapper handles — fan-out, reconvergence, loop-carried recurrences, memory
ops, multiplies — without ever producing an input ``DFG.validate`` would
reject. ``hypothesis`` is not available in the container, so generation is
a plain seeded :class:`random.Random` walk: ``random_dfg(seed)`` is a pure
function of its arguments, which makes every fuzz failure replayable from
the seed printed in the test id.

Construction invariants (each is load-bearing for validity):

* Intra-iteration edges only go ``src < dst`` — the distance-0 subgraph is
  a DAG by construction, never by rejection sampling.
* Ops are assigned *after* wiring, from the node's final in-degree, so the
  ``OP_ARITY`` check can't fire: 0 → ``input``/``const``, 1 → unary pool
  (including ``load``/``store`` for memory pressure), 2 → binary pool.
* Loop-carried edges have ``distance ≥ 1`` and respect the in-degree cap,
  so ``rec_ii`` is always finite and arity still holds.
"""

from __future__ import annotations

import random

from .dfg import DFG, Edge

__all__ = ["random_dfg"]

_NULLARY = ("input", "const")
_UNARY = ("neg", "not", "abs", "mov")
_UNARY_MEM = ("load", "store")
_BINARY = ("add", "sub", "and", "or", "xor", "shl", "shr", "min", "max", "cmp")
_BINARY_MUL = ("mul",)


def random_dfg(
    seed: int,
    *,
    min_nodes: int = 4,
    max_nodes: int = 10,
    p_second_operand: float = 0.55,
    p_carried: float = 0.35,
    p_mem: float = 0.25,
    p_mul: float = 0.15,
    name: str | None = None,
) -> DFG:
    """One valid random DFG, a pure function of ``seed`` and the knobs.

    ``p_second_operand`` drives reconvergence (two distinct predecessors),
    ``p_carried`` the chance of each of up to two loop-carried back edges,
    ``p_mem``/``p_mul`` the per-candidate chance of drawing from the memory
    and multiplier pools (exercising capability classes on heterogeneous
    fabrics). The result always passes ``DFG.validate()``.
    """
    rng = random.Random(seed)
    n = rng.randint(min_nodes, max_nodes)
    in_deg = [0] * n
    edges: list[Edge] = []

    # Forward wiring: every non-root node consumes at least one earlier
    # node (keeps the graph connected enough to be interesting), and with
    # probability p_second_operand a second, distinct one.
    for v in range(1, n):
        u = rng.randrange(v)
        edges.append(Edge(u, v))
        in_deg[v] = 1
        if v >= 2 and rng.random() < p_second_operand:
            w = rng.randrange(v)
            if w != u:
                edges.append(Edge(w, v))
                in_deg[v] = 2

    # Loop-carried back edges: distance >= 1 keeps rec_ii finite even when
    # src >= dst closes a cycle; the in-degree cap keeps arity valid.
    for _ in range(2):
        if rng.random() >= p_carried:
            continue
        dst = rng.randrange(n)
        if in_deg[dst] >= 2:
            continue
        src = rng.randrange(n)
        dist = rng.randint(1, 2)
        edges.append(Edge(src, dst, distance=dist))
        in_deg[dst] += 1

    ops = []
    for v in range(n):
        if in_deg[v] == 0:
            ops.append(rng.choice(_NULLARY))
        elif in_deg[v] == 1:
            if rng.random() < p_mem:
                ops.append(rng.choice(_UNARY_MEM))
            else:
                ops.append(rng.choice(_UNARY))
        else:
            if rng.random() < p_mul:
                ops.append(rng.choice(_BINARY_MUL))
            else:
                ops.append(rng.choice(_BINARY))

    dfg = DFG(
        num_nodes=n,
        edges=edges,
        ops=ops,
        name=name or f"fuzz_{seed}",
        imms=[float(rng.randint(-8, 8)) for _ in range(n)],
    )
    dfg.validate()  # raises on a generator bug — invariants above prevent it
    return dfg

"""The 17-benchmark DFG suite (MiBench + Rodinia innermost loops, paper §V).

The authors' exact LLVM-extracted DFGs are not published; what Table III fixes
is each benchmark's node count and (via mII = max(ResII, RecII) and the
published per-size mII values) its recurrence-cycle length RecII. We generate
deterministic DFGs that reproduce those statistics exactly:

  * node count       == Table III "DFG Nodes"
  * RecII            == derived from the largest-grid mII (ResII ~ 1 there)
  * structure        == loop-body shaped: live-in loads fan out into a layered
                        binary-op DAG with store sinks and a single recurrence
                        chain closed by a distance-1 loop-carried edge (phi).

Generated graphs are validated (acyclic intra-iteration part, arity bounds,
RecII match) at construction. Real DFGs can be swapped in via DFG.from_json.
"""

from __future__ import annotations

import random
import zlib

from .dfg import DFG, Edge

# name -> (num_nodes, rec_ii) per Table III (RecII derived from large-grid mII)
TABLE3_BENCHMARKS: dict[str, tuple[int, int]] = {
    "aes": (23, 14),
    "backprop": (34, 5),
    "basicmath": (21, 7),
    "bitcount": (7, 3),
    "cfd": (51, 2),
    "crc32": (24, 8),
    "fft": (20, 7),
    "gsm": (24, 4),
    "heartwall": (35, 3),
    "hotspot3D": (57, 2),
    "lud": (26, 3),
    "nw": (33, 2),
    "particlefilter": (38, 9),
    "sha1": (21, 2),
    "sha2": (25, 7),
    "stringsearch": (28, 3),
    "susan": (21, 2),
}

_BINOPS = ["add", "sub", "mul", "xor", "and", "or", "shl", "shr", "min", "max"]
_UNOPS = ["neg", "not", "abs", "mov"]


def make_benchmark_dfg(name: str, num_nodes: int, rec: int, *, seed: int | None = None) -> DFG:
    """Deterministic loop-body-shaped DFG with the requested statistics."""
    if rec < 1 or num_nodes < rec + 2:
        raise ValueError(f"{name}: need at least rec+2={rec + 2} nodes")
    # crc32, NOT hash(): str hashing is salted per process (PYTHONHASHSEED),
    # which silently made "deterministic" DFGs differ between test runs
    rng = random.Random(seed if seed is not None else zlib.crc32(name.encode()))

    ops: list[str] = []
    edges: list[Edge] = []
    n_inputs = max(2, min(num_nodes // 5, num_nodes - rec - 1))
    for _ in range(n_inputs):
        ops.append("input")
    inputs = list(range(n_inputs))

    # Recurrence chain: c0 (phi) -> c1 -> ... -> c_{rec-1} -(carried)-> c0.
    # Chain nodes only take predecessors from {prev chain node} U inputs so the
    # single carried edge closes exactly one simple cycle of length `rec`.
    chain = list(range(n_inputs, n_inputs + rec))
    ops.append("phi")
    edges.append(Edge(rng.choice(inputs), chain[0]))
    for i, v in enumerate(chain[1:], start=1):
        ops.append(rng.choice(_BINOPS))
        edges.append(Edge(chain[i - 1], v))
        if rng.random() < 0.6:
            edges.append(Edge(rng.choice(inputs), v))
    edges.append(Edge(chain[-1], chain[0], 1))  # loop-carried back-edge

    # Remaining nodes: layered DAG reading from anything created earlier,
    # with a locality bias so the graph looks like real straight-line code.
    first_free = n_inputs + rec
    for v in range(first_free, num_nodes):
        pool = list(range(v))
        # bias towards recent producers
        weights = [1.0 + 3.0 * (p / max(1, v - 1)) for p in pool]
        k = 2 if rng.random() < 0.7 else 1
        preds = _weighted_sample(rng, pool, weights, k)
        if v == num_nodes - 1 or (num_nodes - v <= 2 and rng.random() < 0.7):
            ops.append("store")
            preds = preds[:1]
        else:
            ops.append(rng.choice(_BINOPS) if len(preds) == 2 else rng.choice(_UNOPS))
        for p in preds:
            edges.append(Edge(p, v))

    dfg = DFG(num_nodes=num_nodes, edges=edges, ops=ops, name=name)
    dfg.validate()
    got = dfg.rec_ii()
    if got != rec:
        raise AssertionError(f"{name}: generated RecII {got} != target {rec}")
    return dfg


def _weighted_sample(rng: random.Random, pool: list[int], weights: list[float], k: int) -> list[int]:
    chosen: list[int] = []
    pool = list(pool)
    weights = list(weights)
    for _ in range(min(k, len(pool))):
        total = sum(weights)
        r = rng.random() * total
        acc = 0.0
        for i, w in enumerate(weights):
            acc += w
            if r <= acc:
                chosen.append(pool.pop(i))
                weights.pop(i)
                break
    return chosen


def route_stress_dfg() -> DFG:
    """The route-through demo kernel: load → mul → store with an address chain.

    On bank-split machines (``onehop_split_4x4``: memory ops pinned to column
    0, multiplies to column 3) both the ``load→mul`` and ``mul→store`` edges
    connect PEs that are never adjacent, so the kernel is unmappable under
    direct adjacency at every II — and maps with one route-through mov per
    bank crossing (``max_route_hops >= 1``). Used by the route-through tests,
    the hetero benchmark's route row, and the CI escalation smoke.
    """
    from .dfg import Edge

    return DFG(
        num_nodes=5,
        ops=["input", "load", "const", "mul", "store"],
        edges=[Edge(0, 1), Edge(1, 3), Edge(2, 3), Edge(3, 4)],
        imms=[0.0, 0.0, 3.0, 0.0, 0.0],
        name="route_stress",
    )


def load_suite(names: list[str] | None = None) -> dict[str, DFG]:
    """Table III benchmarks, deterministically generated.

    ``names`` selects a subset (order-preserving, unknown names rejected);
    the default is all 17. The returned DFGs are the batch-compilation
    workload consumed by ``python -m repro.compile --suite`` and
    ``compile_many`` (see ``repro.core.service``).

    Example::

        from repro.core.benchsuite import load_suite

        suite = load_suite(["bitcount", "fft"])
        assert [d.num_nodes for d in suite.values()] == [7, 20]
    """
    if names is not None:
        unknown = [n for n in names if n not in TABLE3_BENCHMARKS]
        if unknown:
            raise ValueError(
                f"unknown benchmark(s) {unknown}; "
                f"choose from {sorted(TABLE3_BENCHMARKS)}"
            )
        return {n: make_benchmark_dfg(n, *TABLE3_BENCHMARKS[n]) for n in names}
    return {
        name: make_benchmark_dfg(name, n, rec)
        for name, (n, rec) in TABLE3_BENCHMARKS.items()
    }

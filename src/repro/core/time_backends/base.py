"""Backend protocol, problem container and registry for the time phase.

A backend enumerates time solutions (absolute schedule ``t_abs`` per node) for
a fixed (DFG, CGRA, II, window) problem, one per call, never repeating a
*kernel-label partition* (``t mod II`` per node): the space phase depends only
on the partition, so a partition that failed to embed once will fail again and
must not be re-proposed. Backends are resumable — a call that runs out of
budget (``deadline`` / ``step_budget``) returns None while keeping its search
state, and the next call continues where it stopped; ``exhausted`` is only set
when the whole space is proven empty.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Protocol


@dataclass(frozen=True)
class TimeProblem:
    """Everything a time backend needs, precomputed once by TimeSolver."""

    num_nodes: int
    edges: tuple[tuple[int, int, int], ...]   # (src, dst, distance)
    adj: tuple[frozenset[int], ...]           # undirected DFG adjacency
    ii: int
    asap: tuple[int, ...]                     # modulo-aware window low
    alap: tuple[int, ...]                     # modulo-aware window high
    cap: int                                  # PEs: capacity per kernel step
    # connectivity degree: D_M on a direct-only search; the relaxed closed
    # ≤(1+route_hops)-step reach degree when the mapper allows route-through
    # (TimeSolver(route_hops=...), DESIGN.md §12.3) — the paper's D_M bound
    # is not a necessary condition once edges may ride mov chains.
    d_m: int
    strict: bool                              # strict connectivity mode
    seed: int = 0
    # per-op-class capacities (DESIGN.md §10): (class name, per-step capacity,
    # member node ids). Only classes whose capacity is strictly below ``cap``
    # appear — the global capacity bound subsumes the rest, and an empty tuple
    # keeps the homogeneous constraint set bit-identical to the paper's.
    class_caps: tuple[tuple[str, int, tuple[int, ...]], ...] = ()
    # triangle exclusion (strict mode) is only sound on triangle-free PE
    # graphs: False for diagonal/one-hop grids and 3-rings of a torus.
    triangle_free: bool = True


class TimeBackend(Protocol):  # pragma: no cover - typing only
    name: str
    exhausted: bool

    def next_solution(
        self, *, deadline: float | None = None, step_budget: int | None = None
    ) -> list[int] | None: ...

    def block(self, labels: list[int]) -> None: ...


class BackendUnavailable(RuntimeError):
    """Requested backend exists but its dependency is not importable."""


@dataclass
class _BackendSpec:
    name: str
    factory: Callable[..., "TimeBackend"]
    available: Callable[[], bool]
    aliases: tuple[str, ...] = ()


_REGISTRY: dict[str, _BackendSpec] = {}
_ALIASES: dict[str, str] = {}


def register_backend(
    name: str,
    factory: Callable[..., "TimeBackend"],
    available: Callable[[], bool],
    *,
    aliases: tuple[str, ...] = (),
) -> None:
    spec = _BackendSpec(name, factory, available, aliases)
    _REGISTRY[name] = spec
    for a in aliases:
        _ALIASES[a] = name


def resolve_backend_name(name: str) -> str:
    """Canonicalise an alias/auto request to a concrete registered backend."""
    if name == "auto":
        for candidate in ("z3", "cp"):
            if candidate in _REGISTRY and _REGISTRY[candidate].available():
                return candidate
        raise BackendUnavailable("no time backend available")
    name = _ALIASES.get(name, name)
    if name not in _REGISTRY:
        raise ValueError(f"unknown time backend {name!r}")
    return name


def available_backends() -> dict[str, bool]:
    """Backend name -> importable right now. For diagnostics and tests."""
    return {n: spec.available() for n, spec in _REGISTRY.items()}


def create_backend(
    name: str, problem: TimeProblem, *, timeout_s: float | None = None
) -> "TimeBackend":
    name = resolve_backend_name(name)
    spec = _REGISTRY[name]
    if not spec.available():
        raise BackendUnavailable(f"time backend {name!r} is not importable")
    return spec.factory(problem, timeout_s=timeout_s)


def residue_window(lo: int, hi: int, k: int, ii: int) -> tuple[int, int] | None:
    """Min/max t in [lo, hi] with t ≡ k (mod ii), or None if the class is
    empty. The congruence rounding here underpins both the CP label domains
    and the re-realization passes — keep it in one place."""
    first = lo + ((k - lo) % ii)
    if first > hi:
        return None
    return first, first + ((hi - first) // ii) * ii


def mov_slot_headroom(labels, ii: int, cap: int) -> list[int]:
    """Free-slot count per kernel step for a realized label assignment.

    The slot/cardinality accounting shared by the route-through materializer
    (core/mono.py) when it re-labels a partition by inserting ``mov`` nodes:
    a mov occupies a real (PE, step) slot, so a step may only absorb one when
    its load is below ``cap`` (the per-step capacity both backends enforce
    for the original nodes). Per-class caps need no extra row here — a mov is
    ``alu`` work placed on a concrete capable free PE, and distinct-PE
    occupancy is a witness that every cardinality constraint still holds.
    """
    load = [0] * ii
    for k in labels:
        load[k % ii] += 1
    return [cap - c for c in load]


def triangles(adj) -> list[tuple[int, int, int]]:
    """All triangles {u<v<w} of an undirected adjacency list of sets.

    Mesh/torus PE graphs are bipartite => triangle-free, so three mutually
    adjacent DFG nodes can never share a kernel step; strict-mode backends
    exclude such partitions up front (DESIGN.md §7).
    """
    out: list[tuple[int, int, int]] = []
    for u in range(len(adj)):
        for v in adj[u]:
            if v <= u:
                continue
            for w in adj[u] & adj[v]:
                if w > v:
                    out.append((u, v, w))
    return out

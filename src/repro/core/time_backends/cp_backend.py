"""Incremental pure-Python CP time backend (DESIGN.md §4.2).

Two-level decomposition of the time phase that enumerates each *kernel-label
partition at most once* — the property the space phase actually needs:

  Level 1 — label search. DFS over per-node kernel labels ``k_v`` (domains =
  the residues ``t mod II`` reachable inside the node's modulo-aware
  [asap, alap] window) with the paper's capacity + connectivity constraints,
  the strict same-step bound, the bipartite-triangle cut, and a necessary
  per-edge realizability bound. The DFS keeps a *persistent trail* (explicit
  decision stack) across ``next_solution()`` calls: enumeration resumes from
  the last decision instead of re-solving from scratch, and blocking a
  returned partition is implicit — the DFS simply never revisits a label
  tuple. External blocking clauses (mapper-level rejects) are honoured via a
  blocked set consulted before a complete assignment is realized.

  Level 2 — fold realization. Given a complete label assignment, the
  dependency constraints ``t_dst >= t_src + 1 - II*distance`` restricted to
  ``t_v ≡ k_v (mod II)`` form a monotone difference-constraint system over
  finite domains; its least fixpoint (Bellman-Ford with congruence rounding)
  either yields the minimal consistent ``t_abs`` or proves the partition
  admits no schedule — no search needed, so realization is polynomial.

The old generator backend enumerated raw ``t_abs`` assignments, re-proposing
the same partition many times (once per fold combination) and carrying no
state between mapper retries; this one is both incremental and partition-deduplicated.
"""

from __future__ import annotations

import random
import time as _time

from .base import TimeProblem, register_backend, residue_window, triangles


class IncrementalCPBackend:
    name = "cp-inc"
    exhausted: bool

    def __init__(self, problem: TimeProblem, *, timeout_s: float | None = None):
        p = self.p = problem
        self.timeout_s = timeout_s
        n, ii = p.num_nodes, p.ii
        self.exhausted = False
        # observational telemetry (DESIGN.md §15): cumulative decision steps
        # and partition realizations across every next_solution() call —
        # read via getattr by TimeSolver, never consulted by the search
        self.steps_total = 0
        self.realizations = 0
        self._blocked: set[tuple[int, ...]] = set()

        # per-(node, residue) min/max absolute time inside the window
        self._tmin: list[dict[int, int]] = []
        self._tmax: list[dict[int, int]] = []
        domains: list[list[int]] = []
        for v in range(n):
            lo, hi = p.asap[v], p.alap[v]
            tmin: dict[int, int] = {}
            tmax: dict[int, int] = {}
            for k in range(ii):
                win = residue_window(lo, hi, k, ii)
                if win is not None:
                    tmin[k], tmax[k] = win
            self._tmin.append(tmin)
            self._tmax.append(tmax)
            domains.append(sorted(tmin, key=lambda k: tmin[k]))

        # static variable order: most-constrained first (smallest label
        # domain, then highest degree) — mirrors the old generator's ordering
        self._order = sorted(
            range(n), key=lambda v: (len(domains[v]), -len(p.adj[v]))
        )
        # value order: earliest-feasible-first on the first solve (greedy,
        # matches ASAP-style packing); seeded shuffle for retry diversity
        if p.seed:
            rng = random.Random(p.seed)
            for dom in domains:
                rng.shuffle(dom)
        self._domains = domains

        self._adj = [sorted(s) for s in p.adj]
        self._edges = list(p.edges)
        self._labels = [-1] * n
        self._count_per_step = [0] * ii
        # per-capability-class occupancy (heterogeneous grids, DESIGN.md §10):
        # class ci keeps its own per-step counter next to the global one
        self._cls_cap = [cap_c for _name, cap_c, _m in p.class_caps]
        self._cls_count = [[0] * ii for _ in p.class_caps]
        self._cls_of: list[tuple[int, ...]] = [()] * n
        for ci, (_name, _cap_c, members) in enumerate(p.class_caps):
            for v in members:
                self._cls_of[v] = self._cls_of[v] + (ci,)
        # triangle cut only matters in strict mode and only for nodes in one
        self._tri_of: list[list[tuple[int, int]]] = [[] for _ in range(n)]
        if p.strict and p.triangle_free:
            for u, v, w in triangles(p.adj):
                self._tri_of[u].append((v, w))
                self._tri_of[v].append((u, w))
                self._tri_of[w].append((u, v))
        # persistent trail: (node, index-into-domain) per decision
        self._trail: list[tuple[int, int]] = []
        self._pending = 0   # value index to resume from at the current depth

    # ------------------------------------------------------------- search
    def block(self, labels: list[int]) -> None:
        self._blocked.add(tuple(labels))

    def next_solution(
        self, *, deadline: float | None = None, step_budget: int | None = None
    ) -> list[int] | None:
        if self.exhausted:
            return None
        if self.timeout_s is not None:
            cap = _time.perf_counter() + self.timeout_s
            deadline = cap if deadline is None else min(deadline, cap)
        p = self.p
        n = p.num_nodes
        # re-entry after a yielded solution: step past it
        if len(self._trail) == n:
            self._backtrack()
            if self.exhausted:
                return None
        steps = 0
        while True:
            depth = len(self._trail)
            if depth == n:
                labels = tuple(self._labels)
                if labels not in self._blocked:
                    self.realizations += 1
                    t_abs = self._realize()
                    if t_abs is not None:
                        return t_abs
                self._backtrack()
                if self.exhausted:
                    return None
                continue
            steps += 1
            self.steps_total += 1
            if step_budget is not None and steps > step_budget:
                return None  # trail kept: resumable
            if deadline is not None and not steps & 0x3F:
                if _time.perf_counter() > deadline:
                    return None
            v = self._order[depth]
            dom = self._domains[v]
            start, self._pending = self._pending, 0
            placed = False
            for idx in range(start, len(dom)):
                k = dom[idx]
                if self._consistent(v, k):
                    self._trail.append((v, idx))
                    self._labels[v] = k
                    self._count_per_step[k] += 1
                    for ci in self._cls_of[v]:
                        self._cls_count[ci][k] += 1
                    placed = True
                    break
            if not placed:
                self._backtrack()
                if self.exhausted:
                    return None

    def _backtrack(self) -> None:
        while self._trail:
            v, idx = self._trail.pop()
            k = self._labels[v]
            self._count_per_step[k] -= 1
            for ci in self._cls_of[v]:
                self._cls_count[ci][k] -= 1
            self._labels[v] = -1
            if idx + 1 < len(self._domains[v]):
                self._pending = idx + 1
                return
        self.exhausted = True

    # -------------------------------------------------------- constraints
    def _consistent(self, v: int, k: int) -> bool:
        p = self.p
        ii = p.ii
        labels = self._labels
        if self._count_per_step[k] >= p.cap:
            return False
        for ci in self._cls_of[v]:
            if self._cls_count[ci][k] >= self._cls_cap[ci]:
                return False
        strict = p.strict
        d_m = p.d_m
        # connectivity of v: assigned neighbours bucketed by step
        per_step: dict[int, int] = {}
        for u in self._adj[v]:
            lu = labels[u]
            if lu >= 0:
                per_step[lu] = per_step.get(lu, 0) + 1
        if per_step.get(k, 0) > (d_m - 1 if strict else d_m):
            return False
        for cnt in per_step.values():
            if cnt > d_m:
                return False
        # v's assignment adds one to each assigned neighbour's step-k count
        for u in self._adj[v]:
            lu = labels[u]
            if lu < 0:
                continue
            cu = 1
            for w in self._adj[u]:
                if w != v and labels[w] == k:
                    cu += 1
            limit = d_m - 1 if strict and lu == k else d_m
            if cu > limit:
                return False
        if strict and self._tri_of[v]:
            for a, b in self._tri_of[v]:
                if labels[a] == k and labels[b] == k:
                    return False
        # per-edge realizability (necessary): some fold pair must satisfy the
        # dependency once both endpoints' residues are fixed
        tmin_v = self._tmin[v][k]
        tmax_v = self._tmax[v][k]
        for src, dst, dist in self._edges:
            if src == v and labels[dst] >= 0:
                if self._tmax[dst][labels[dst]] < tmin_v + 1 - ii * dist:
                    return False
            elif dst == v and labels[src] >= 0:
                if tmax_v < self._tmin[src][labels[src]] + 1 - ii * dist:
                    return False
        return True

    # -------------------------------------------------------- realization
    def _realize(self) -> list[int] | None:
        """Least fixpoint of the difference constraints within residue classes."""
        p = self.p
        ii = p.ii
        labels = self._labels
        lb = [self._tmin[v][labels[v]] for v in range(p.num_nodes)]
        ub = [self._tmax[v][labels[v]] for v in range(p.num_nodes)]
        changed = True
        while changed:
            changed = False
            for src, dst, dist in self._edges:
                bound = lb[src] + 1 - ii * dist
                if lb[dst] < bound:
                    t = bound + ((labels[dst] - bound) % ii)
                    if t > ub[dst]:
                        return None
                    lb[dst] = t
                    changed = True
        return lb


def _available() -> bool:
    return True


register_backend("cp", IncrementalCPBackend, _available, aliases=("python", "cp-inc"))

"""Z3 SMT time backend — the paper-faithful encoding (DESIGN.md §4.1).

Integer variables t_v with the linear decomposition t = II*fold + k (Z3
handles this far better than the `mod` operator on small grids), pseudo-
boolean capacity/connectivity constraints, and label-partition blocking
clauses after each model so the mapper's retry loop converges quickly.
"""

from __future__ import annotations

import time as _time

from .base import TimeProblem, register_backend, triangles

try:  # pragma: no cover - availability probed at import
    import z3  # type: ignore

    HAVE_Z3 = True
except Exception:  # pragma: no cover
    z3 = None
    HAVE_Z3 = False


class Z3Backend:
    name = "z3"
    exhausted: bool

    def __init__(self, problem: TimeProblem, *, timeout_s: float | None = None):
        if not HAVE_Z3:  # pragma: no cover
            raise RuntimeError("z3 backend requested but z3 is not importable")
        p = self.p = problem
        self.timeout_s = timeout_s
        self.exhausted = False
        self._solutions = 0
        # observational telemetry (DESIGN.md §15): one "step" per solver
        # check() call — the closest z3 analogue to the cp backend's
        # decision-step counter; read via getattr by TimeSolver
        self.steps_total = 0
        n, ii = p.num_nodes, p.ii
        self._solver = z3.Solver()
        if timeout_s is not None:
            self._solver.set("timeout", int(timeout_s * 1000))
        self._solver.set("random_seed", p.seed & 0xFFFF)
        self._t = [z3.Int(f"t_{v}") for v in range(n)]
        self._k = [z3.Int(f"k_{v}") for v in range(n)]
        self._f = [z3.Int(f"f_{v}") for v in range(n)]
        s = self._solver
        max_fold = max(p.alap) // ii + 1 if n else 1
        for v in range(n):
            s.add(self._t[v] >= p.asap[v], self._t[v] <= p.alap[v])
            s.add(self._t[v] == ii * self._f[v] + self._k[v])
            s.add(self._k[v] >= 0, self._k[v] < ii)
            s.add(self._f[v] >= 0, self._f[v] <= max_fold)
        # 1. modulo-scheduling constraints
        for src, dst, dist in p.edges:
            s.add(self._t[dst] >= self._t[src] + 1 - ii * dist)
        # 2. capacity constraints — global, then per capability class on
        # heterogeneous grids (only classes with capacity < cap are present)
        for i in range(ii):
            s.add(z3.PbLe([(self._k[v] == i, 1) for v in range(n)], p.cap))
        for _cls, cap_c, members in p.class_caps:
            for i in range(ii):
                s.add(z3.PbLe([(self._k[v] == i, 1) for v in members], cap_c))
        # 3. connectivity constraints
        for v in range(n):
            nbrs = sorted(p.adj[v])
            if not nbrs:
                continue
            for i in range(ii):
                s.add(z3.PbLe([(self._k[u] == i, 1) for u in nbrs], p.d_m))
            if p.strict:
                # same-step neighbours can only use the open neighbourhood
                s.add(
                    z3.PbLe(
                        [(self._k[u] == self._k[v], 1) for u in nbrs], p.d_m - 1
                    )
                )
        if p.strict and p.triangle_free:
            # triangle-free PE graph => no mono-chromatic triangle (DESIGN.md
            # §7); unsound on diagonal/one-hop grids, hence the gate
            for u, v, w in triangles(p.adj):
                s.add(z3.Or(self._k[u] != self._k[v], self._k[u] != self._k[w]))

    def block(self, labels: list[int]) -> None:
        n = self.p.num_nodes
        self._solver.add(
            z3.Or([self._k[v] != labels[v] for v in range(n)])
        )

    def next_solution(
        self, *, deadline: float | None = None, step_budget: int | None = None
    ) -> list[int] | None:
        if self.exhausted:
            return None
        if deadline is not None:
            ms = int(max(0.001, deadline - _time.perf_counter()) * 1000)
            self._solver.set("timeout", ms)
        else:
            # per-call deadlines must not leak into later unbounded calls
            self._solver.set(
                "timeout",
                int(self.timeout_s * 1000) if self.timeout_s is not None else 0,
            )
        self.steps_total += 1
        res = self._solver.check()
        if res == z3.unsat:
            self.exhausted = True
            return None
        if res != z3.sat:  # unknown: budget ran out, resumable
            return None
        model = self._solver.model()
        n = self.p.num_nodes
        t_abs = [model.eval(self._t[v]).as_long() for v in range(n)]
        # Block the *label partition*, not just this t_abs: the space search
        # depends only on labels, so any schedule with the same labels would
        # fail the same way.
        self.block([t % self.p.ii for t in t_abs])
        if self._solutions == 0:
            # Retry solves want *structurally* diverse label partitions (the
            # first solve wants fast default heuristics) — flip to randomised
            # phase selection once retries begin.
            try:
                self._solver.set("phase_selection", 5)
            except z3.Z3Exception:  # pragma: no cover
                pass
        self._solutions += 1
        return t_abs


def _available() -> bool:
    return HAVE_Z3


register_backend("z3", Z3Backend, _available)

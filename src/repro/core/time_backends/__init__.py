"""Time-solver backend subsystem (DESIGN.md §4).

The time phase is a pluggable constraint solver behind a small protocol
(`base.TimeBackend`): the faithful Z3 SMT encoding when `z3-solver` is
installed, and a dependency-free incremental CP solver otherwise. Backends are
looked up through the registry so `TimeSolver` (core/time_smt.py) can report
exactly which engine produced a schedule.
"""

from .base import (
    BackendUnavailable,
    TimeProblem,
    available_backends,
    create_backend,
    resolve_backend_name,
)
from .cp_backend import IncrementalCPBackend
from .z3_backend import HAVE_Z3, Z3Backend

__all__ = [
    "BackendUnavailable",
    "TimeProblem",
    "available_backends",
    "create_backend",
    "resolve_backend_name",
    "IncrementalCPBackend",
    "Z3Backend",
    "HAVE_Z3",
]

"""End-to-end space/time-decoupled CGRA mapper (paper §IV) with a portfolio
search layer (DESIGN.md §6).

Pipeline per II (starting at mII = max(ResII, RecII)):

  1. TIME  — backend search over the KMS window for a schedule satisfying the
     modulo-scheduling + capacity + connectivity constraints (time_smt.py).
  2. SPACE — monomorphism search embedding the labelled DFG into the MRRG
     (mono.py).
  3. If the space search fails (possible: the published constraints are
     necessary but not sufficient, see DESIGN.md §7), the time solution is
     excluded — the incremental backends never re-propose a label partition —
     and step 1 re-runs.

The portfolio layer replaces the old strictly-sequential (II, slack) sweep:
all candidate windows are visited in rounds of geometrically growing budgets
(time-solver steps, space-search nodes, restarts). Round r spends little
enough per window that infeasible low IIs cannot starve feasible higher ones
— the failure mode that made 20x20 grids take tens of seconds — while windows
that merely need a deeper dive get it on the next round, preserving the
smallest-II-first quality preference. Time solutions whose partitions failed
to embed are kept and retried with bigger space budgets/new seeds in later
rounds before fresh partitions are enumerated (time work is never repeated),
and finished mappings land in a small LRU cache keyed on (DFG content hash,
CGRA dims, II) so repeated compilations of the same kernel are free. A
persistent on-disk layer under the LRU (``cache_dir`` / $REPRO_CACHE_DIR,
service/cache.py, DESIGN.md §9) extends that reuse across processes and
restarts, and the service layer (service/batch.py, DESIGN.md §8) fans the
mapper out across worker processes — per batch via ``compile_many`` and per
job via (II, slack) window striping (``window_offset``/``window_stride``).

``deterministic=True`` replaces every wall-clock budget with visited-node /
solver-step budgets: identical inputs then take the identical search path
regardless of machine load (used by tests; see DESIGN.md §6.3).
"""

from __future__ import annotations

import time as _time
from collections import OrderedDict
from dataclasses import dataclass, field

from .. import obs
from .cgra import CGRA
from .dfg import DFG, Route, splice_routes
from .mono import SpaceStats, check_monomorphism, check_routes, find_monomorphism
from .space_backends import (
    SpaceBudget,
    create_space_backend,
    resolve_space_backend_name,
)
from .schedule import min_ii, rec_ii, res_ii
from .time_backends import resolve_backend_name
from .time_smt import TimeSolution, TimeSolver, check_time_solution


@dataclass
class Mapping:
    """A complete space-time mapping of a DFG onto a CGRA.

    When the space engine had to route edges through intermediate PEs
    (``max_route_hops > 0``, DESIGN.md §12), ``dfg`` is the *rewritten* graph
    — original node ids unchanged, one appended ``mov`` node per hop — and
    ``routes`` carries the provenance, so consumers can still report
    placements of the original kernel (``original_nodes`` /
    ``original_placement``). A direct mapping has ``routes == []``.
    """

    dfg: DFG
    cgra: CGRA
    ii: int
    t_abs: list[int]                 # absolute schedule time per node
    placement: list[int]             # PE per node
    routes: list[Route] = field(default_factory=list)  # route-through provenance

    @property
    def labels(self) -> list[int]:
        return [t % self.ii for t in self.t_abs]

    @property
    def folds(self) -> list[int]:
        return [t // self.ii for t in self.t_abs]

    @property
    def schedule_length(self) -> int:
        return max(self.t_abs) + 1

    @property
    def num_stages(self) -> int:
        """Pipeline depth: number of interleaved iterations in steady state."""
        return -(-self.schedule_length // self.ii)

    @property
    def num_route_movs(self) -> int:
        """Route-through movs appended to the DFG (0 for direct mappings)."""
        return sum(len(r.movs) for r in self.routes)

    @property
    def original_nodes(self) -> range:
        """Node ids of the pre-rewrite kernel (splicing appends, never renames)."""
        return range(self.dfg.num_nodes - self.num_route_movs)

    def original_placement(self) -> list[int]:
        """Placement restricted to the original kernel's nodes."""
        return list(self.placement[: len(self.original_nodes)])

    def routes_spec(self) -> tuple[tuple[int, int, int, int], ...]:
        """Compact ``(src, dst, distance, n_movs)`` rows — what both mapping
        caches persist; ``dfg.splice_routes`` rebuilds the rewritten DFG."""
        return tuple(r.spec() for r in self.routes)

    def kernel_table(self) -> list[list[tuple[int, int]]]:
        """Per kernel step: [(pe, node)] executing at that step."""
        rows: list[list[tuple[int, int]]] = [[] for _ in range(self.ii)]
        for v in self.dfg.nodes:
            rows[self.labels[v]].append((self.placement[v], v))
        for r in rows:
            r.sort()
        return rows

    def validate(
        self, *, connectivity: str = "paper", registers: bool = True
    ) -> list[str]:
        """All violated constraints of this mapping (empty = valid).

        ``registers=True`` (the default) additionally runs the simulator's
        register-pressure probe and reports a violation when the steady-state
        live-value count on any PE exceeds that PE's register bound
        (``cgra.registers_at(pe)`` — per-capability-class when the arch
        declares ``registers_by_class``, the scalar ``registers_per_pe``
        otherwise; paper §V-3). The mapper itself validates with
        ``registers=False``: it only *guarantees* the bound when asked via
        ``max_register_pressure``, and a caller probing an already-found
        mapping should see the violation, not a crash.
        """
        errs = check_time_solution(
            self.dfg, self.cgra, TimeSolution(self.ii, self.t_abs),
            connectivity=connectivity,
        )
        errs += check_monomorphism(
            self.dfg, self.cgra, self.labels, self.placement, self.ii
        )
        if self.routes:
            errs += check_routes(
                self.dfg, self.cgra, self.t_abs, self.placement, self.ii,
                self.routes,
            )
        if registers and not errs:
            # simulate imports this module for Mapping: import lazily
            from .simulate import register_pressure_by_pe

            for pe, pressure in sorted(register_pressure_by_pe(self).items()):
                bound = self.cgra.registers_at(pe)
                if pressure > bound:
                    errs.append(
                        f"register pressure {pressure} > {bound} on PE {pe}"
                    )
        return errs

    def pretty(self) -> str:
        lines = [
            f"mapping of {self.dfg.name!r} on {self.cgra.rows}x{self.cgra.cols} "
            f"CGRA: II={self.ii}, schedule length={self.schedule_length}, "
            f"stages={self.num_stages}"
        ]
        for step, row in enumerate(self.kernel_table()):
            cells = " ".join(
                f"PE{pe}<-n{v}(it{self.folds[v]})" for pe, v in row
            )
            lines.append(f"  t%II={step}: {cells}")
        return "\n".join(lines)


@dataclass
class MapperStats:
    time_phase_s: float = 0.0
    space_phase_s: float = 0.0
    validate_s: float = 0.0          # independent re-validation of mappings
    total_s: float = 0.0
    time_solutions_tried: int = 0
    mono_failures: int = 0
    final_ii: int = -1
    m_ii: int = -1
    res_ii: int = -1
    rec_ii: int = -1
    backend: str = ""
    space_backend: str = ""          # concrete engine that placed the result
    rounds: int = 0
    windows_opened: int = 0          # (II, slack) windows that got a solver
    cache_hit: bool = False          # served from the in-process LRU
    disk_cache_hit: bool = False     # served from the persistent disk cache
    space_nodes_visited: int = 0
    # ---- observability counters (DESIGN.md §15.3): per-compile solver and
    # cache-layer telemetry mirrored into JobReport/CompileResult.metrics
    time_steps: int = 0              # cumulative time-backend search steps
    space_restarts: int = 0          # space-engine restarts across all probes
    mem_cache_lookups: int = 0       # in-process LRU consultations (0 or 1)
    mem_cache_hits: int = 0
    disk_cache_lookups: int = 0      # persistent-layer consultations (0 or 1)
    disk_cache_hits: int = 0
    disk_cache_promotions: int = 0   # disk hits promoted into the LRU


@dataclass
class MapResult:
    mapping: Mapping | None
    stats: MapperStats
    reason: str = ""

    @property
    def ok(self) -> bool:
        return self.mapping is not None


# --------------------------------------------------------------- LRU cache

# (dfg_hash, rows, cols, topology, connectivity, max_rp, arch_token,
#  pressure_token, max_route_hops, ii) -> (t_abs, placement, routes_spec)
_MAP_CACHE: OrderedDict[
    tuple, tuple[list[int], list[int], tuple]
] = OrderedDict()
_MAP_CACHE_MAX = 128


@dataclass
class MemoryCacheStats:
    """Hit/miss counters for the in-process LRU mapping cache.

    The symmetric twin of ``service.cache.CacheStats`` (the persistent
    layer has counted since PR 2; the LRU never did) — process-wide, reset
    together with the cache by :func:`clear_mapping_cache`, and surfaced
    per compile through ``CompileResult.metrics`` (DESIGN.md §15.3).
    """

    hits: int = 0
    misses: int = 0
    writes: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float | None:
        n = self.hits + self.misses
        return round(self.hits / n, 6) if n else None

    def as_dict(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "writes": self.writes,
            "evictions": self.evictions,
            "hit_rate": self.hit_rate,
        }


_MEM_CACHE_STATS = MemoryCacheStats()


def memory_cache_stats() -> MemoryCacheStats:
    """The process-wide LRU counters (live object, not a snapshot)."""
    return _MEM_CACHE_STATS


def clear_mapping_cache() -> None:
    global _MEM_CACHE_STATS
    _MAP_CACHE.clear()
    _MEM_CACHE_STATS = MemoryCacheStats()


def _cache_base_key(
    dfg, cgra, connectivity, max_rp, max_route_hops=0, space_backend="exact",
) -> tuple:
    # arch_token is None on the paper's homogeneous grid and a digest of the
    # capability layout otherwise (DESIGN.md §10) — heterogeneous mappings of
    # the same DFG must never alias homogeneous ones in either cache layer.
    # pressure_token keys the *effective per-PE* register bounds the mapper
    # guarantees under max_rp (scalar-only keying served oversubscribing
    # mappings across register sizings), and max_route_hops keys the route-
    # through allowance — a hops=2 mapping carries movs a hops=0 caller must
    # never be served. space_backend is the *resolved* engine name ("auto"
    # never reaches a key): exact and anneal explore different mapping
    # distributions, so entries must not alias across engines (DESIGN.md §13.4).
    return (
        dfg.stable_hash(), cgra.rows, cgra.cols, cgra.topology,
        connectivity, max_rp, cgra.arch_token(),
        cgra.pressure_token(max_rp), max_route_hops, space_backend,
    )


def _rebuild_mapping(
    dfg: DFG, cgra: CGRA, ii: int, t_abs: list[int], placement: list[int],
    routes_spec,
) -> Mapping:
    """Reconstruct a (possibly routed) Mapping from cached arrays.

    Raises ValueError when ``routes_spec`` does not splice onto ``dfg`` —
    disk-cache callers treat that as a corrupt entry.
    """
    if routes_spec:
        routed, routes = splice_routes(dfg, [tuple(s) for s in routes_spec])
        return Mapping(dfg=routed, cgra=cgra, ii=ii, t_abs=t_abs,
                       placement=placement, routes=routes)
    return Mapping(dfg=dfg, cgra=cgra, ii=ii, t_abs=t_abs, placement=placement)


def _cache_put(base_key: tuple, mapping: Mapping) -> None:
    key = (*base_key, mapping.ii)
    _MAP_CACHE[key] = (
        list(mapping.t_abs), list(mapping.placement), mapping.routes_spec()
    )
    _MAP_CACHE.move_to_end(key)
    _MEM_CACHE_STATS.writes += 1
    while len(_MAP_CACHE) > _MAP_CACHE_MAX:
        _MAP_CACHE.popitem(last=False)
        _MEM_CACHE_STATS.evictions += 1


def _cache_get(
    base_key: tuple, lo_ii: int, hi_ii: int
) -> tuple[int, list[int], list[int], tuple] | None:
    for ii in range(lo_ii, hi_ii + 1):
        key = (*base_key, ii)
        hit = _MAP_CACHE.get(key)
        if hit is not None:
            _MAP_CACHE.move_to_end(key)
            _MEM_CACHE_STATS.hits += 1
            return ii, list(hit[0]), list(hit[1]), hit[2]
    _MEM_CACHE_STATS.misses += 1
    return None


def _cache_drop(base_key: tuple, ii: int) -> None:
    _MAP_CACHE.pop((*base_key, ii), None)


def cache_store_mapping(
    dfg: DFG,
    cgra: CGRA,
    mapping: Mapping,
    *,
    connectivity: str = "strict",
    max_register_pressure: int | None = None,
    max_route_hops: int = 0,
    space_backend: str = "auto",
    cache_dir: str | None = None,
) -> None:
    """Insert an externally produced valid mapping into both cache layers.

    The adoption path of the exact certification sweep (DESIGN.md §14.4): a
    ``better-found`` mapping comes from the joint backend, not from the
    portfolio, yet future compiles under the *same* option key must be able
    to serve it. The key mirrors ``_map_dfg_impl``'s lookup exactly —
    ``space_backend`` is resolved the same way, so ``"auto"`` callers hit
    what ``"auto"`` stores. The caller vouches for validity (``Compiler``
    only adopts mappings that passed ``Mapping.validate``); both layers
    re-validate on every read anyway.
    """
    resolved = resolve_space_backend_name(space_backend, cgra)
    base_key = _cache_base_key(
        dfg, cgra, connectivity, max_register_pressure, max_route_hops,
        resolved,
    )
    _cache_put(base_key, mapping)
    from .service.cache import DiskMappingCache, resolve_cache_dir

    root = resolve_cache_dir(cache_dir)
    if root is not None:
        DiskMappingCache(root).put(
            base_key, mapping.ii, mapping.t_abs, mapping.placement,
            routes=mapping.routes_spec(),
        )


def _pressure_offenders(mapping: Mapping, max_rp: int) -> list[int]:
    """PEs whose steady-state pressure exceeds their *effective* bound.

    The effective bound is per-PE — ``min(max_rp, cgra.registers_at(pe))`` —
    so a scalar budget sized for the largest register file (e.g. a 16-entry
    mem-PE file) can no longer wave through a mapping that oversubscribes a
    smaller per-class file on another PE (the PR-4 scalar-fold bug).
    """
    # simulate imports this module for Mapping: import lazily
    from .simulate import register_pressure_by_pe

    cgra = mapping.cgra
    return [
        pe
        for pe, p in sorted(register_pressure_by_pe(mapping).items())
        if p > min(max_rp, cgra.registers_at(pe))
    ]


# ---------------------------------------------------------------- portfolio

@dataclass
class _Window:
    ii: int
    slack: int
    solver: TimeSolver | None = None
    infeasible: bool = False              # precheck ValueError: never opens
    yielded_any: bool = False             # produced >= 1 time solution ever
    pending: list[TimeSolution] = field(default_factory=list)  # space-failed


def ii_slack_windows(lo_ii: int, hi_ii: int, max_slack: int):
    """Canonical (II, slack) window order shared with the joint baseline."""
    for ii in range(lo_ii, hi_ii + 1):
        for slack in range(0, max_slack + 1):
            yield ii, slack


# Default slack depth of the sweep; shared with the racing clamp
# (service/batch.py) so both agree on the window-space size.
DEFAULT_MAX_SLACK = 3


def default_max_ii(m_ii: int) -> int:
    """Default upper II bound of the sweep.

    Single source of truth for the window-space size: used by ``map_dfg``
    and by the service layer's racing clamp (service/batch.py), which must
    agree on how many windows exist.
    """
    return max(m_ii * 4, m_ii + 8)


def map_dfg(dfg: DFG, cgra: CGRA, *, should_stop=None, **kwargs) -> MapResult:
    """Map ``dfg`` onto ``cgra`` — compatibility shim over ``repro.api``.

    The stable entry point is now the :mod:`repro.api` layer (DESIGN.md §11):
    every keyword this function historically accepted is a field of
    :class:`repro.api.CompileOptions`, and this shim simply builds one and
    delegates — ``map_dfg(dfg, cgra, **kw)`` and
    ``Compiler(cgra, resolve_options(**kw)).compile(dfg)`` take the identical
    search path (the parity tests in ``tests/test_api.py`` pin this
    bit-for-bit). Unknown keywords raise ``TypeError`` via the options
    dataclass; statically-invalid combinations raise ``ValueError`` from
    ``CompileOptions.validate``.

    Example — map the paper's running example onto a 2×2 mesh::

        from repro.core import CGRA, map_dfg, running_example

        res = map_dfg(running_example(), CGRA(2, 2))
        assert res.ok and res.mapping.ii == 4          # paper Fig. 2b
        print(res.mapping.pretty())                    # kernel table

    ``should_stop`` (a zero-arg cancellation callable) is not part of the
    serialisable options and stays a direct argument. See
    :func:`_map_dfg_impl` for the full option reference.
    """
    # lazy by design: the api layer imports this module, not vice versa
    from ..api.options import MAPPER_FIELDS, CompileOptions

    unknown = sorted(set(kwargs) - set(MAPPER_FIELDS))
    if unknown:
        # service-only CompileOptions fields (jobs, deadline_s, ...) must
        # fail here exactly like the historical signature's TypeError did —
        # silently ignoring a caller's budget/profile would be worse
        raise TypeError(
            f"map_dfg() got unexpected keyword arguments: {', '.join(unknown)}"
        )
    opts = CompileOptions(**kwargs)
    opts.validate()
    return _map_dfg_impl(
        dfg, cgra, should_stop=should_stop, **opts.mapper_kwargs()
    )


def _map_dfg_impl(
    dfg: DFG,
    cgra: CGRA,
    *,
    max_ii: int | None = None,
    max_slack: int = DEFAULT_MAX_SLACK,
    connectivity: str = "strict",
    backend: str = "auto",
    space_backend: str = "auto",
    time_budget_s: float = 120.0,
    space_timeout_s: float = 0.6,
    space_polish_timeout_s: float = 2.5,
    space_timeout_growth: float = 1.0,
    det_space_cap: int = 400_000,
    max_retries_per_window: int = 8,
    window_timeout_s: float = 10.0,
    max_register_pressure: int | None = None,
    max_route_hops: int = 0,
    deterministic: bool = False,
    use_cache: bool = True,
    cache_dir: str | None = None,
    window_offset: int = 0,
    window_stride: int = 1,
    should_stop=None,
    seed: int = 0,
) -> MapResult:
    """The portfolio-search engine behind ``map_dfg``/``Compiler.compile``.

    It sweeps (II, slack) *windows*
    starting at mII = max(ResII, RecII): for each window the time backend
    proposes a *label partition* (kernel step ``t mod II`` per node, plus a
    *fold* ``t div II``), and the monomorphism engine tries to embed it into
    the MRRG. The portfolio layer interleaves all windows in rounds of growing
    budgets (DESIGN.md §6), so an infeasible low II cannot starve the sweep.

    Example — map the paper's running example onto a 2×2 mesh::

        from repro.core import CGRA, map_dfg, running_example

        res = map_dfg(running_example(), CGRA(2, 2))
        assert res.ok and res.mapping.ii == 4          # paper Fig. 2b
        print(res.mapping.pretty())                    # kernel table
        labels, folds = res.mapping.labels, res.mapping.folds

    Key options:

    * ``max_register_pressure`` enables register-file-aware mapping — the
      restriction the paper's §V-3 leaves to future work: mappings whose
      steady-state live-value count on any PE exceeds that PE's *effective*
      bound — ``min(max_register_pressure, cgra.registers_at(pe))`` — are
      rejected and the search continues, so accepted mappings are guaranteed
      to fit even per-class-sized register files (DESIGN.md §10.7). The
      offending PEs' schedules are re-realized (lifetime-compacted) before
      rejecting.
    * ``max_route_hops`` allows route-through mapping (DESIGN.md §12): when a
      label partition admits no direct embedding, the space engine may place
      G-adjacent ops up to ``1 + max_route_hops`` closed-adjacency steps
      apart and splice ``mov`` nodes (each occupying a real (PE, step) slot)
      onto the connecting path. Escalation is direct-first per partition:
      hops 0, then 1, ... then ``max_route_hops``, so direct embeddings are
      always preferred. 0 (the default) is the paper's direct-only behaviour,
      bit-identical to previous releases.
    * ``space_backend`` picks the placement engine (DESIGN.md §13):
      ``"exact"`` is the paper's complete bitset search, ``"anneal"`` the
      clustered simulated-annealing engine for very large fabrics, and
      ``"auto"`` (default) sizes the choice to the fabric — exact up to
      ``AUTO_EXACT_MAX_PES`` (400) PEs, anneal above, with an exact-engine
      rescue leg on deep portfolio rounds. ``space_timeout_s`` /
      ``space_polish_timeout_s`` / ``space_timeout_growth`` shape the
      per-call wall caps (polish dives get
      ``max(space_polish_timeout_s, space_timeout_s)``; fresh rounds grow as
      ``space_timeout_s * (1 + space_timeout_growth * round)``), and
      ``det_space_cap`` bounds per-round space nodes in deterministic mode.
    * ``deterministic=True`` swaps every wall-clock limit for node/step
      budgets so results are load-independent and reproducible;
      ``time_budget_s`` / ``space_timeout_s`` / ``window_timeout_s`` are then
      ignored, both mapping caches are bypassed (process/disk history must not
      leak into results), and the backend must be (or ``"auto"``-resolve to)
      the cp backend — z3 cannot honor step budgets.
    * ``cache_dir`` layers the persistent on-disk mapping cache (DESIGN.md §9)
      under the in-process LRU: memory first, disk second, solve last; a disk
      hit is promoted to memory and solved mappings are written to both.
      Defaults to ``$REPRO_CACHE_DIR`` when set; ``use_cache=False`` disables
      both layers.
    * ``window_offset`` / ``window_stride`` restrict the sweep to every
      ``stride``-th window of the canonical ``ii_slack_windows`` order — the
      striping used by the service layer to race one search across worker
      processes (DESIGN.md §8). ``should_stop`` (a zero-arg callable) is the
      matching cooperative-cancellation hook: polled at every budget check, a
      True return finishes with the best mapping found so far.
    """
    dfg.validate()
    if window_stride < 1 or not (0 <= window_offset < window_stride):
        raise ValueError(
            f"invalid window striping: offset {window_offset}, stride {window_stride}"
        )
    if max_route_hops < 0:
        raise ValueError(f"max_route_hops must be >= 0, got {max_route_hops}")
    if deterministic:
        # the bounded/reproducible contract only holds on the cp backend (z3
        # cannot honor step budgets), and only when process history cannot
        # leak in through the mapping cache
        if backend == "auto":
            backend = "cp"
        elif backend == "z3":
            raise ValueError(
                "deterministic=True requires the cp backend: z3 solves are "
                "wall-clock-bounded and load-dependent"
            )
        use_cache = False
    # resolve now so a bad backend name raises here instead of being
    # swallowed by the per-window infeasibility handler below
    backend = resolve_backend_name(backend)
    # "auto" is fabric-sized (exact <= AUTO_EXACT_MAX_PES PEs, anneal above,
    # DESIGN.md §13.3); remember the request so auto-on-large can still fall
    # back to the exact engine on deep rounds without surprising a caller
    # who *asked* for anneal
    space_auto = space_backend == "auto"
    space_backend = resolve_space_backend_name(space_backend, cgra)
    space_engine = create_space_backend(space_backend)
    exact_fallback = (
        create_space_backend("exact")
        if space_auto and space_backend != "exact" else None
    )
    stats = MapperStats()
    stats.space_backend = space_backend

    def timed_validate(mapping: Mapping) -> list[str]:
        t0 = _time.perf_counter()
        errs = mapping.validate(connectivity=connectivity, registers=False)
        stats.validate_s += _time.perf_counter() - t0
        return errs

    if cgra.heterogeneous:
        # fail fast on structurally impossible targets (an op class with no
        # capable PE) instead of exhausting the whole (II, slack) sweep
        unsupported = cgra.unsupported_ops(dfg)
        if unsupported:
            return MapResult(
                None, stats,
                reason="infeasible by capability: " + "; ".join(unsupported),
            )
    stats.res_ii = res_ii(dfg, cgra)
    stats.rec_ii = rec_ii(dfg)
    stats.m_ii = min_ii(dfg, cgra)
    start = _time.perf_counter()
    deadline = None if deterministic else start + time_budget_s
    hi = max_ii if max_ii is not None else default_max_ii(stats.m_ii)

    def pressure_reject(mapping: Mapping) -> bool:
        """Cache-served mappings must honor the same per-PE guarantee as
        freshly solved ones — a stale/poisoned entry that oversubscribes any
        PE's effective bound is rejected, never returned."""
        if max_register_pressure is None:
            return False
        return bool(_pressure_offenders(mapping, max_register_pressure))

    base_key = None
    disk = None
    if use_cache:
        base_key = _cache_base_key(
            dfg, cgra, connectivity, max_register_pressure, max_route_hops,
            space_backend,
        )
        stats.mem_cache_lookups += 1
        hit = _cache_get(base_key, stats.m_ii, hi)
        if hit is not None:
            ii, t_abs, placement, routes_spec = hit
            mapping = _rebuild_mapping(dfg, cgra, ii, t_abs, placement,
                                       routes_spec)
            if not timed_validate(mapping) and not pressure_reject(mapping):
                stats.cache_hit = True
                stats.mem_cache_hits += 1
                obs.event("cache.memory.hit", kernel=dfg.name, ii=ii)
                stats.final_ii = ii
                stats.backend = "cache"
                stats.total_s = _time.perf_counter() - start
                return MapResult(mapping, stats)
            _cache_drop(base_key, ii)   # invalid/oversubscribed: never serve
        if not stats.mem_cache_hits:
            obs.event("cache.memory.miss", kernel=dfg.name)
        # memory missed: consult the persistent layer (DESIGN.md §9).
        # Function-local import by design: service/batch.py imports this
        # module at top level, so a module-level import here would close an
        # import cycle — keep any future service imports lazy like this one.
        from .service.cache import DiskMappingCache, resolve_cache_dir

        resolved = resolve_cache_dir(cache_dir)
        if resolved is not None:
            disk = DiskMappingCache(resolved)
            lo = stats.m_ii
            stats.disk_cache_lookups += 1
            while True:
                dhit = disk.get(base_key, lo, hi)
                if dhit is None:
                    obs.event("cache.disk.miss", kernel=dfg.name)
                    break
                ii, t_abs, placement, routes_spec = dhit
                try:
                    mapping = _rebuild_mapping(dfg, cgra, ii, t_abs,
                                               placement, routes_spec)
                    invalid = bool(timed_validate(mapping)) or pressure_reject(
                        mapping
                    )
                except (ValueError, IndexError):
                    invalid = True      # routes don't splice onto this DFG
                if invalid:
                    # schema-valid but semantically invalid: drop it so it
                    # cannot poison every future cold lookup, try higher IIs
                    disk.invalidate(base_key, ii)
                    lo = ii + 1
                    continue
                _cache_put(base_key, mapping)          # promote to memory
                stats.disk_cache_hit = True
                stats.disk_cache_hits += 1
                stats.disk_cache_promotions += 1
                obs.event("cache.disk.hit", kernel=dfg.name, ii=ii)
                obs.event("cache.disk.promote", kernel=dfg.name, ii=ii)
                stats.final_ii = ii
                stats.backend = "disk-cache"
                stats.total_s = _time.perf_counter() - start
                return MapResult(mapping, stats)

    windows = [
        _Window(ii, s)
        for idx, (ii, s) in enumerate(ii_slack_windows(stats.m_ii, hi, max_slack))
        if idx % window_stride == window_offset
    ]
    # deterministic mode has no wall-clock backstop: the per-round node
    # budgets are capped so total work is bounded by rounds x windows x node
    # caps — det_space_cap is a CompileOptions field (one source of truth
    # shared with CI profiles); the cp-step cap stays local
    det_cp_cap = 400_000
    max_rounds = 6 if deterministic else 16
    # anytime polish: extra rounds on lower-II windows; wall-capped when not
    # deterministic, round-capped when it is
    improve_rounds = 3 if deterministic else 8
    solvers: list[TimeSolver] = []
    best: Mapping | None = None
    polish_left = 0
    produced_by = space_backend      # engine that placed the current best

    def out_of_time() -> bool:
        if should_stop is not None and should_stop():
            return True
        return deadline is not None and _time.perf_counter() > deadline

    def finish(mapping: Mapping | None, reason: str = "") -> MapResult:
        stats.time_phase_s += sum(s.stats.solver_time_s for s in solvers)
        stats.time_steps = sum(s.stats.steps for s in solvers)
        stats.total_s = _time.perf_counter() - start
        if mapping is not None:
            errs = timed_validate(mapping)
            if errs:  # defensive: should be impossible
                raise AssertionError(f"mapper produced invalid mapping: {errs}")
            stats.final_ii = mapping.ii
            stats.space_backend = produced_by
            if use_cache:
                _cache_put(base_key, mapping)
                if disk is not None:
                    disk.put(base_key, mapping.ii, mapping.t_abs,
                             mapping.placement, routes=mapping.routes_spec())
        return MapResult(mapping, stats, reason=reason)

    def try_space(
        sol: TimeSolution, w: _Window, rnd: int,
        node_budget: int, restarts: int, salt: int = 0,
    ) -> Mapping | None:
        if not obs.enabled():
            return _try_space(sol, w, rnd, node_budget, restarts, salt)
        n0, r0 = stats.space_nodes_visited, stats.space_restarts
        with obs.span("space.probe", ii=w.ii, slack=w.slack, round=rnd,
                      engine=space_backend) as sp:
            mapping = _try_space(sol, w, rnd, node_budget, restarts, salt)
            sp.set(found=mapping is not None,
                   nodes=stats.space_nodes_visited - n0,
                   restarts=stats.space_restarts - r0)
            return mapping

    def _try_space(
        sol: TimeSolution, w: _Window, rnd: int,
        node_budget: int, restarts: int, salt: int = 0,
    ) -> Mapping | None:
        nonlocal produced_by
        sstats = SpaceStats()
        if deterministic:
            timeout = None
        elif best is not None:      # polish dive: deep per-call wall cap
            timeout = max(space_polish_timeout_s, space_timeout_s)
        else:
            timeout = space_timeout_s * (1 + space_timeout_growth * rnd)
        space = None
        # portfolio per (II, slack, fabric size): the resolved engine leads;
        # when "auto" resolved to anneal (very large fabric), deep rounds add
        # an exact-engine rescue leg — anneal is incomplete, and by round 2 a
        # partition that keeps failing has earned a complete search. Small
        # fabrics never take the extra leg, keeping the historical path
        # bit-identical.
        engines = [space_engine]
        if exact_fallback is not None and rnd >= 2:
            engines.append(exact_fallback)
        # escalation order (DESIGN.md §12.4): direct first, then one more
        # allowed hop per level — route-throughs are only spent when no
        # tighter embedding of this partition is found. hops == 0 takes the
        # exact historical call, keeping the direct path bit-identical; with
        # routing enabled the per-call wall cap is split across the levels so
        # a partition can never spend more than the historical cap in total.
        if timeout is not None and max_route_hops:
            timeout /= max_route_hops + 1
        for engine in engines:
            for hops in range(max_route_hops + 1):
                space = engine.place(
                    dfg, cgra, sol.labels, w.ii,
                    budget=SpaceBudget(
                        timeout_s=timeout,
                        node_budget=node_budget,
                        restarts=restarts,
                    ),
                    seed=seed * 8191 + rnd * 127 + w.slack * 17 + salt,
                    stats=sstats,
                    should_stop=should_stop,
                    **(
                        {} if hops == 0
                        else {"t_abs": sol.t_abs, "max_route_hops": hops}
                    ),
                )
                if space is not None:
                    break
            if space is not None:
                produced_by = engine.name
                break
        stats.space_phase_s += sstats.search_time_s
        stats.space_nodes_visited += sstats.nodes_visited
        stats.space_restarts += sstats.restarts
        if space is None:
            stats.mono_failures += 1
            return None
        if space.routes:
            # splice the materialised movs into the DFG (provenance-keeping
            # rewrite: original node ids unchanged, movs appended in route
            # order — exactly the order the extended arrays are built in)
            routed_dfg, routes = splice_routes(
                dfg,
                [(r.edge[0], r.edge[1], r.edge[2], len(r.path))
                 for r in space.routes],
            )
            mapping = Mapping(
                dfg=routed_dfg, cgra=cgra, ii=w.ii,
                t_abs=list(sol.t_abs) + [t for r in space.routes
                                         for t in r.times],
                placement=list(space.placement) + [pe for r in space.routes
                                                   for pe in r.path],
                routes=routes,
            )
        else:
            mapping = Mapping(
                dfg=dfg, cgra=cgra, ii=w.ii,
                t_abs=sol.t_abs, placement=space.placement,
            )
        if max_register_pressure is not None:
            offenders = _pressure_offenders(mapping, max_register_pressure)
            if offenders and not mapping.routes:
                # paper §V-3 extension: before rejecting, re-realize the
                # *offending PEs'* schedules with compacted lifetimes (same
                # labels => the found placement stays valid) — usually enough
                # to fit their files without disturbing the rest
                off_nodes = [
                    v for v in dfg.nodes if space.placement[v] in set(offenders)
                ]
                compact = w.solver.realize_compact(sol, nodes=off_nodes)
                mapping = Mapping(
                    dfg=dfg, cgra=cgra, ii=w.ii,
                    t_abs=compact.t_abs, placement=space.placement,
                )
                offenders = _pressure_offenders(mapping, max_register_pressure)
                if offenders:
                    # partial push wasn't enough: compact every lifetime
                    compact = w.solver.realize_compact(sol)
                    mapping = Mapping(
                        dfg=dfg, cgra=cgra, ii=w.ii,
                        t_abs=compact.t_abs, placement=space.placement,
                    )
                    offenders = _pressure_offenders(
                        mapping, max_register_pressure
                    )
            if offenders:
                # routed mappings skip re-realization (mov times are pinned
                # inside the original gaps); a different placement of the
                # same partition may still fit — pending, not blocked
                stats.mono_failures += 1
                return None
        return mapping

    polish_deadline: float | None = None

    def record(mapping: Mapping) -> None:
        """Anytime improvement: keep the best (lowest-II) mapping, restrict
        the remaining search to strictly lower IIs, grant polish rounds."""
        nonlocal best, polish_left, windows, deadline, polish_deadline
        if best is None or mapping.ii < best.ii:
            best = mapping
        polish_left = improve_rounds
        windows = [w for w in windows if w.ii < best.ii]
        if not deterministic and polish_deadline is None:
            # polish is bounded: a few multiples of the time-to-first-mapping,
            # never the whole remaining budget
            elapsed = _time.perf_counter() - start
            polish_s = max(5.0, min(20.0, 4 * elapsed, 0.25 * time_budget_s))
            polish_deadline = _time.perf_counter() + polish_s
            deadline = min(deadline, polish_deadline)

    rnd = 0
    while rnd < max_rounds:
        stats.rounds = rnd + 1
        obs.event("mapper.round", round=rnd, windows=len(windows),
                  best_ii=best.ii if best is not None else None)
        if best is not None:
            if polish_left <= 0 or not windows:
                return finish(best)
            polish_left -= 1
        # geometric budgets: cheap sweep first, deep dives on revisit; once an
        # incumbent exists, polish dives go straight to the deep end — the
        # polish deadline (or round cap) is the limiter, not the schedule
        space_cap = det_space_cap if deterministic else 4_000_000
        if best is None:
            space_nodes = min(15_000 * 8**rnd, space_cap)
            restarts = min(4 + 2 * rnd, 12)
        else:
            space_nodes = space_cap if not deterministic else min(15_000 * 8**rnd, space_cap)
            restarts = 10
        cp_steps = min(20_000 * 4**rnd, det_cp_cap if deterministic else 2_000_000)
        # fresh partitions get a cheap screen (embeddable ones usually embed
        # within a few k nodes); the deep budget goes to a rotating window of
        # pending partitions — many cheap probes beat few deep dives
        new_sols = min(4 + 4 * rnd, 4 * max(2, max_retries_per_window))
        screen_nodes = min(space_nodes, 25_000)
        screen_restarts = min(restarts, 4)
        deep_k = 4
        progress = False

        ii_seen_solution: set[int] = set()
        sweep = windows
        if best is not None:
            # polish: the II closest below the incumbent is the most likely
            # to embed — improve stepwise instead of sinking the polish
            # budget into (possibly space-infeasible) minimum-II windows
            sweep = sorted(windows, key=lambda x: (-x.ii, x.slack))
        for w in sweep:
            if w.infeasible:
                continue
            if out_of_time():
                return finish(best, "" if best else "time budget exhausted")
            # Deeper-slack windows mostly re-enumerate equivalent partitions —
            # only open slack s+1 once every shallower window of this II is
            # exhausted without ever yielding a time solution (matches the
            # old sweep's II-escalation behaviour). Under route-through the
            # extra slack is exactly where the mov firing slots come from
            # (each hop consumes one cycle of an edge's time gap), so there
            # the gate ignores yielded_any: deeper slack opens as soon as the
            # shallower windows are exhausted, even when their (unroutable)
            # partitions kept the old gate shut.
            if w.slack > 0:
                shallower = [
                    x for x in windows if x.ii == w.ii and x.slack < w.slack
                ]
                if any(
                    not x.infeasible
                    and ((max_route_hops == 0 and x.yielded_any)
                         or x.solver is None or not x.solver.exhausted)
                    for x in shallower
                ):
                    continue
            if w.solver is None:
                try:
                    w.solver = TimeSolver(
                        dfg, cgra, w.ii,
                        extra_slack=w.slack,
                        connectivity=connectivity,
                        backend=backend,
                        route_hops=max_route_hops,
                        timeout_s=None,
                        # seed 0 keeps the CP value order greedy (earliest-
                        # first), so each window's FIRST partition matches the
                        # classic modulo-scheduling packing; diversity comes
                        # from enumeration, not from scrambling the first shot
                        seed=seed * 31,
                    )
                except ValueError:
                    w.infeasible = True  # window can't hold the critical path
                    continue
                solvers.append(w.solver)
                stats.windows_opened += 1
                stats.backend = w.solver.stats.backend
                obs.event("mapper.window.open", ii=w.ii, slack=w.slack,
                          backend=stats.backend)
            # 1) retry cached partitions with this round's bigger space budget
            if rnd > 0 and w.pending:
                mapping = None
                for i in range(min(deep_k, len(w.pending))):
                    sol = w.pending.pop(0)
                    mapping = try_space(sol, w, rnd, space_nodes, restarts, salt=i)
                    if mapping is not None:
                        record(mapping)
                        break
                    w.pending.append(sol)   # back of the rotation queue
                    if out_of_time():
                        return finish(best, "" if best else "time budget exhausted")
                if not windows:   # record() trimmed everything below best away
                    return finish(best)
                if mapping is not None:
                    break  # windows trimmed: restart the sweep on lower IIs
                progress = True
            # 2) enumerate fresh partitions (bounded per round)
            if w.solver.exhausted or w.ii in ii_seen_solution:
                continue
            found = None
            for _ in range(new_sols):
                if out_of_time():
                    return finish(best, "" if best else "time budget exhausted")
                call_deadline = None
                if not deterministic:
                    call_deadline = min(
                        _time.perf_counter() + window_timeout_s, deadline
                    )
                sol = w.solver.next_solution(
                    deadline=call_deadline, step_budget=cp_steps
                )
                if sol is None:
                    break
                w.yielded_any = True
                ii_seen_solution.add(w.ii)
                stats.time_solutions_tried += 1
                progress = True
                found = try_space(sol, w, rnd, screen_nodes, screen_restarts)
                if found is not None:
                    record(found)
                    break
                w.pending.append(sol)
            if found is not None:
                if not windows:   # record() trimmed everything below best away
                    return finish(best)
                break  # windows trimmed: restart the sweep on lower IIs
        if not progress and all(
            w.infeasible or (w.solver is not None and w.solver.exhausted and not w.pending)
            for w in windows
        ):
            return finish(best, "" if best else f"search space exhausted up to II={hi}")
        rnd += 1
    return finish(best, "" if best else f"no mapping up to II={hi} within budget")

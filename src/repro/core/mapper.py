"""End-to-end space/time-decoupled CGRA mapper (paper §IV).

Pipeline per II (starting at mII = max(ResII, RecII)):

  1. TIME  — SMT search over the KMS window for a schedule satisfying the
     modulo-scheduling + capacity + connectivity constraints (time_smt.py).
  2. SPACE — monomorphism search embedding the labelled DFG into the MRRG
     (mono.py).
  3. If the space search fails (possible: the published constraints are
     necessary but not sufficient, see DESIGN.md §7), the time solution is
     excluded with a blocking clause and step 1 re-runs — a completeness
     backstop the paper does not need in 67/68 cases and we rarely hit.

If no (time, space) pair exists within the II's KMS window, the window is
relaxed (schedule-length slack) and finally II is incremented.
"""

from __future__ import annotations

import time as _time
from dataclasses import dataclass, field

from .cgra import CGRA
from .dfg import DFG
from .mono import SpaceStats, check_monomorphism, find_monomorphism
from .schedule import min_ii, rec_ii, res_ii
from .time_smt import TimeSolution, TimeSolver, check_time_solution


@dataclass
class Mapping:
    """A complete space-time mapping of a DFG onto a CGRA."""

    dfg: DFG
    cgra: CGRA
    ii: int
    t_abs: list[int]                 # absolute schedule time per node
    placement: list[int]             # PE per node

    @property
    def labels(self) -> list[int]:
        return [t % self.ii for t in self.t_abs]

    @property
    def folds(self) -> list[int]:
        return [t // self.ii for t in self.t_abs]

    @property
    def schedule_length(self) -> int:
        return max(self.t_abs) + 1

    @property
    def num_stages(self) -> int:
        """Pipeline depth: number of interleaved iterations in steady state."""
        return -(-self.schedule_length // self.ii)

    def kernel_table(self) -> list[list[tuple[int, int]]]:
        """Per kernel step: [(pe, node)] executing at that step."""
        rows: list[list[tuple[int, int]]] = [[] for _ in range(self.ii)]
        for v in self.dfg.nodes:
            rows[self.labels[v]].append((self.placement[v], v))
        for r in rows:
            r.sort()
        return rows

    def validate(self, *, connectivity: str = "paper") -> list[str]:
        errs = check_time_solution(
            self.dfg, self.cgra, TimeSolution(self.ii, self.t_abs),
            connectivity=connectivity,
        )
        errs += check_monomorphism(
            self.dfg, self.cgra, self.labels, self.placement, self.ii
        )
        return errs

    def pretty(self) -> str:
        lines = [
            f"mapping of {self.dfg.name!r} on {self.cgra.rows}x{self.cgra.cols} "
            f"CGRA: II={self.ii}, schedule length={self.schedule_length}, "
            f"stages={self.num_stages}"
        ]
        for step, row in enumerate(self.kernel_table()):
            cells = " ".join(
                f"PE{pe}<-n{v}(it{self.folds[v]})" for pe, v in row
            )
            lines.append(f"  t%II={step}: {cells}")
        return "\n".join(lines)


@dataclass
class MapperStats:
    time_phase_s: float = 0.0
    space_phase_s: float = 0.0
    total_s: float = 0.0
    time_solutions_tried: int = 0
    mono_failures: int = 0
    final_ii: int = -1
    m_ii: int = -1
    res_ii: int = -1
    rec_ii: int = -1
    backend: str = ""


@dataclass
class MapResult:
    mapping: Mapping | None
    stats: MapperStats
    reason: str = ""

    @property
    def ok(self) -> bool:
        return self.mapping is not None


def map_dfg(
    dfg: DFG,
    cgra: CGRA,
    *,
    max_ii: int | None = None,
    max_slack: int = 3,
    connectivity: str = "strict",
    backend: str = "auto",
    time_budget_s: float = 120.0,
    space_timeout_s: float = 0.6,
    max_retries_per_window: int = 8,
    window_timeout_s: float = 10.0,
    max_register_pressure: int | None = None,
) -> MapResult:
    """Map `dfg` onto `cgra` with the decoupled pipeline.

    ``max_register_pressure`` enables register-file-aware mapping — the
    restriction the paper's §V-3 leaves to future work: mappings whose
    steady-state per-PE live-value count exceeds the budget are rejected and
    the search continues (blocking clause + retry), so accepted mappings are
    guaranteed to fit the register files.
    """
    dfg.validate()
    stats = MapperStats()
    stats.res_ii = res_ii(dfg, cgra)
    stats.rec_ii = rec_ii(dfg)
    stats.m_ii = min_ii(dfg, cgra)
    start = _time.perf_counter()
    deadline = start + time_budget_s
    hi = max_ii if max_ii is not None else max(stats.m_ii * 4, stats.m_ii + 8)

    for ii in range(stats.m_ii, hi + 1):
        for slack in range(0, max_slack + 1):
            if _time.perf_counter() > deadline:
                stats.total_s = _time.perf_counter() - start
                return MapResult(None, stats, reason="time budget exhausted")
            window_had_time_solution = False
            try:
                solver = TimeSolver(
                    dfg, cgra, ii,
                    extra_slack=slack,
                    connectivity=connectivity,
                    backend=backend,
                    timeout_s=max(
                        0.1, min(window_timeout_s, deadline - _time.perf_counter())
                    ),
                    seed=ii * 31 + slack,
                )
            except ValueError:
                continue  # infeasible window (horizon < critical path)
            stats.backend = solver.stats.backend
            retries = 0
            while retries < max_retries_per_window:
                sol = solver.next_solution()
                stats.time_phase_s = max(stats.time_phase_s, 0.0)
                if sol is None:
                    break
                window_had_time_solution = True
                stats.time_solutions_tried += 1
                sstats = SpaceStats()
                space = find_monomorphism(
                    dfg, cgra, sol.labels, ii,
                    timeout_s=space_timeout_s, stats=sstats,
                    restarts=4, seed=retries,
                )
                stats.space_phase_s += sstats.search_time_s
                if space is not None:
                    mapping = Mapping(
                        dfg=dfg, cgra=cgra, ii=ii,
                        t_abs=sol.t_abs, placement=space.placement,
                    )
                    if max_register_pressure is not None:
                        from .simulate import check_register_pressure

                        pressure = check_register_pressure(mapping)
                        if pressure > max_register_pressure:
                            # paper §V-3 extension: reject and keep searching
                            stats.mono_failures += 1
                            retries += 1
                            continue
                    stats.time_phase_s += solver.stats.solver_time_s
                    stats.final_ii = ii
                    stats.total_s = _time.perf_counter() - start
                    errs = mapping.validate()
                    if errs:  # defensive: should be impossible
                        raise AssertionError(
                            f"mapper produced invalid mapping: {errs}"
                        )
                    return MapResult(mapping, stats)
                stats.mono_failures += 1
                retries += 1
                if _time.perf_counter() > deadline:
                    break
            stats.time_phase_s += solver.stats.solver_time_s
            if window_had_time_solution:
                # Time solutions exist but none embedded: wider windows mostly
                # re-enumerate equivalent partitions — escalate II instead
                # (matches the paper's II-inflation behaviour on hard cases).
                break
    stats.total_s = _time.perf_counter() - start
    return MapResult(None, stats, reason=f"no mapping up to II={hi}")

"""Beyond-paper application: space/time-decoupled placement on TPU meshes.

A TPU slice is a 2-D/3-D torus of chips connected by near-neighbour ICI links
— structurally the same substrate as a CGRA mesh of PEs. The paper's insight
(schedule in time under capacity/connectivity constraints, then place with a
monomorphism so every dependency is a single hop) therefore transfers directly
to the placement problems a distributed LM framework faces:

  * pipeline-parallel stage placement: stages = DFG nodes, activations flowing
    stage->stage = edges, II = the pipeline's steady-state repeat interval.
    A monomorphic placement means all stage boundaries are single-hop ICI
    transfers, lowerable to `collective_permute` (cheap, contention-free)
    instead of arbitrary point-to-point routes.
  * MoE expert-group placement: expert groups = nodes, heavy token routes
    (profiled or uniform) = edges; neighbour placement keeps the all-to-all's
    heaviest pairs on single hops.

The device "CGRA" uses torus topology (ICI wraps around); everything else —
the SMT time solver, the monomorphism search — is reused unchanged.
"""

from __future__ import annotations

from dataclasses import dataclass

from .cgra import CGRA
from .dfg import DFG, Edge
from .mapper import MapResult, Mapping, map_dfg


@dataclass(frozen=True)
class StageGraph:
    """A model partitioned into communicating stages (pipeline or experts)."""

    num_stages: int
    # (src, dst, carried): carried=True marks the steady-state wrap edge
    # (microbatch i+1 enters stage 0 while microbatch i is downstream).
    flows: tuple[tuple[int, int, bool], ...]
    name: str = "stages"

    def to_dfg(self) -> DFG:
        # carried (wrap) edges span the whole pipeline: distance = depth
        edges = [
            Edge(s, d, self.num_stages if carried else 0)
            for s, d, carried in self.flows
        ]
        ops = []
        for v in range(self.num_stages):
            indeg = sum(1 for _, d, _ in self.flows if d == v)
            ops.append({0: "input", 1: "mov", 2: "phi"}.get(indeg, "add"))
        return DFG(num_nodes=self.num_stages, edges=edges, ops=ops, name=self.name)


def linear_pipeline(num_stages: int, *, wrap: bool = True, name: str = "pipeline") -> StageGraph:
    """Classic 1F1B-style pipeline: stage i feeds stage i+1; the wrap edge
    models microbatch m+num_stages re-entering stage 0 while m drains — its
    dependence distance equals the pipeline depth, so RecII stays 1 and the
    mapper seeks a *fully spatial* solution (II=1: all stages concurrently on
    distinct, adjacent devices — the steady-state pipeline)."""
    flows = [(i, i + 1, False) for i in range(num_stages - 1)]
    if wrap and num_stages > 1:
        flows.append((num_stages - 1, 0, True))
    return StageGraph(num_stages, tuple(flows), name=name)


def mesh_as_cgra(shape: tuple[int, int], *, registers_per_pe: int = 32) -> CGRA:
    """Model a TPU chip/host grid as a torus 'CGRA' (ICI links wrap)."""
    return CGRA(rows=shape[0], cols=shape[1], topology="torus",
                registers_per_pe=registers_per_pe)


@dataclass
class DevicePlacement:
    """stage -> device coordinate on the mesh, plus the schedule phase."""

    mesh_shape: tuple[int, int]
    stage_to_device: list[tuple[int, int]]
    stage_phase: list[int]
    ii: int
    mapping: Mapping

    def single_hop_fraction(self) -> float:
        """Fraction of stage flows that are single-hop (or same-device)."""
        cgra = self.mapping.cgra
        ok = 0
        edges = self.mapping.dfg.edges
        for e in edges:
            pu = self.mapping.placement[e.src]
            pv = self.mapping.placement[e.dst]
            if cgra.adjacency[pu][pv]:
                ok += 1
        return ok / max(1, len(edges))

    def permute_pairs(self) -> list[tuple[int, int]]:
        """(src_device, dst_device) pairs for a collective_permute lowering."""
        out = []
        for e in self.mapping.dfg.edges:
            pu = self.mapping.placement[e.src]
            pv = self.mapping.placement[e.dst]
            if pu != pv:
                out.append((pu, pv))
        return sorted(set(out))


def place_stages(
    stages: StageGraph,
    mesh_shape: tuple[int, int],
    *,
    time_budget_s: float = 30.0,
) -> DevicePlacement | None:
    """Place a stage graph onto a device mesh with the paper's mapper."""
    cgra = mesh_as_cgra(mesh_shape)
    dfg = stages.to_dfg()
    res: MapResult = map_dfg(dfg, cgra, time_budget_s=time_budget_s)
    if not res.ok:
        return None
    m = res.mapping
    return DevicePlacement(
        mesh_shape=mesh_shape,
        stage_to_device=[cgra.pe_coords(p) for p in m.placement],
        stage_phase=list(m.labels),
        ii=m.ii,
        mapping=m,
    )


def expert_groups_graph(
    num_groups: int,
    heavy_routes: Sequence[tuple[int, int]] = (),
    name: str = "experts",
) -> StageGraph:
    """MoE expert-group placement problem: groups exchanging the heaviest
    token traffic (profiled or assumed) become edges; a monomorphic placement
    puts each hot pair on a single ICI hop, so the all-to-all's dominant
    flows avoid multi-hop congestion. Groups with no profiled affinity get a
    ring backbone (every group still adjacent to a neighbour for the
    fallback uniform traffic)."""
    flows = [(i, (i + 1) % num_groups, (i + 1) == num_groups)
             for i in range(num_groups)]
    # canonicalise heavy routes low->high so the intra-iteration graph stays
    # acyclic (placement only needs adjacency, which is undirected anyway)
    flows += [(min(a, b), max(a, b), False) for a, b in heavy_routes]
    # dedupe
    seen, uniq = set(), []
    for s, d, c in flows:
        if (s, d) not in seen and s != d:
            seen.add((s, d))
            uniq.append((s, d, c))
    return StageGraph(num_groups, tuple(uniq), name=name)


def device_order_for_pipeline(num_stages: int, mesh_shape: tuple[int, int]) -> list[int]:
    """Flat device ordering for `jax.make_mesh`-style pipeline axes such that
    consecutive pipeline stages sit on ICI-adjacent devices.

    Falls back to a snake order (always single-hop on a torus row-major grid)
    if the mapper declines, so callers can rely on a result.
    """
    placement = place_stages(linear_pipeline(num_stages), mesh_shape)
    if placement is not None and placement.single_hop_fraction() == 1.0:
        cgra = mesh_as_cgra(mesh_shape)
        return [cgra.pe_index(r, c) for r, c in placement.stage_to_device]
    # snake fallback
    rows, cols = mesh_shape
    order: list[int] = []
    for r in range(rows):
        cs = range(cols) if r % 2 == 0 else range(cols - 1, -1, -1)
        order.extend(r * cols + c for c in cs)
    return order[:num_stages]

"""Joint space-time mapping baseline (SAT-MapIt-style, paper ref [22]).

The comparison target for the paper's Table III / Fig. 5: a SAT/SMT encoding
over the *full* mapping space — boolean variables x[v, pe, t] over the KMS
window × PE grid, with

  * exactly-one position per node,
  * at-most-one node per (PE, kernel step)  [resource constraint],
  * support clauses per dependency edge: if u sits at (pu, tu) then v must sit
    at some time-compatible slot on a PE in pu's closed neighbourhood
    (register-file routing, same machine model as the decoupled mapper).

This is the standard "support" CNF encoding; it is faithful to the *joint*
search structure whose cost grows with |PEs| x II — exactly the scalability
wall the paper's decoupling removes. The II search loop (start at mII, widen
the window, then increment II) matches the decoupled mapper's, so compile-time
comparisons are apples-to-apples.
"""

from __future__ import annotations

import time as _time
from dataclasses import dataclass

from .cgra import CGRA
from .dfg import DFG
from .mapper import Mapping, MapResult, MapperStats, ii_slack_windows
from .schedule import asap_schedule, min_ii, modulo_windows, rec_ii, res_ii
from .time_backends.z3_backend import HAVE_Z3, z3


def map_dfg_joint(
    dfg: DFG,
    cgra: CGRA,
    *,
    max_ii: int | None = None,
    max_slack: int = 3,
    time_budget_s: float = 60.0,
) -> MapResult:
    """Joint mapper entry point; mirrors mapper.map_dfg's interface."""
    if not HAVE_Z3:
        raise RuntimeError("joint baseline requires z3")
    dfg.validate()
    stats = MapperStats(backend="z3-joint")
    if cgra.heterogeneous:
        # the joint encoding has no capability/port constraints; reject the
        # target gracefully instead of producing an invalid mapping
        return MapResult(
            None, stats,
            reason="joint baseline does not support heterogeneous targets "
                   "(capability classes / memory ports)",
        )
    stats.res_ii = res_ii(dfg, cgra)
    stats.rec_ii = rec_ii(dfg)
    stats.m_ii = min_ii(dfg, cgra)
    start = _time.perf_counter()
    deadline = start + time_budget_s
    hi = max_ii if max_ii is not None else max(stats.m_ii * 4, stats.m_ii + 8)

    # Same canonical window order as the decoupled mapper's portfolio, so
    # compile-time comparisons stay apples-to-apples; the joint encoding is
    # too monolithic to interleave budgets, which is exactly its problem.
    for ii, slack in ii_slack_windows(stats.m_ii, hi, max_slack):
        remaining = deadline - _time.perf_counter()
        if remaining <= 0:
            stats.total_s = _time.perf_counter() - start
            return MapResult(None, stats, reason="time budget exhausted")
        mapping = _solve_joint(dfg, cgra, ii, slack, remaining, stats)
        if mapping is not None:
            stats.final_ii = ii
            stats.total_s = _time.perf_counter() - start
            # registers=False: like the decoupled mapper, the joint encoding
            # does not constrain register pressure, only space-time validity
            errs = mapping.validate(registers=False)
            if errs:
                raise AssertionError(f"joint mapper invalid mapping: {errs}")
            return MapResult(mapping, stats)
    stats.total_s = _time.perf_counter() - start
    return MapResult(None, stats, reason=f"no mapping up to II={hi}")


def _solve_joint(
    dfg: DFG,
    cgra: CGRA,
    ii: int,
    slack: int,
    timeout_s: float,
    stats: MapperStats,
) -> Mapping | None:
    horizon = max(asap_schedule(dfg), default=0) + slack
    windows = modulo_windows(dfg, ii, horizon)
    if windows is None:
        return None
    asap, alap = windows
    d_m = cgra.connectivity_degree
    if any(len(n) > d_m * ii - 1 for n in dfg.undirected_adjacency()):
        return None  # analytic degree bound (same precheck as TimeSolver)
    num_pes = cgra.num_pes
    nbrs_closed = [(p, *cgra.neighbors[p]) for p in range(num_pes)]

    s = z3.Solver()
    s.set("timeout", max(1, int(timeout_s * 1000)))

    # x[v][(pe, t)] booleans over each node's KMS window x the PE grid
    x: list[dict[tuple[int, int], "z3.BoolRef"]] = []
    for v in dfg.nodes:
        xv = {
            (pe, t): z3.Bool(f"x_{v}_{pe}_{t}")
            for t in range(asap[v], alap[v] + 1)
            for pe in range(num_pes)
        }
        x.append(xv)
        s.add(z3.PbEq([(b, 1) for b in xv.values()], 1))  # exactly one

    # resource: at most one node per (pe, kernel step)
    by_pe_step: dict[tuple[int, int], list] = {}
    for v in dfg.nodes:
        for (pe, t), b in x[v].items():
            by_pe_step.setdefault((pe, t % ii), []).append(b)
    for lits in by_pe_step.values():
        if len(lits) > 1:
            s.add(z3.PbLe([(b, 1) for b in lits], 1))

    # dependencies: support clauses (u at (pu,tu)) -> v on a compatible slot
    for e in dfg.edges:
        tu_range = range(asap[e.src], alap[e.src] + 1)
        tv_range = range(asap[e.dst], alap[e.dst] + 1)
        for tu in tu_range:
            compat_ts = [tv for tv in tv_range if tv >= tu + 1 - ii * e.distance]
            for pu in range(num_pes):
                support = [
                    x[e.dst][(pv, tv)] for tv in compat_ts for pv in nbrs_closed[pu]
                ]
                s.add(z3.Implies(x[e.src][(pu, tu)], z3.Or(support)))

    t0 = _time.perf_counter()
    res = s.check()
    stats.time_phase_s += _time.perf_counter() - t0  # joint: all time is "search"
    if res != z3.sat:
        return None
    model = s.model()
    t_abs = [-1] * dfg.num_nodes
    placement = [-1] * dfg.num_nodes
    for v in dfg.nodes:
        for (pe, t), b in x[v].items():
            if z3.is_true(model.eval(b)):
                t_abs[v] = t
                placement[v] = pe
                break
    assert all(t >= 0 for t in t_abs)
    return Mapping(dfg=dfg, cgra=cgra, ii=ii, t_abs=t_abs, placement=placement)

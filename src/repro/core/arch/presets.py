"""Named architecture presets (DESIGN.md §10).

Each preset is a factory returning a fresh :class:`~repro.core.arch.ArchSpec`
mirroring a machine from the paper or its companion line of work:

* ``paper_homogeneous_4x4`` — the paper's §V evaluation grid: 4×4 mesh,
  every PE executes every op.
* ``satmapit_edge_mem_4x4`` — SAT-MapIt-style (arXiv 2512.02875): only the
  twelve border PEs of a 4×4 mesh reach memory (4 load/store ports), interior
  PEs are pure compute; every PE keeps the full ALU + multiplier. Memory PEs
  carry a double-size register file (``registers_by_class``) — the
  buffer-sizing asymmetry such machines use for load/store latency hiding.
* ``mul_sparse_8x8`` — an 8×8 mesh where only the main-diagonal PEs carry a
  multiplier/divider (the classic area-saving layout); memory everywhere.
* ``diagonal_20x20`` — a large king-move (diagonal) grid, homogeneous
  capabilities: exercises the non-bipartite-topology path at scale.
* ``onehop_split_4x4`` — a one-hop grid whose memory and multiplier banks
  sit on opposite columns, 3 apart: the route-through demo machine
  (``--max-route-hops``, DESIGN.md §12).
* ``mesh_50x50`` / ``mesh_100x100`` — large homogeneous meshes (2.5k and
  10k PEs): the scale regime the annealing space backend opens up
  (DESIGN.md §13; auto-selection sends them to ``anneal``).

``list_presets()``/``get_preset()`` are the registry surface the CLIs use.
"""

from __future__ import annotations

from typing import Callable

from .spec import ArchSpec

__all__ = ["PRESETS", "get_preset", "list_presets"]


def _border_mem(rows: int, cols: int, classes_border: tuple[str, ...],
                classes_interior: tuple[str, ...]) -> tuple[tuple[str, ...], ...]:
    out = []
    for r in range(rows):
        for c in range(cols):
            edge = r in (0, rows - 1) or c in (0, cols - 1)
            out.append(classes_border if edge else classes_interior)
    return tuple(out)


def paper_homogeneous_4x4() -> ArchSpec:
    return ArchSpec(name="paper_homogeneous_4x4", rows=4, cols=4)


def satmapit_edge_mem_4x4() -> ArchSpec:
    return ArchSpec(
        name="satmapit_edge_mem_4x4",
        rows=4,
        cols=4,
        pe_classes=_border_mem(4, 4, ("alu", "mem", "mul"), ("alu", "mul")),
        mem_ports=4,
        registers_by_class={"mem": 16},
    )


def mul_sparse_8x8() -> ArchSpec:
    classes = tuple(
        ("alu", "mem", "mul") if r == c else ("alu", "mem")
        for r in range(8)
        for c in range(8)
    )
    return ArchSpec(name="mul_sparse_8x8", rows=8, cols=8, pe_classes=classes)


def diagonal_20x20() -> ArchSpec:
    return ArchSpec(name="diagonal_20x20", rows=20, cols=20, topology="diagonal")


def onehop_split_4x4() -> ArchSpec:
    """One-hop 4×4 with memory and multiplier banks on opposite columns.

    Column 0 PEs are the only memory ports, column 3 PEs the only
    multipliers, the middle columns plain ALUs. Even with the one-hop
    links (distance-2 row/column hops) the two banks sit 3 apart, so *any*
    load→mul or mul→store dependency is unmappable under direct adjacency —
    the machine shape that needs route-through mapping
    (``--max-route-hops``): one mov on a middle-column PE bridges the banks.
    """
    classes = tuple(
        ("alu", "mem") if c == 0 else ("alu", "mul") if c == 3 else ("alu",)
        for _r in range(4)
        for c in range(4)
    )
    return ArchSpec(
        name="onehop_split_4x4", rows=4, cols=4, topology="one-hop",
        pe_classes=classes,
    )


def mesh_50x50() -> ArchSpec:
    return ArchSpec(name="mesh_50x50", rows=50, cols=50)


def mesh_100x100() -> ArchSpec:
    return ArchSpec(name="mesh_100x100", rows=100, cols=100)


PRESETS: dict[str, Callable[[], ArchSpec]] = {
    "paper_homogeneous_4x4": paper_homogeneous_4x4,
    "satmapit_edge_mem_4x4": satmapit_edge_mem_4x4,
    "mul_sparse_8x8": mul_sparse_8x8,
    "diagonal_20x20": diagonal_20x20,
    "onehop_split_4x4": onehop_split_4x4,
    "mesh_50x50": mesh_50x50,
    "mesh_100x100": mesh_100x100,
}


def get_preset(name: str) -> ArchSpec:
    """Build a preset by name; the spec is validated before it is returned."""
    try:
        factory = PRESETS[name]
    except KeyError:
        raise ValueError(
            f"unknown preset {name!r} (choose from {', '.join(sorted(PRESETS))})"
        ) from None
    spec = factory()
    spec.validate()
    return spec


def list_presets() -> list[str]:
    return sorted(PRESETS)

"""Declarative CGRA architecture descriptions (DESIGN.md §10).

An :class:`ArchSpec` is the serialisable source of truth for a target
machine: grid dimensions, topology family, per-PE capability classes,
memory-port count and register-file size. It compiles to the runtime
:class:`~repro.core.cgra.CGRA` model via :meth:`ArchSpec.cgra`, validates
against a workload via :meth:`ArchSpec.validate_for`, and hashes stably via
:meth:`ArchSpec.spec_hash` (the digest the mapping caches fold into their
keys, alongside ``CGRA.arch_token``).

The JSON format is deliberately small::

    {
      "name": "satmapit_edge_mem_4x4",
      "rows": 4, "cols": 4,
      "topology": "mesh",
      "pe_classes": [["alu", "mem"], ["alu"], ...],   // row-major, or null
      "mem_ports": 4,                                  // or null
      "registers_per_pe": 8,
      "registers_by_class": {"mem": 16}                // or null (scalar only)
    }

``pe_classes: null`` means homogeneous (every PE, every class). Named
presets live in :mod:`repro.core.arch.presets`; :func:`resolve_arch` turns a
CLI argument (preset name or ``.json`` path) into a spec.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, replace
from functools import cached_property

from ..cgra import CGRA, op_class

__all__ = ["ArchSpec", "op_class", "resolve_arch"]


@dataclass(frozen=True)
class ArchSpec:
    """Declarative description of a (possibly heterogeneous) CGRA target.

    Example — a 2×2 grid where only the left column touches memory::

        spec = ArchSpec(
            name="tiny", rows=2, cols=2,
            pe_classes=(("alu", "mem", "mul"), ("alu",),
                        ("alu", "mem", "mul"), ("alu",)),
            mem_ports=1,
        )
        spec.validate()
        cgra = spec.cgra()
        assert cgra.capable(0, "mem") and not cgra.capable(1, "mem")
        again = ArchSpec.from_json(spec.to_json())
        assert again.spec_hash() == spec.spec_hash()
    """

    name: str
    rows: int
    cols: int
    topology: str = "mesh"
    # per-PE capability classes, row-major; None = every PE every class
    pe_classes: tuple[tuple[str, ...], ...] | None = None
    # max memory ops per cycle grid-wide; None = one port per mem-capable PE
    mem_ports: int | None = None
    registers_per_pe: int = 8
    # per-capability-class register-file override (e.g. {"mem": 16} sizes
    # memory-PE buffers differently, SAT-MapIt-style); a dict or a
    # ((class, count), ...) tuple, normalised to the sorted tuple form.
    # None = every PE gets the scalar registers_per_pe
    registers_by_class: tuple[tuple[str, int], ...] | None = None

    def __post_init__(self) -> None:
        if isinstance(self.registers_by_class, dict):
            object.__setattr__(
                self, "registers_by_class",
                tuple(sorted(self.registers_by_class.items())),
            )
        elif self.registers_by_class is not None:
            object.__setattr__(
                self, "registers_by_class",
                tuple(sorted(tuple(p) for p in self.registers_by_class)),
            )

    # ------------------------------------------------------------- validation
    def validate(self) -> None:
        """Raise ValueError on a structurally invalid spec.

        The grid/topology/class/port invariants are owned by
        ``CGRA.__post_init__`` — constructing the CGRA *is* the check, so the
        two layers cannot drift; this only adds the spec-level extras and a
        name-prefixed message for file-loaded specs.
        """
        if self.registers_per_pe < 1:
            raise ValueError(f"{self.name}: registers_per_pe must be >= 1")
        try:
            self._cgra  # noqa: B018 — cached construction runs the checks
        except ValueError as exc:
            raise ValueError(f"{self.name}: {exc}") from None

    def validate_for(self, dfg) -> list[str]:
        """Workload-level feasibility: every DFG op class needs ≥1 capable PE
        (and a non-zero port budget for memory ops). Returns problems, not
        raises, so batch frontends can report per-job."""
        return self.cgra().unsupported_ops(dfg)

    # ------------------------------------------------------------ compilation
    @cached_property
    def _cgra(self) -> CGRA:
        return CGRA(
            rows=self.rows,
            cols=self.cols,
            topology=self.topology,
            registers_per_pe=self.registers_per_pe,
            pe_classes=self.pe_classes,
            mem_ports=self.mem_ports,
            registers_by_class=self.registers_by_class,
        )

    def cgra(self) -> CGRA:
        """The runtime machine model this spec describes."""
        self.validate()
        return self._cgra

    def spec_hash(self) -> str:
        """Stable content digest over everything mapping-relevant.

        ``name`` is excluded — renaming a preset must not orphan cached
        mappings. The same digest feeds cache keys and BENCH artifacts.
        """
        payload = json.dumps(
            {
                "rows": self.rows,
                "cols": self.cols,
                "topology": self.topology,
                "pe_classes": (
                    None if self.pe_classes is None
                    else [sorted(c) for c in self.pe_classes]
                ),
                "mem_ports": self.mem_ports,
                "registers_per_pe": self.registers_per_pe,
                "registers_by_class": (
                    None if self.registers_by_class is None
                    else dict(self.registers_by_class)
                ),
            },
            sort_keys=True,
            separators=(",", ":"),
        )
        return hashlib.sha1(payload.encode()).hexdigest()

    # ------------------------------------------------------------------- I/O
    def to_json(self) -> str:
        return json.dumps(
            {
                "name": self.name,
                "rows": self.rows,
                "cols": self.cols,
                "topology": self.topology,
                "pe_classes": (
                    None if self.pe_classes is None
                    else [list(c) for c in self.pe_classes]
                ),
                "mem_ports": self.mem_ports,
                "registers_per_pe": self.registers_per_pe,
                "registers_by_class": (
                    None if self.registers_by_class is None
                    else dict(self.registers_by_class)
                ),
            },
            indent=2,
        )

    @classmethod
    def from_json(cls, text: str) -> "ArchSpec":
        # every malformation surfaces as ValueError so CLI frontends can
        # catch one exception type and print a clean message
        try:
            d = json.loads(text)
            pe_classes = d.get("pe_classes")
            spec = cls(
                name=d.get("name", "arch"),
                rows=d["rows"],
                cols=d["cols"],
                topology=d.get("topology", "mesh"),
                pe_classes=(
                    None if pe_classes is None
                    else tuple(tuple(c) for c in pe_classes)
                ),
                mem_ports=d.get("mem_ports"),
                registers_per_pe=d.get("registers_per_pe", 8),
                registers_by_class=d.get("registers_by_class"),
            )
        except (KeyError, TypeError, AttributeError) as exc:
            raise ValueError(f"malformed ArchSpec JSON: {exc!r}") from None
        spec.validate()
        return spec

    def save(self, path: str) -> None:
        with open(path, "w", encoding="utf-8") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "ArchSpec":
        with open(path, "r", encoding="utf-8") as f:
            return cls.from_json(f.read())

    def renamed(self, name: str) -> "ArchSpec":
        return replace(self, name=name)


def resolve_arch(arg: str) -> ArchSpec:
    """Resolve a CLI ``--arch`` argument: preset name first, file path second.

    Raises ValueError with the preset list when neither matches, so the CLI
    error is self-documenting.
    """
    from .presets import PRESETS, get_preset

    if arg in PRESETS:
        return get_preset(arg)
    import os

    if os.path.exists(arg):
        return ArchSpec.load(arg)
    raise ValueError(
        f"unknown architecture {arg!r}: not a preset "
        f"({', '.join(sorted(PRESETS))}) and not a file"
    )

"""Architecture-description subsystem (DESIGN.md §10).

Declarative, serialisable CGRA specs — capability classes per PE, topology
family, memory ports, register-file size — plus a library of named presets.
``ArchSpec.cgra()`` compiles a spec into the runtime ``CGRA`` model; the
capability information then flows through the time backends (per-op-class
capacity), the space engine (candidate-mask intersection), the simulator
(hard capability/port assertions) and the mapping caches (spec hash in the
key).
"""

from .presets import PRESETS, get_preset, list_presets
from .spec import ArchSpec, op_class, resolve_arch

__all__ = [
    "ArchSpec",
    "PRESETS",
    "get_preset",
    "list_presets",
    "op_class",
    "resolve_arch",
]

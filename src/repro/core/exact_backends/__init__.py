"""Exact joint time+space backend and optimality certificates (DESIGN.md §14).

The portfolio mapper (core/mapper.py) is a heuristic: its IIs are good but
unproven. This package adds the missing ground truth — a complete joint
search over (kernel step, PE) assignments per DFG node that either proves no
mapping exists at a candidate II (``solve_joint`` → ``unsat``) or produces a
real, independently validated mapping (``sat``). ``certify.py`` drives it
over every II below a portfolio result and emits a machine-checkable
:class:`~repro.core.exact_backends.certify.Certificate` with status
``optimal | better-found | timeout``; ``tools/check_certificates.py``
re-validates certificates without trusting the solver.

The related SAT-MapIt line (PAPERS.md) and DRMT-style ILP schedulers encode
this with quotient/remainder modulo variables in an external solver; the
container ships neither z3 nor OR-Tools, so the same model is implemented
here as a self-contained propagate-and-backtrack search over bitmask domains
(no dependencies beyond the stdlib, deterministic under node budgets).
"""

from .certify import (
    CERTIFICATE_VERSION,
    Certificate,
    certify_mapping,
    verify_certificate,
)
from .joint import JointOutcome, solve_joint

__all__ = [
    "CERTIFICATE_VERSION",
    "Certificate",
    "JointOutcome",
    "certify_mapping",
    "solve_joint",
    "verify_certificate",
]

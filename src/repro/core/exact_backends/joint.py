"""Complete joint (kernel step, PE) search for one candidate II (§14.1).

The decoupled pipeline answers "does *this label partition* embed?"; this
module answers the question the portfolio can only approximate: "does *any*
mapping of the DFG onto ``MRRG(cgra, ii)`` exist?" — by branching jointly on
the pair (label ``t mod II``, PE) per node. That joint domain is exactly the
MRRG vertex set, so one search decides both phases at once:

* **slot exclusivity** — two nodes never share a (PE, label) slot (the MRRG
  vertex-injectivity of the monomorphism phase);
* **adjacency** — every DFG edge lands on closed-adjacent PEs
  (``CGRA.closed_masks``; ``reach_hops > 1`` relaxes to ``reach_masks`` for
  route-through lower bounds, DESIGN.md §14.3);
* **capability/ports** — a node only sits on a PE of its op class, and at
  most ``class_capacity("mem")`` memory ops share one kernel step;
* **modulo schedulability** — the chosen labels admit absolute times
  ``t ≡ label (mod II)`` satisfying every dependency ``t_v ≥ t_u + 1 − II·d``
  (checked by Bellman–Ford over residue-rounded edge weights — the
  quotient/remainder split of the DRMT-style ILP encodings, with the
  quotients eliminated instead of branched).

Domains are per-label PE bitmasks in the DESIGN.md §5 layout, propagated by
forward checking; symmetry is broken by pinning the highest-degree node to
label 0 (global schedule rotation) and to one PE per grid-automorphism orbit.
The search is exhaustive, so ``unsat`` is a proof; budgets make the answer
``unknown`` instead of wrong. Everything is stdlib-only and deterministic
under ``node_budget`` (the certify/CI mode).
"""

from __future__ import annotations

import time as _time
from dataclasses import dataclass

from ..cgra import CGRA, op_class
from ..dfg import DFG
from ..mapper import Mapping

__all__ = ["JointOutcome", "solve_joint", "grid_automorphisms"]

#: How often (in visited nodes) the wall deadline is polled.
_DEADLINE_STRIDE = 1024


@dataclass
class JointOutcome:
    """Result of one :func:`solve_joint` call at a fixed II.

    ``status`` is ``"sat"`` (a mapping exists — ``mapping`` carries it when
    the search ran with direct adjacency), ``"unsat"`` (exhaustive proof that
    none exists), or ``"unknown"`` (budget exhausted first). ``unsat`` under
    ``reach_hops > 1`` is still a sound impossibility proof for the *relaxed*
    model, hence a valid lower bound for route-through mappings; ``sat``
    there proves nothing about real (mov-realised) mappings, so ``mapping``
    is None.
    """

    status: str
    ii: int
    nodes_visited: int = 0
    wall_s: float = 0.0
    reach_hops: int = 1
    mapping: Mapping | None = None


class _Budget(Exception):
    """Internal: node budget or deadline exhausted mid-search."""


def grid_automorphisms(cgra: CGRA) -> list[tuple[int, ...]]:
    """PE permutations preserving adjacency, capabilities and registers.

    Candidates are the grid's coordinate symmetries — the dihedral
    reflections/transposes, plus every row/column translation on a torus —
    filtered against the *actual* ``closed_masks`` / ``capability_masks`` /
    ``registers_at`` data, so heterogeneous fabrics only keep the symmetries
    their capability layout survives. Used to shrink the root node's PE
    domain to one representative per orbit (§14.2); always contains the
    identity.
    """
    rows, cols, n = cgra.rows, cgra.cols, cgra.num_pes
    candidates: set[tuple[int, ...]] = set()
    shifts = (
        [(dr, dc) for dr in range(rows) for dc in range(cols)]
        if cgra.topology == "torus" else [(0, 0)]
    )
    for flip_r in (False, True):
        for flip_c in (False, True):
            for transpose in (False, True):
                if transpose and rows != cols:
                    continue
                for dr, dc in shifts:
                    perm = []
                    for p in range(n):
                        r, c = cgra.pe_coords(p)
                        if flip_r:
                            r = rows - 1 - r
                        if flip_c:
                            c = cols - 1 - c
                        if transpose:
                            r, c = c, r
                        perm.append(
                            cgra.pe_index((r + dr) % rows, (c + dc) % cols)
                        )
                    candidates.add(tuple(perm))

    def permuted_mask(mask: int, perm: tuple[int, ...]) -> int:
        out = 0
        while mask:
            bit = mask & -mask
            out |= 1 << perm[bit.bit_length() - 1]
            mask ^= bit
        return out

    closed = cgra.closed_masks
    caps = cgra.capability_masks
    out = []
    for perm in sorted(candidates):
        if any(permuted_mask(closed[p], perm) != closed[perm[p]]
               for p in range(n)):
            continue
        if any(permuted_mask(m, perm) != m for m in caps.values()):
            continue
        if any(cgra.registers_at(p) != cgra.registers_at(perm[p])
               for p in range(n)):
            continue
        out.append(perm)
    return out


def _orbit_representatives(cgra: CGRA) -> int:
    """Bitmask of one minimal PE per orbit of the automorphism group."""
    perms = grid_automorphisms(cgra)
    mask = 0
    for p in range(cgra.num_pes):
        if min(perm[p] for perm in perms) == p:
            mask |= 1 << p
    return mask


def _rounded_weights(
    dfg: DFG, ii: int
) -> list[tuple[int, int, int]]:
    """The raw difference constraints ``t_dst − t_src ≥ 1 − II·d``."""
    return [(e.src, e.dst, 1 - ii * e.distance) for e in dfg.edges]


def _schedulable(
    labels: list[int], edges: list[tuple[int, int, int]], ii: int, n: int
) -> bool:
    """Can absolute times ``t ≡ label (mod II)`` satisfy every dependency?

    For an edge with both endpoints labelled, the weight rounds up to the
    smallest value congruent to ``label[dst] − label[src] (mod II)`` — a
    *constant* once labels are fixed, so this is plain Bellman–Ford
    longest-path; a positive cycle (still relaxing after ``n`` passes) means
    the partial labelling admits no schedule at this II.
    """
    dist = [0] * n
    for _ in range(n + 1):
        changed = False
        for s, d, w in edges:
            ls, ld = labels[s], labels[d]
            if ls >= 0 and ld >= 0:
                w += (ld - ls - w) % ii
            nd = dist[s] + w
            if nd > dist[d]:
                dist[d] = nd
                changed = True
        if not changed:
            return True
    return False


def _realize_times(
    labels: list[int], edges: list[tuple[int, int, int]], ii: int, n: int
) -> list[int]:
    """Minimal nonnegative ``t_abs`` with ``t ≡ label (mod II)`` per node."""
    t = list(labels)
    for _ in range(n + 1):
        changed = False
        for s, d, w in edges:
            lo = t[s] + w
            if t[d] < lo:
                t[d] = lo + ((t[d] - lo) % ii)
                changed = True
        if not changed:
            break
    return t


def solve_joint(
    dfg: DFG,
    cgra: CGRA,
    ii: int,
    *,
    reach_hops: int = 1,
    node_budget: int | None = None,
    deadline_s: float | None = None,
) -> JointOutcome:
    """Decide whether *any* mapping of ``dfg`` on ``cgra`` exists at ``ii``.

    Exhaustive joint search (module docstring); ``node_budget`` bounds
    visited assignments (the deterministic knob), ``deadline_s`` bounds wall
    time. ``reach_hops=1`` is the paper's direct-routability model and the
    only mode that returns a :class:`Mapping` on ``sat``; ``reach_hops =
    1 + max_route_hops`` is the §14.3 relaxation whose ``unsat`` answers
    bound route-through mappings from below.
    """
    if ii < 1:
        raise ValueError(f"ii must be >= 1, got {ii}")
    if reach_hops < 1:
        raise ValueError(f"reach_hops must be >= 1, got {reach_hops}")
    dfg.validate()
    start = _time.perf_counter()
    n, num_pes = dfg.num_nodes, cgra.num_pes

    def done(status: str, visited: int, mapping: Mapping | None = None):
        return JointOutcome(
            status=status, ii=ii, nodes_visited=visited,
            wall_s=_time.perf_counter() - start, reach_hops=reach_hops,
            mapping=mapping,
        )

    # ---- free structural unsat proofs (these ARE the res/rec bounds) ----
    classes = [op_class(op) for op in dfg.ops]
    counts: dict[str, int] = {}
    for cls in classes:
        counts[cls] = counts.get(cls, 0) + 1
    if n > num_pes * ii:
        return done("unsat", 0)
    for cls, cnt in counts.items():
        if cnt > cgra.class_capacity(cls) * ii:
            return done("unsat", 0)
    edges = _rounded_weights(dfg, ii)
    if not _schedulable([-1] * n, edges, ii, n):   # II < RecII
        return done("unsat", 0)

    reach = (cgra.closed_masks if reach_hops == 1
             else cgra.reach_masks(reach_hops))
    cap_mask = [cgra.capability_masks[c] for c in classes]
    und = [sorted(s) for s in dfg.undirected_adjacency()]
    mem_cap = cgra.class_capacity("mem")
    track_mem = (cgra.mem_ports is not None
                 and "mem" in counts and mem_cap < counts["mem"] + 1)
    mem_nodes = [v for v in range(n) if classes[v] == "mem"]

    # ---- domains: per node, a PE bitmask per label (§5 bit layout) ----
    dom: list[list[int]] = [[cap_mask[v]] * ii for v in range(n)]
    cnt = [cap_mask[v].bit_count() * ii for v in range(n)]
    labels = [-1] * n
    place = [-1] * n
    mem_at = [0] * ii

    # symmetry root: highest-degree node, pinned to label 0 and one PE per
    # grid-automorphism orbit (any solution rotates/reflects onto this form)
    root = max(range(n), key=lambda v: (len(und[v]), -v))
    reps = _orbit_representatives(cgra) & cap_mask[root]
    if reps == 0:
        return done("unsat", 0)
    for k in range(ii):
        dom[root][k] = reps if k == 0 else 0
    cnt[root] = reps.bit_count()

    trail: list[tuple[int, int, int]] = []     # (node, label, old mask)
    visited = 0
    budget = node_budget if node_budget is not None else float("inf")
    deadline = (None if deadline_s is None else start + deadline_s)

    def shrink(v: int, k: int, new_mask: int) -> bool:
        """Record + apply one domain write; False on wipeout."""
        old = dom[v][k]
        if new_mask == old:
            return True
        trail.append((v, k, old))
        dom[v][k] = new_mask
        cnt[v] += new_mask.bit_count() - old.bit_count()
        return cnt[v] > 0

    def propagate(v: int, k: int, p: int) -> bool:
        bit = 1 << p
        for u in range(n):                      # slot exclusivity
            if labels[u] < 0 and u != v and dom[u][k] & bit:
                if not shrink(u, k, dom[u][k] & ~bit):
                    return False
        r = reach[p]
        for u in und[v]:                        # adjacency
            if labels[u] < 0:
                for j in range(ii):
                    if dom[u][j] & ~r:
                        if not shrink(u, j, dom[u][j] & r):
                            return False
        if track_mem and classes[v] == "mem":
            mem_at[k] += 1
            if mem_at[k] >= mem_cap:            # step's ports are full
                for u in mem_nodes:
                    if labels[u] < 0 and dom[u][k]:
                        if not shrink(u, k, 0):
                            return False
        return _schedulable(labels, edges, ii, n)

    def search(depth: int) -> bool:
        nonlocal visited
        if depth == n:
            return True
        v = -1
        best = None
        for u in range(n):
            if labels[u] < 0:
                key = (cnt[u], -len(und[u]), u)
                if best is None or key < best:
                    best, v = key, u
        mark = len(trail)
        mem_mark = mem_at[0:] if track_mem else None
        for k in range(ii):
            mask = dom[v][k]
            while mask:
                bit = mask & -mask
                mask ^= bit
                p = bit.bit_length() - 1
                visited += 1
                if visited > budget:
                    raise _Budget
                if deadline is not None and visited % _DEADLINE_STRIDE == 0 \
                        and _time.perf_counter() > deadline:
                    raise _Budget
                labels[v], place[v] = k, p
                if propagate(v, k, p) and search(depth + 1):
                    return True
                labels[v] = place[v] = -1
                while len(trail) > mark:       # undo this value's writes
                    u, j, old = trail.pop()
                    cnt[u] += old.bit_count() - dom[u][j].bit_count()
                    dom[u][j] = old
                if track_mem:
                    mem_at[:] = mem_mark
        return False

    try:
        sat = search(0)
    except _Budget:
        return done("unknown", visited)
    except RecursionError:                      # pragma: no cover
        return done("unknown", visited)
    if not sat:
        return done("unsat", visited)
    mapping = None
    if reach_hops == 1:
        t_abs = _realize_times(labels, edges, ii, n)
        mapping = Mapping(
            dfg=dfg, cgra=cgra, ii=ii, t_abs=t_abs, placement=list(place)
        )
    return done("sat", visited, mapping)

"""Optimality certificates for portfolio mappings (DESIGN.md §14.2–§14.4).

:func:`certify_mapping` takes a mapping the portfolio produced and sweeps
:func:`~repro.core.exact_backends.joint.solve_joint` over every II below it,
producing a :class:`Certificate` whose machine-readable ``status`` is

* ``"optimal"``   — every lower II is proven impossible (or the portfolio II
  already equals the recomputable mII bound), so ``ii == ii_opt``;
* ``"better-found"`` — the joint search produced a strictly better *valid*
  mapping; the caller should adopt it (``ii_opt`` is then proven optimal,
  since all IIs below it were refuted first);
* ``"timeout"``   — the budget ran out before a verdict; ``ii_lower_bound``
  still carries every II the sweep *did* refute.

A certificate never asks to be trusted: it records the probe outcomes, the
bound ingredients (res/rec/mII) and the final mapping arrays, and
:func:`verify_certificate` re-checks all of it — bound recomputation, probe
coverage, mapping validation and cycle-accurate re-execution — without
invoking the solver. ``tools/check_certificates.py`` wraps that into a CLI
over the BENCH artifacts, and the CI gate compares fresh bench rows against
recorded ``optimal`` certificates (a row regressing past its certified II
fails the build).

Route-through compiles (``max_route_hops > 0``) are certified against the
§14.3 reach-mask relaxation: a relaxed ``unsat`` soundly bounds even
mov-realised mappings, while ``better-found`` claims are only ever made from
direct-model solutions (which are real mappings outright).
"""

from __future__ import annotations

import dataclasses
import time as _time
from dataclasses import dataclass, field

from ... import obs
from ..cgra import CGRA
from ..dfg import DFG
from ..mapper import Mapping, _pressure_offenders, _rebuild_mapping
from ..schedule import min_ii, rec_ii, res_ii
from .joint import solve_joint

__all__ = [
    "CERTIFICATE_VERSION",
    "Certificate",
    "certify_mapping",
    "verify_certificate",
]

#: Bumped whenever the certificate schema or its proof semantics change;
#: the verifier rejects versions it does not understand.
CERTIFICATE_VERSION = 1

_STATUSES = ("optimal", "better-found", "timeout")

#: Default total wall budget of one certification sweep (seconds). Split
#: evenly across the candidate IIs still open below the portfolio result.
DEFAULT_BUDGET_S = 20.0

#: Default per-probe node budget in deterministic mode (load-independent).
DEFAULT_NODE_BUDGET = 2_000_000


@dataclass
class Certificate:
    """A machine-checkable optimality claim about one compiled mapping.

    JSON-shaped throughout (``as_dict``/``from_dict`` round-trip): this is
    what BENCH rows embed and what the independent verifier consumes. The
    ``probes`` list records one entry per solver call —
    ``{"ii", "outcome": "bound" | "unsat" | "sat" | "unknown",
    "reach_hops", "nodes", "wall_s"}`` — and ``mapping`` carries the final
    (possibly adopted) schedule/placement arrays plus route specs so the
    verifier can re-execute it.
    """

    kernel: str
    dfg_hash: str
    cgra: dict
    connectivity: str
    reach_hops: int
    res_ii: int
    rec_ii: int
    m_ii: int
    ii_portfolio: int
    ii: int
    ii_opt: int | None
    ii_lower_bound: int
    status: str
    probes: list[dict] = field(default_factory=list)
    mapping: dict | None = None
    budget: dict = field(default_factory=dict)
    note: str = ""
    version: int = CERTIFICATE_VERSION

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Certificate":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"unknown Certificate keys: {', '.join(unknown)}")
        return cls(**d)


def _cgra_identity(cgra: CGRA) -> dict:
    return {
        "rows": cgra.rows,
        "cols": cgra.cols,
        "topology": cgra.topology,
        "arch_token": cgra.arch_token(),
    }


def _mapping_payload(mapping: Mapping) -> dict:
    return {
        "ii": mapping.ii,
        "t_abs": list(mapping.t_abs),
        "placement": list(mapping.placement),
        "routes": [list(s) for s in mapping.routes_spec()],
    }


def certify_mapping(
    dfg: DFG,
    cgra: CGRA,
    mapping: Mapping,
    *,
    connectivity: str = "strict",
    max_route_hops: int = 0,
    max_register_pressure: int | None = None,
    budget_s: float = DEFAULT_BUDGET_S,
    node_budget: int | None = None,
    deterministic: bool = False,
) -> tuple[Certificate, Mapping | None]:
    """Certify (or beat) a portfolio mapping's II.

    ``dfg`` is the *original* kernel (for routed mappings, ``mapping.dfg``
    is the mov-spliced rewrite — the model sweeps the original). Returns
    ``(certificate, better_mapping)`` where ``better_mapping`` is a fully
    validated replacement when ``status == "better-found"`` and None
    otherwise. Deterministic mode drops the wall deadline and bounds every
    probe by ``node_budget`` joint-search nodes instead.
    """
    start = _time.perf_counter()
    r_ii, c_ii = res_ii(dfg, cgra), rec_ii(dfg)
    m_ii = min_ii(dfg, cgra)
    hops = 1 + max_route_hops
    if node_budget is None and deterministic:
        node_budget = DEFAULT_NODE_BUDGET
    cert = Certificate(
        kernel=dfg.name,
        dfg_hash=dfg.stable_hash(),
        cgra=_cgra_identity(cgra),
        connectivity=connectivity,
        reach_hops=hops,
        res_ii=r_ii,
        rec_ii=c_ii,
        m_ii=m_ii,
        ii_portfolio=mapping.ii,
        ii=mapping.ii,
        ii_opt=None,
        ii_lower_bound=m_ii,
        status="timeout",
        mapping=_mapping_payload(mapping),
        budget={
            "budget_s": None if deterministic else budget_s,
            "node_budget": node_budget,
            "deterministic": deterministic,
        },
    )

    if mapping.ii <= m_ii:
        # the recomputable bound already meets the result: free proof
        cert.status = "optimal"
        cert.ii_opt = mapping.ii
        cert.ii_lower_bound = mapping.ii
        cert.probes.append({"ii": mapping.ii, "outcome": "bound",
                            "reach_hops": hops, "nodes": 0, "wall_s": 0.0})
        return cert, None

    candidates = list(range(m_ii, mapping.ii))
    better: Mapping | None = None
    for k in candidates:
        if deterministic:
            deadline_k = None
        else:
            # the lowest unresolved II gates every claim (optimal needs all
            # of them refuted, better-found needs everything below its sat
            # refuted), so each probe may spend the whole remaining budget
            deadline_k = budget_s - (_time.perf_counter() - start)
            if deadline_k <= 0:
                cert.note = f"budget exhausted before probing II={k}"
                break

        # direct model first: a sat here is a real mapping whatever the
        # route allowance was, and with hops == 1 its unsat is the proof
        with obs.span("exact.probe", kernel=dfg.name, ii=k,
                      reach_hops=1) as sp:
            out = solve_joint(dfg, cgra, k, reach_hops=1,
                              node_budget=node_budget, deadline_s=deadline_k)
            sp.set(outcome=out.status, nodes=out.nodes_visited)
        cert.probes.append({"ii": k, "outcome": out.status, "reach_hops": 1,
                            "nodes": out.nodes_visited,
                            "wall_s": round(out.wall_s, 4)})
        if out.status == "sat":
            assert out.mapping is not None
            errs = out.mapping.validate(connectivity=connectivity,
                                        registers=False)
            if errs:                            # pragma: no cover - solver bug
                cert.note = f"joint solution at II={k} failed validation: {errs[0]}"
                break
            if max_register_pressure is not None and _pressure_offenders(
                    out.mapping, max_register_pressure):
                cert.note = (
                    f"II={k} achievable but exceeds the requested register "
                    f"bound; optimality under that bound undecided"
                )
                break
            better = out.mapping
            cert.status = "better-found"
            cert.ii = k
            cert.ii_opt = k
            cert.mapping = _mapping_payload(better)
            cert.note = (
                f"strictly better mapping found and proven optimal at II={k} "
                f"(portfolio gave II={cert.ii_portfolio})"
            )
            return cert, better
        if out.status == "unsat":
            if hops > 1:
                # direct impossibility does not bound mov-realised mappings:
                # refute the reach-relaxed model too (§14.3)
                with obs.span("exact.probe", kernel=dfg.name, ii=k,
                              reach_hops=hops) as sp:
                    rout = solve_joint(dfg, cgra, k, reach_hops=hops,
                                       node_budget=node_budget,
                                       deadline_s=deadline_k)
                    sp.set(outcome=rout.status, nodes=rout.nodes_visited)
                cert.probes.append({
                    "ii": k, "outcome": rout.status, "reach_hops": hops,
                    "nodes": rout.nodes_visited,
                    "wall_s": round(rout.wall_s, 4),
                })
                if rout.status == "sat":
                    cert.note = (
                        f"reach-relaxed model satisfiable at II={k}; "
                        f"route-aware optimality undecided"
                    )
                    break
                if rout.status == "unknown":
                    cert.note = f"relaxed probe at II={k} ran out of budget"
                    break
            cert.ii_lower_bound = k + 1
            continue
        cert.note = f"probe at II={k} ran out of budget"
        break

    if cert.ii_lower_bound >= cert.ii_portfolio:
        cert.status = "optimal"
        cert.ii_opt = cert.ii_portfolio
    return cert, better


# --------------------------------------------------------------- verification

def verify_certificate(
    cert: Certificate | dict,
    dfg: DFG,
    cgra: CGRA,
    *,
    check_execution: bool = True,
) -> list[str]:
    """Independently re-check a certificate; returns violations (empty = ok).

    Trusts nothing derivable: recomputes the res/rec/mII bound from the DFG
    and architecture, re-walks the probe list to confirm the claimed lower
    bound is covered by ``unsat`` probes at the right relaxation level,
    re-validates the embedded mapping against every §2 constraint, and (by
    default) re-executes it cycle-accurately against the sequential oracle
    (``simulate.check_equivalence`` → ``execute_mapping``). The solver's
    ``unsat`` verdicts themselves are the one thing only a re-solve could
    re-check; everything else is recomputed here.
    """
    errs: list[str] = []
    if isinstance(cert, Certificate):
        cert = cert.as_dict()
    try:
        cert = Certificate.from_dict(dict(cert))
    except (TypeError, ValueError) as exc:
        return [f"malformed certificate: {exc}"]
    if cert.version != CERTIFICATE_VERSION:
        return [f"unsupported certificate version {cert.version}"]
    if cert.status not in _STATUSES:
        errs.append(f"unknown status {cert.status!r}")

    if cert.dfg_hash != dfg.stable_hash():
        errs.append(
            f"dfg hash mismatch: certificate {cert.dfg_hash[:12]}…, "
            f"kernel {dfg.stable_hash()[:12]}…"
        )
    ident = _cgra_identity(cgra)
    if cert.cgra != ident:
        errs.append(f"architecture mismatch: certificate {cert.cgra}, target {ident}")
    if errs:
        return errs                     # wrong problem: nothing else is meaningful

    # ---- bound recomputation (independent of the solver) ----
    r_ii, c_ii = res_ii(dfg, cgra), rec_ii(dfg)
    m_ii = max(r_ii, c_ii)
    if (cert.res_ii, cert.rec_ii, cert.m_ii) != (r_ii, c_ii, m_ii):
        errs.append(
            f"bound mismatch: certificate res/rec/mII = "
            f"{cert.res_ii}/{cert.rec_ii}/{cert.m_ii}, recomputed "
            f"{r_ii}/{c_ii}/{m_ii}"
        )

    # ---- probe coverage: every II in [mII, lower_bound) must be refuted
    # at the certificate's relaxation level (direct when reach_hops == 1) ----
    refuted = {
        p.get("ii")
        for p in cert.probes
        if p.get("outcome") == "unsat" and p.get("reach_hops") == cert.reach_hops
    }
    if cert.reach_hops > 1:
        # a relaxed refutation is only sound if the direct model was refuted
        # too (certify always probes direct first); require both on record
        direct = {
            p.get("ii") for p in cert.probes
            if p.get("outcome") == "unsat" and p.get("reach_hops") == 1
        }
        refuted &= direct
    covered = m_ii
    while covered in refuted:
        covered += 1
    if cert.ii_lower_bound > covered and cert.ii_lower_bound > m_ii:
        errs.append(
            f"ii_lower_bound={cert.ii_lower_bound} not covered by unsat "
            f"probes (refuted up to {covered})"
        )
    if cert.ii_lower_bound < m_ii:
        errs.append(
            f"ii_lower_bound={cert.ii_lower_bound} below recomputed mII={m_ii}"
        )

    if cert.status == "optimal":
        if cert.ii_opt != cert.ii:
            errs.append(f"optimal status but ii_opt={cert.ii_opt} != ii={cert.ii}")
        if cert.ii > m_ii and covered < cert.ii:
            errs.append(
                f"optimal status but IIs {covered}..{cert.ii - 1} were never refuted"
            )
    elif cert.status == "better-found":
        if cert.ii_opt != cert.ii or cert.ii >= cert.ii_portfolio:
            errs.append(
                f"better-found status inconsistent: ii={cert.ii}, "
                f"ii_opt={cert.ii_opt}, portfolio={cert.ii_portfolio}"
            )
        if cert.ii > m_ii and covered < cert.ii:
            errs.append(
                f"better-found at II={cert.ii} but IIs {covered}..{cert.ii - 1} "
                f"were never refuted"
            )
        sat_ok = any(
            p.get("outcome") == "sat" and p.get("ii") == cert.ii
            and p.get("reach_hops") == 1
            for p in cert.probes
        )
        if not sat_ok:
            errs.append("better-found status without a direct sat probe on record")
    elif cert.status == "timeout":
        if cert.ii_opt is not None:
            errs.append(f"timeout status must not claim ii_opt={cert.ii_opt}")

    # ---- mapping re-validation + re-execution ----
    if cert.mapping is None:
        errs.append("certificate carries no mapping payload")
        return errs
    try:
        mp = cert.mapping
        mapping = _rebuild_mapping(
            dfg, cgra, int(mp["ii"]), list(mp["t_abs"]),
            list(mp["placement"]), [tuple(s) for s in mp.get("routes", [])],
        )
    except (KeyError, ValueError, IndexError, TypeError) as exc:
        errs.append(f"mapping payload does not reconstruct: {exc}")
        return errs
    if mapping.ii != cert.ii:
        errs.append(f"mapping II {mapping.ii} != certified ii {cert.ii}")
    verrs = mapping.validate(connectivity=cert.connectivity, registers=False)
    errs.extend(f"mapping invalid: {e}" for e in verrs)
    if check_execution and not verrs:
        from ..simulate import check_equivalence

        try:
            if not check_equivalence(mapping):
                errs.append("mapping re-execution diverged from the DFG oracle")
        except Exception as exc:            # execute_mapping hard-errors
            errs.append(f"mapping re-execution failed: {exc}")
    return errs

"""Functional validation of mappings by execution (reference + mapped).

Two executors over the same ALU semantics:

  * ``interpret_dfg`` — direct, iteration-by-iteration reference execution of
    the loop's DFG (the "what the loop computes" oracle).
  * ``execute_mapping`` — cycle-accurate modulo-scheduled execution of a
    space-time mapping on the register-file CGRA model: every operand read
    asserts (a) the value was already produced, (b) the producer PE is
    closed-adjacent to the consumer PE. Any scheduling/placement bug surfaces
    as a hard error; outputs must match the reference bit-for-bit.

Also provides the opcode table shared with kernels/cgra_sim.py and a
register-pressure probe (paper §V-3 assumes enough registers; we measure).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from .cgra import op_class
from .dfg import DFG, OP_ARITY
from .mapper import Mapping

# Stable opcode numbering shared with the Pallas kernel.
OPCODES: dict[str, int] = {
    name: i
    for i, name in enumerate(
        [
            "input", "const", "load", "store", "add", "sub", "mul", "div",
            "and", "or", "xor", "shl", "shr", "min", "max", "neg", "not",
            "abs", "mov", "phi", "cmp",
        ]
    )
}


def alu(op: str, a: float, b: float, imm: float) -> float:
    """Scalar ALU semantics, float domain.

    Bitwise ops work on 16-bit casts of |x| so results are exactly
    representable in float32 — keeping this oracle bit-identical to the
    vectorised Pallas kernel (kernels/cgra_sim.py), which computes in f32.
    """
    ia, ib = int(abs(a)) & 0xFFFF, int(abs(b)) & 0xFFFF
    if op in ("input", "const"):
        return imm
    if op in ("load", "mov", "store"):
        return a
    if op == "phi":
        # loop-carried merge: accumulate (carried operand is 0 on iteration 0),
        # which makes recurrences semantically live for equivalence testing
        return a + b
    if op == "add":
        return a + b
    if op == "sub":
        return a - b
    if op == "mul":
        return a * b
    if op == "div":
        return a / b if b != 0 else 0.0
    if op == "and":
        return float(ia & ib)
    if op == "or":
        return float(ia | ib)
    if op == "xor":
        return float(ia ^ ib)
    if op == "shl":
        return float((ia << (ib % 8)) & 0xFFFF)
    if op == "shr":
        return float(ia >> (ib % 8))
    if op == "min":
        return min(a, b)
    if op == "max":
        return max(a, b)
    if op == "neg":
        return -a
    if op == "not":
        return float(~ia & 0xFFFF)
    if op == "abs":
        return abs(a)
    if op == "cmp":
        return 1.0 if a > b else 0.0
    raise ValueError(f"unknown op {op}")


def _operands(dfg: DFG, v: int) -> list:
    """Deterministic operand order: ``DFG.operands`` (port pins, then
    intra edges first, then carried, by src). Shared with kernels/ops.py."""
    return dfg.operands(v)


def interpret_dfg(
    dfg: DFG, inputs: dict[int, list[float]], num_iters: int
) -> dict[int, list[float]]:
    """Reference execution; returns per-store-node output streams."""
    order = _topo(dfg)
    vals: list[dict[int, float]] = []  # per iteration: node -> value
    outs: dict[int, list[float]] = {
        v: [] for v in dfg.nodes if dfg.ops[v] == "store"
    }
    for it in range(num_iters):
        cur: dict[int, float] = {}
        for v in order:
            op = dfg.ops[v]
            if op == "input":
                cur[v] = inputs[v][it]
                continue
            if op == "const":
                cur[v] = dfg.imms[v]
                continue
            args: list[float] = []
            for e in _operands(dfg, v):
                if e.distance == 0:
                    args.append(cur[e.src])
                else:
                    src_it = it - e.distance
                    args.append(vals[src_it][e.src] if src_it >= 0 else 0.0)
            a = args[0] if args else 0.0
            b = args[1] if len(args) > 1 else 0.0
            cur[v] = alu(op, a, b, dfg.imms[v])
            if op == "store":
                outs[v].append(cur[v])
        vals.append(cur)
    return outs


@dataclass
class ExecutionReport:
    outputs: dict[int, list[float]]
    max_register_pressure: dict[int, int]  # pe -> max simultaneous live values
    cycles: int


def execute_mapping(
    mapping: Mapping, inputs: dict[int, list[float]], num_iters: int
) -> ExecutionReport:
    """Cycle-accurate modulo-scheduled execution on the CGRA model.

    Beyond routing/timing, heterogeneous grids (core/arch, DESIGN.md §10)
    are enforced as hard errors: an op on a PE lacking its capability class,
    or a cycle firing more memory ops than the grid has ports, raises — the
    oracle double-checks the mapper's capability bookkeeping independently.
    """
    dfg, cgra, ii = mapping.dfg, mapping.cgra, mapping.ii
    t_abs, placement = mapping.t_abs, mapping.placement
    for v in dfg.nodes:
        cls = op_class(dfg.ops[v])
        if not cgra.capable(placement[v], cls):
            raise AssertionError(
                f"capability violation: node {v} ({dfg.ops[v]}, class {cls!r}) "
                f"mapped to PE {placement[v]} which lacks it"
            )
    total_cycles = max(t_abs) + 1 + (num_iters - 1) * ii
    # register files: pe -> {(producer_node, iteration): value}
    regs: list[dict[tuple[int, int], float]] = [dict() for _ in range(cgra.num_pes)]
    outs: dict[int, list[float]] = {
        v: [0.0] * num_iters for v in dfg.nodes if dfg.ops[v] == "store"
    }
    pressure = [0] * cgra.num_pes
    # last consumer cycle of each (node, iteration) value, for liveness
    last_use: dict[tuple[int, int], int] = {}
    for v in dfg.nodes:
        for e in _operands(dfg, v):
            for it in range(num_iters):
                src_it = it - e.distance
                if src_it < 0:
                    continue
                c = t_abs[v] + it * ii
                key = (e.src, src_it)
                last_use[key] = max(last_use.get(key, -1), c)

    for c in range(total_cycles):
        # ops whose (cycle - t_abs) is a non-negative multiple of II fire now
        firing = []
        for v in dfg.nodes:
            d = c - t_abs[v]
            if d >= 0 and d % ii == 0 and d // ii < num_iters:
                firing.append((v, d // ii))
        if cgra.mem_ports is not None:
            mem_firing = sum(
                1 for v, _ in firing if op_class(dfg.ops[v]) == "mem"
            )
            if mem_firing > cgra.mem_ports:
                raise AssertionError(
                    f"memory-port violation: {mem_firing} memory ops fire at "
                    f"cycle {c} > {cgra.mem_ports} ports"
                )
        for v, it in firing:
            op = dfg.ops[v]
            pe = placement[v]
            if op == "input":
                val = inputs[v][it]
            elif op == "const":
                val = dfg.imms[v]
            else:
                args: list[float] = []
                for e in _operands(dfg, v):
                    src_it = it - e.distance
                    if src_it < 0:
                        args.append(0.0)
                        continue
                    src_pe = placement[e.src]
                    if not cgra.adjacency[pe][src_pe]:
                        raise AssertionError(
                            f"routing violation: node {v}@PE{pe} reads node "
                            f"{e.src}@PE{src_pe} (not adjacent)"
                        )
                    key = (e.src, src_it)
                    if key not in regs[src_pe]:
                        raise AssertionError(
                            f"timing violation: node {v} it={it} cycle={c} reads "
                            f"{key} not yet produced"
                        )
                    args.append(regs[src_pe][key])
                a = args[0] if args else 0.0
                b = args[1] if len(args) > 1 else 0.0
                val = alu(op, a, b, dfg.imms[v])
            regs[pe][(v, it)] = val
            if op == "store":
                outs[v][it] = val
        # retire dead values; record pressure
        for pe in range(cgra.num_pes):
            dead = [k for k in regs[pe] if last_use.get(k, -1) <= c]
            pressure[pe] = max(pressure[pe], len(regs[pe]))
            for k in dead:
                del regs[pe][k]
    return ExecutionReport(
        outputs=outs,
        max_register_pressure={pe: p for pe, p in enumerate(pressure) if p},
        cycles=total_cycles,
    )


def check_equivalence(
    mapping: Mapping, *, num_iters: int = 8, seed: int = 0
) -> ExecutionReport:
    """Run both executors on random inputs and assert identical outputs."""
    import random

    rng = random.Random(seed)
    inputs = {
        v: [round(rng.uniform(-4, 4), 3) for _ in range(num_iters)]
        for v in mapping.dfg.nodes
        if mapping.dfg.ops[v] == "input"
    }
    ref = interpret_dfg(mapping.dfg, inputs, num_iters)
    rep = execute_mapping(mapping, inputs, num_iters)
    for v, stream in ref.items():
        got = rep.outputs[v][: len(stream)]
        if got != stream:
            raise AssertionError(
                f"mapped execution diverges at store node {v}: {got} != {stream}"
            )
    return rep


def utilization_report(mapping: Mapping) -> dict:
    """Fabric-occupancy summary of a mapping (JSON-friendly).

    Per the modulo-scheduling model, each node occupies exactly one
    ``(pe, t_abs % ii)`` slot, so a fabric of ``num_pes`` PEs at initiation
    interval ``ii`` offers ``num_pes * ii`` slots. The report gives:

    * ``pes_used`` / ``occupancy`` — how much of the fabric the placement
      actually touches (the interesting number on 50×50+ grids, where a
      kernel lights up a tiny corner);
    * ``per_pe`` — used-slot count for each *used* PE only (an empty dict
      entry per idle PE would dwarf the row on large fabrics);
    * ``route_movs`` / ``route_wire_hops`` — route-through cost from
      ``Mapping.routes``: a spliced route with *n* movs spans *n + 1*
      wire hops between its original producer and consumer.
    """
    ii, num_pes = mapping.ii, mapping.cgra.num_pes
    per_pe: dict[int, int] = {}
    for v in mapping.dfg.nodes:
        pe = mapping.placement[v]
        per_pe[pe] = per_pe.get(pe, 0) + 1
    slots_used = sum(per_pe.values())
    slots_total = num_pes * ii
    return {
        "num_pes": num_pes,
        "ii": ii,
        "pes_used": len(per_pe),
        "slots_used": slots_used,
        "slots_total": slots_total,
        "occupancy": round(slots_used / slots_total, 6),
        "per_pe": {pe: per_pe[pe] for pe in sorted(per_pe)},
        "route_movs": mapping.num_route_movs,
        "route_wire_hops": sum(len(r.movs) + 1 for r in mapping.routes),
    }


def register_pressure_by_pe(
    mapping: Mapping, *, num_iters: int | None = None
) -> dict[int, int]:
    """Max simultaneous live values per PE (only PEs with pressure > 0).

    The per-PE resolution matters on heterogeneous register files
    (``CGRA.registers_at`` / ``ArchSpec.registers_by_class``):
    ``Mapping.validate`` compares each PE's pressure against that PE's own
    bound instead of one grid-wide scalar.

    ``num_iters=None`` (the default) probes ``num_stages + 2`` iterations (at
    least 8): a value can stay live for up to ``num_stages`` interleaved
    iterations, so a fixed shallow probe under-reports the steady state of
    deep pipelines — exactly the regime where register files overflow.
    """
    if num_iters is None:
        num_iters = max(8, mapping.num_stages + 2)
    inputs = {
        v: [1.0] * num_iters
        for v in mapping.dfg.nodes
        if mapping.dfg.ops[v] == "input"
    }
    rep = execute_mapping(mapping, inputs, num_iters)
    return rep.max_register_pressure


def check_register_pressure(
    mapping: Mapping, *, num_iters: int | None = None
) -> int:
    """Max simultaneous live values on any PE (paper assumes this fits)."""
    by_pe = register_pressure_by_pe(mapping, num_iters=num_iters)
    return max(by_pe.values(), default=0)


def _topo(dfg: DFG) -> list[int]:
    indeg = [0] * dfg.num_nodes
    adj: list[list[int]] = [[] for _ in dfg.nodes]
    for e in dfg.intra_edges():
        adj[e.src].append(e.dst)
        indeg[e.dst] += 1
    stack = [v for v in dfg.nodes if indeg[v] == 0]
    order = []
    while stack:
        v = stack.pop()
        order.append(v)
        for w in adj[v]:
            indeg[w] -= 1
            if indeg[w] == 0:
                stack.append(w)
    return order

"""repro: monomorphism-based CGRA mapping (space/time decoupled) + a
production-grade multi-pod JAX training/serving framework built around it.

Subpackages
-----------
api        the stable public compiler surface: Compiler sessions, typed
           CompileOptions profiles, structured CompileResult (DESIGN.md §11)
core       the paper's mapping algorithm (SMT time + monomorphism space)
kernels    Pallas TPU kernels (CGRA functional simulator, flash attention)
models     LM model zoo for the 10 assigned architectures
configs    one config per architecture, selectable via --arch
data       sharded input pipelines
optim      optimizers, LR schedules, gradient compression
checkpoint sharding-aware async checkpointing
runtime    fault tolerance, elastic scaling, straggler mitigation
sharding   logical-axis sharding rules for pjit
launch     production mesh, multi-pod dry-run, train/serve drivers
roofline   compiled-artifact roofline analysis
"""

__version__ = "1.1.0"

# the api surface is re-exported lazily so `import repro` stays light
_API_EXPORTS = (
    "Compiler", "CompileOptions", "CompileResult", "BatchResult",
    "PROFILES", "resolve_options",
)


def __getattr__(name):
    if name in _API_EXPORTS:
        from repro import api

        return getattr(api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

"""repro: monomorphism-based CGRA mapping (space/time decoupled) + a
production-grade multi-pod JAX training/serving framework built around it.

Subpackages
-----------
core       the paper's mapping algorithm (SMT time + monomorphism space)
kernels    Pallas TPU kernels (CGRA functional simulator, flash attention)
models     LM model zoo for the 10 assigned architectures
configs    one config per architecture, selectable via --arch
data       sharded input pipelines
optim      optimizers, LR schedules, gradient compression
checkpoint sharding-aware async checkpointing
runtime    fault tolerance, elastic scaling, straggler mitigation
sharding   logical-axis sharding rules for pjit
launch     production mesh, multi-pod dry-run, train/serve drivers
roofline   compiled-artifact roofline analysis
"""

__version__ = "1.0.0"

"""``python -m repro.daemon`` — the persistent compile-daemon CLI.

Front-end of :mod:`repro.core.daemon` (DESIGN.md §16): ``serve`` runs a
:class:`~repro.core.daemon.CompileDaemon` behind a unix socket; ``submit``,
``stats``, ``ping`` and ``shutdown`` talk to a running daemon over the
NDJSON protocol.

Examples::

    # serve the 5x5 mesh with 4 workers and a persistent cache
    PYTHONPATH=src python -m repro.daemon serve --socket /tmp/repro.sock \\
        --size 5 --workers 4 --cache-dir ~/.cache/repro-maps &

    # compile suite kernels through it (full CompileResult rows, NDJSON)
    PYTHONPATH=src python -m repro.daemon submit --socket /tmp/repro.sock \\
        --bench fft --bench bitcount --tenant ci --request-deadline-s 10

    # observe, then stop
    PYTHONPATH=src python -m repro.daemon stats --socket /tmp/repro.sock
    PYTHONPATH=src python -m repro.daemon shutdown --socket /tmp/repro.sock

``serve`` accepts the shared compiler-option flags (``--profile``,
``--cache-dir``, ...) plus daemon knobs: ``--workers``, ``--queue-limit``,
``--no-speculate``, ``--cache-max-bytes`` / ``--cache-max-age-s`` (periodic
disk-cache pruning), and ``--trace-dir`` (rotated per-segment span files
that ``tools/trace_report.py`` reads directly).
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import sys

from repro.api import add_cli_args, options_from_args
from repro.core.cgra import CGRA
from repro.core.daemon import CompileDaemon, DaemonClient, DaemonError, DaemonServer
from repro.core.dfg import DFG

DEFAULT_SOCKET = "/tmp/repro-daemon.sock"


def _add_socket_arg(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--socket", default=DEFAULT_SOCKET,
                    help=f"daemon unix-socket path (default {DEFAULT_SOCKET})")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.daemon",
        description="Persistent CGRA compile daemon (serve) and its client "
                    "verbs (submit / stats / ping / shutdown).",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    serve = sub.add_parser("serve", help="run the compile daemon")
    _add_socket_arg(serve)
    tgt = serve.add_argument_group("target CGRA")
    tgt.add_argument("--size", type=int, default=5,
                     help="square grid size N (NxN, default 5)")
    tgt.add_argument("--rows", type=int, help="grid rows (overrides --size)")
    tgt.add_argument("--cols", type=int, help="grid cols (overrides --size)")
    tgt.add_argument("--topology",
                     choices=["mesh", "torus", "diagonal", "one-hop"],
                     default="mesh")
    add_cli_args(serve)  # the shared compiler-option flags, defined once
    dmn = serve.add_argument_group("daemon")
    dmn.add_argument("--workers", type=int, default=2,
                     help="compile worker threads (default 2)")
    dmn.add_argument("--queue-limit", type=int, default=64, dest="queue_limit",
                     help="max queued requests before admission control "
                          "sheds with the 'overloaded' failure code")
    dmn.add_argument("--no-speculate", action="store_false", default=True,
                     dest="speculate",
                     help="disable idle-time speculative premapping of "
                          "neighboring option variants")
    dmn.add_argument("--cache-max-bytes", type=int, default=None,
                     dest="cache_max_bytes",
                     help="prune the disk mapping cache LRU-by-mtime to this "
                          "byte budget during idle maintenance")
    dmn.add_argument("--cache-max-age-s", type=float, default=None,
                     dest="cache_max_age_s",
                     help="evict disk-cache entries older than this many "
                          "seconds during idle maintenance")
    dmn.add_argument("--trace-dir", default=None, dest="trace_dir",
                     help="rotate per-request span segments into this "
                          "directory as Chrome trace-event JSON files "
                          "(tools/trace_report.py reads each segment)")
    dmn.add_argument("--rotate-every", type=int, default=256,
                     dest="rotate_every",
                     help="completed requests per rotated trace segment")
    dmn.add_argument("--quiet", action="store_true")

    submit = sub.add_parser(
        "submit", help="compile DFGs through a running daemon")
    _add_socket_arg(submit)
    submit.add_argument("--bench", action="append", default=[],
                        help="a built-in suite benchmark by name (repeatable)")
    submit.add_argument("--dfg", action="append", default=[], metavar="FILE",
                        help="a DFG.to_json file (repeatable)")
    submit.add_argument("--tenant", default=None,
                        help="tenant label attached to each request")
    submit.add_argument("--request-deadline-s", type=float, default=None,
                        dest="request_deadline_s",
                        help="per-request deadline (expired requests come "
                             "back 'cancelled', shed ones 'overloaded')")
    submit.add_argument("--options", default=None, metavar="JSON",
                        help="per-request CompileOptions overrides as a JSON "
                             'object, e.g. \'{"max_route_hops": 1}\'')
    submit.add_argument("--quiet", action="store_true",
                        help="suppress the per-row summary lines (NDJSON "
                             "rows still go to stdout)")

    for verb, txt in (("stats", "print daemon counters as JSON"),
                      ("ping", "liveness probe (exit 0 = alive)"),
                      ("shutdown", "stop a running daemon")):
        p = sub.add_parser(verb, help=txt)
        _add_socket_arg(p)
    return ap


def _cmd_serve(args: argparse.Namespace) -> int:
    try:
        opts = options_from_args(args)
    except ValueError as exc:
        print(str(exc), file=sys.stderr)
        return 2
    if opts.arch:
        target = None  # Compiler resolves options.arch
    else:
        rows = args.rows if args.rows is not None else args.size
        cols = args.cols if args.cols is not None else args.size
        target = CGRA(rows, cols, topology=args.topology)
    daemon = CompileDaemon(
        target, opts,
        workers=args.workers,
        queue_limit=args.queue_limit,
        speculate=args.speculate,
        cache_max_bytes=args.cache_max_bytes,
        cache_max_age_s=args.cache_max_age_s,
        trace_dir=args.trace_dir,
        rotate_every=args.rotate_every,
    )
    server = DaemonServer(daemon, args.socket)
    try:
        server.start()
    except (OSError, RuntimeError) as exc:
        print(f"cannot serve on {args.socket}: {exc}", file=sys.stderr)
        return 2
    # SIGTERM/SIGINT take the same graceful path as the shutdown op, so a
    # supervised daemon (or ^C) still drains, rotates traces, and unlinks
    # the socket
    for sig in (signal.SIGTERM, signal.SIGINT):
        signal.signal(
            sig, lambda *_: server._shutdown_requested.set())
    if not args.quiet:
        print(f"repro daemon serving on {args.socket} "
              f"({daemon.num_workers} workers, queue limit "
              f"{daemon.queue_limit}, speculate={daemon.speculate})",
              flush=True)
    server.serve_forever()
    if not args.quiet:
        print("repro daemon stopped", flush=True)
    return 0


def _load_submit_dfgs(args: argparse.Namespace) -> list[DFG]:
    dfgs: list[DFG] = []
    if args.bench:
        from repro.core.benchsuite import load_suite

        dfgs.extend(load_suite(names=args.bench).values())
    for path in args.dfg:
        with open(path, "r", encoding="utf-8") as f:
            dfg = DFG.from_json(f.read())
        dfg.validate()
        if dfg.name == "dfg":
            dfg.name = os.path.splitext(os.path.basename(path))[0]
        dfgs.append(dfg)
    return dfgs


def _cmd_submit(args: argparse.Namespace) -> int:
    overrides = None
    if args.options:
        try:
            overrides = json.loads(args.options)
            if not isinstance(overrides, dict):
                raise ValueError("not a JSON object")
        except ValueError as exc:
            print(f"bad --options JSON: {exc}", file=sys.stderr)
            return 2
    try:
        dfgs = _load_submit_dfgs(args)
    except (OSError, ValueError, KeyError) as exc:
        print(f"cannot load DFGs: {exc}", file=sys.stderr)
        return 2
    if not dfgs:
        print("nothing to submit: pass --bench and/or --dfg", file=sys.stderr)
        return 2
    ok = True
    with DaemonClient(args.socket) as client:
        for dfg in dfgs:
            row = client.compile(
                dfg, tenant=args.tenant,
                deadline_s=args.request_deadline_s, options=overrides)
            ok = ok and row["ok"]
            print(json.dumps(row))
            if not args.quiet:
                status = (f"II={row['ii']}" if row["ok"]
                          else f"FAILED ({row['failure']})")
                print(f"# {row['name']:20s} {status:24s} "
                      f"{row['wall_s']:7.3f}s  [{row['source'] or '-'}] "
                      f"queue {row['service']['queue_s'] * 1e3:.1f}ms",
                      file=sys.stderr)
    return 0 if ok else 1


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.cmd == "serve":
        return _cmd_serve(args)
    try:
        if args.cmd == "submit":
            return _cmd_submit(args)
        with DaemonClient(args.socket) as client:
            if args.cmd == "ping":
                alive = client.ping()
                print("pong" if alive else "no response")
                return 0 if alive else 1
            if args.cmd == "stats":
                print(json.dumps(client.stats(), indent=2))
                return 0
            if args.cmd == "shutdown":
                stopped = client.shutdown()
                print("daemon stopping" if stopped else "shutdown refused")
                return 0 if stopped else 1
    except DaemonError as exc:
        print(f"daemon error: {exc}", file=sys.stderr)
        return 1
    return 2  # pragma: no cover - argparse enforces the verb set


if __name__ == "__main__":
    sys.exit(main())

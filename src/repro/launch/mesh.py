"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. Single-pod: 16x16 = 256 chips ("data", "model").
Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model") — the pod axis is the
cross-DCI data-parallel axis (gradient all-reduce hierarchically: reduce
within pod over ICI, then across pods; gradient compression applies there).

The device order for the model axis can be permuted with the paper's own
placement machinery (core/placement.py) so pipeline/EP neighbours sit on
ICI-adjacent chips — see examples/pipeline_placement.py.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU tests (requires host-device override in the test)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))

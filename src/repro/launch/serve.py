"""Batched serving driver: prefill + decode loop with KV/state caches.

Continuous-batching-lite: a request queue is drained in fixed-size batches;
each batch is prefilled in parallel and decoded token-by-token with the
family's cache (KV / compressed-MLA / recurrent state). Runs any --arch,
full or --reduced.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --requests 16 --batch 4 --prompt-len 32 --gen 16

``--premap-kernels SIZE`` warms the node before serving: the CGRA kernel
suite is batch-compiled onto a SIZE×SIZE grid through the compiler API
(``repro.api.Compiler.compile_batch``, "fast" profile), against the
persistent mapping cache (``--cache-dir`` / ``$REPRO_CACHE_DIR``). A warm
restart then
boots without re-solving a single mapping — the production pattern the
service layer exists for (DESIGN.md §8).

For a long-lived serving node, the persistent compile daemon supersedes
one-shot premapping: ``python -m repro.daemon serve`` keeps the warmed
session resident behind a unix socket, with admission control and idle
speculative premapping of neighboring option variants (DESIGN.md §16).
``--premap-kernels`` remains the right tool for a single cold boot.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model


def serve_batch(spec, params, prompts: np.ndarray, gen: int, cache_len: int):
    cfg = spec.cfg
    b, s = prompts.shape
    if cfg.family == "audio":
        batch = {
            "frames": jnp.zeros((b, cfg.frontend_len, cfg.d_model), jnp.float32),
            "tokens": jnp.asarray(prompts),
        }
        logits, caches = spec.prefill(params, batch, cache_len)
    else:
        logits, caches = spec.prefill(params, jnp.asarray(prompts), cache_len)
    tok = jnp.argmax(logits, -1)[:, None]
    out = [np.asarray(tok)]
    decode = jax.jit(spec.decode_step)
    base = s + cfg.num_meta_tokens + (cfg.frontend_len if cfg.family == "vlm" else 0)
    for i in range(gen - 1):
        logits, caches = decode(params, tok, caches, jnp.int32(base + i))
        tok = jnp.argmax(logits, -1)[:, None]
        out.append(np.asarray(tok))
    return np.concatenate(out, axis=1)


def premap_kernels(size: int, jobs: int, cache_dir: str | None) -> None:
    """Boot-time warm-up: batch-map the kernel suite via the compiler API."""
    from repro.api import Compiler, resolve_options
    from repro.core.benchsuite import load_suite
    from repro.core.cgra import CGRA

    compiler = Compiler(
        CGRA(size, size),
        resolve_options("fast", jobs=jobs, deadline_s=30.0,
                        cache_dir=cache_dir),
    )
    batch = compiler.compile_batch(list(load_suite().values()))
    c = batch.cache_counters
    print(
        f"premap: {len(batch)} kernels on {compiler.cgra} in "
        f"{batch.wall_s:.2f}s ({batch.num_workers} workers) — "
        f"{c['solved']} solved, {c['memory_hits'] + c['disk_hits']} cache "
        f"hits, {c['failed']} failed"
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--premap-kernels", type=int, default=0, metavar="SIZE",
        help="before serving, batch-compile the CGRA kernel suite onto a "
             "SIZE×SIZE grid (0 = skip); for a resident warm session use "
             "`python -m repro.daemon serve` instead (DESIGN.md §16)",
    )
    ap.add_argument("--premap-jobs", type=int, default=2)
    ap.add_argument(
        "--cache-dir", default=None,
        help="persistent mapping cache for --premap-kernels "
             "(default: $REPRO_CACHE_DIR)",
    )
    args = ap.parse_args(argv)

    if args.premap_kernels:
        premap_kernels(args.premap_kernels, args.premap_jobs, args.cache_dir)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    spec = build_model(cfg)
    params = spec.init(jax.random.PRNGKey(args.seed))

    rng = np.random.default_rng(args.seed)
    queue = [
        rng.integers(1, cfg.vocab, size=args.prompt_len).astype(np.int32)
        for _ in range(args.requests)
    ]
    cache_len = args.prompt_len + args.gen + 8

    t0 = time.perf_counter()
    done = 0
    while queue:
        batch = queue[: args.batch]
        queue = queue[args.batch :]
        prompts = np.stack(
            batch + [batch[-1]] * (args.batch - len(batch))
        )  # pad the tail batch
        tokens = serve_batch(spec, params, prompts, args.gen, cache_len)
        done += len(batch)
        print(f"batch done: {len(batch)} reqs, sample continuation {tokens[0][:8]}")
    dt = time.perf_counter() - t0
    total_tokens = done * args.gen
    print(f"served {done} requests / {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init), which is why they sit above the module docstring.

Per cell:
  * build the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  * build the model + sharding rules,
  * jax.jit(step).lower(**ShapeDtypeStructs).compile()   (no allocation),
  * print + persist memory_analysis() / cost_analysis() / roofline terms.

Run one cell:   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --multi-pod
Run everything: PYTHONPATH=src python -m repro.launch.dryrun --all [--results DIR]
(--all orchestrates one subprocess per cell — isolation keeps XLA memory
bounded and makes the sweep resumable; finished cells are skipped.)
"""

import argparse
import json
import sys
import time
import traceback


def _depth_variants(cfg):
    """Shallow-depth configs + extrapolation weights for linear cost fitting.

    Returns (variants, weights): cost_full = sum_i w_i * cost(variants[i]).
    Exact for homogeneous layer stacks: cost(L) = outside + L * body.
    """
    import dataclasses

    L = cfg.num_layers
    if cfg.moe is not None:
        nd, nm = cfg.num_dense_layers, L - cfg.num_dense_layers
        v11 = dataclasses.replace(cfg, num_layers=2, num_dense_layers=1)
        v21 = dataclasses.replace(cfg, num_layers=3, num_dense_layers=2)
        v12 = dataclasses.replace(cfg, num_layers=3, num_dense_layers=1)
        # f = f11 + (nd-1)(f21-f11) + (nm-1)(f12-f11)
        w = [1.0 - (nd - 1) - (nm - 1), float(nd - 1), float(nm - 1)]
        return [v11, v21, v12], w
    if cfg.family == "ssm":            # alternating pairs
        v2 = dataclasses.replace(cfg, num_layers=2)
        v4 = dataclasses.replace(cfg, num_layers=4)
        k = (L - 2) / 2
        return [v2, v4], [1.0 - k, k]
    v1 = dataclasses.replace(cfg, num_layers=1)
    v2 = dataclasses.replace(cfg, num_layers=2)
    return [v1, v2], [1.0 - (L - 1), float(L - 1)]


def run_cell(arch: str, shape_name: str, multi_pod: bool, results_dir: str,
             opt_flags: dict | None = None) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.launch.mesh import data_axes_of, make_production_mesh
    from repro.models import build_model
    from repro.models.zoo import train_input_specs
    from repro.optim import AdamWConfig, adamw_init, adamw_update, build_opt_shardings
    from repro.roofline.analysis import (
        HW, analyze_compiled, model_flops_decode, model_flops_train,
    )
    from repro.sharding import batch_shardings, cache_shardings, param_shardings

    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.roofline.analysis import HW, Roofline, parse_collectives

    base_cfg = get_config(arch)
    opt_flags = opt_flags or {}
    shape = next(s for s in base_cfg.shapes() if s.name == shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    data_axes = data_axes_of(mesh)
    chips = mesh.devices.size
    timings: dict[str, float] = {}

    if opt_flags.get("batch_over_model") in (True, "1", "true"):
        # pure-DP experiment: the 'model' axis joins the batch axes
        data_axes = (*data_axes, "model")

    # FSDP decision from the FULL config (shallow cost variants must use the
    # same layout so extrapolated collectives include FSDP all-gathers)
    _full_spec = build_model(base_cfg, mesh=mesh, data_axes=data_axes)
    _full_params = jax.eval_shape(_full_spec.init, jax.random.PRNGKey(0))
    _probe = param_shardings(_full_params, mesh)
    use_fsdp = any(
        any(ax is not None and ax != "model"
            for s in (leaf.spec,) for ax in s)
        for leaf in jax.tree.leaves(_probe)
    )
    print(f"fsdp={use_fsdp}")

    # ---- §Perf experiment knobs (set via --set key=val)
    replicate_patterns = tuple(
        opt_flags.get("replicate_patterns", "").split(",")
    ) if opt_flags.get("replicate_patterns") else ()

    def tweak_cfg(cfg_x):
        if opt_flags.get("moe_capacity"):
            cfg_x = dataclasses.replace(
                cfg_x,
                moe=dataclasses.replace(
                    cfg_x.moe, capacity_factor=float(opt_flags["moe_capacity"])
                ),
            )
        if opt_flags.get("remat") is not None:
            cfg_x = dataclasses.replace(cfg_x, remat=opt_flags["remat"] in (True, "1", "true"))
        if opt_flags.get("act_constraints") is not None:
            cfg_x = dataclasses.replace(
                cfg_x,
                activation_constraints=opt_flags["act_constraints"] in (True, "1", "true"),
            )
        if opt_flags.get("ep_all") in (True, "1", "true"):
            cfg_x = dataclasses.replace(cfg_x, ep_over_data=True)
        return cfg_x

    def lower_step(cfg_x, *, unroll: bool):
        """Lower the cell's step for a (possibly depth-reduced) config."""
        cfg_b = dataclasses.replace(cfg_x, scan_layers=False) if unroll else cfg_x
        cfg_b = tweak_cfg(cfg_b)
        spec = build_model(cfg_b, mesh=mesh, data_axes=data_axes)
        params_shape = jax.eval_shape(spec.init, jax.random.PRNGKey(0))
        fsdp = use_fsdp if opt_flags.get("fsdp") is None else opt_flags["fsdp"] in (True, "1", "true")
        ep_axes = (
            (*data_axes, "model")
            if opt_flags.get("ep_all") in (True, "1", "true")
            else None
        )
        p_sh = param_shardings(
            params_shape, mesh, force_fsdp=fsdp,
            replicate_patterns=replicate_patterns,
            expert_axes=ep_axes,
        )
        if shape.kind == "train":
            opt_cfg = AdamWConfig(
                moment_dtype=jnp.bfloat16 if "671b" in arch else jnp.float32
            )
            opt_shape = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_shape)
            o_sh = build_opt_shardings(params_shape, p_sh, mesh, data_axis="data")
            batch = train_input_specs(cfg_b, shape)
            b_sh = batch_shardings(batch, mesh, data_axes)
            compress = opt_flags.get("compress_grads") in (True, "1", "true")

            def train_step(params, opt, batch):
                (loss, metrics), grads = jax.value_and_grad(
                    spec.loss_fn, has_aux=True
                )(params, batch)
                if compress:
                    # int8 the gradient payload before the DP reduction
                    # (error feedback runs in the real train loop; the dry-run
                    # measures the wire-size effect)
                    from repro.optim.compression import compress as _c, decompress as _d

                    grads = jax.tree.map(
                        lambda g: _d(*_c(g), g.shape).astype(g.dtype), grads
                    )
                new_params, new_opt, om = adamw_update(grads, opt, params, opt_cfg)
                return new_params, new_opt, {"loss": loss, **metrics, **om}

            return jax.jit(
                train_step,
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            ).lower(params_shape, opt_shape, batch)

        if shape.kind == "prefill":
            batch = train_input_specs(cfg_b, shape)
            batch.pop("labels")
            b_sh = batch_shardings(batch, mesh, data_axes)

            def prefill_step(params, batch):
                if cfg_b.family == "audio":
                    logits, _ = spec.prefill(params, batch, shape.seq_len)
                else:
                    logits, _ = spec.prefill(params, batch["tokens"], shape.seq_len)
                return logits

            return jax.jit(prefill_step, in_shardings=(p_sh, b_sh)).lower(
                params_shape, batch
            )

        caches_shape = jax.eval_shape(
            lambda: spec.make_caches(None, shape.global_batch, shape.seq_len)
        )
        c_sh = cache_shardings(caches_shape, mesh, data_axes)
        token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        tok_sh = batch_shardings(token, mesh, data_axes)
        pos_sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

        def serve_step(params, token, caches, pos):
            return spec.decode_step(params, token, caches, pos)

        return jax.jit(
            serve_step,
            in_shardings=(p_sh, tok_sh, c_sh, pos_sh),
            out_shardings=(None, c_sh),
            donate_argnums=(2,),
        ).lower(params_shape, token, caches_shape, pos)

    def costs_of(compiled):
        cost = compiled.cost_analysis()
        coll = parse_collectives(compiled.as_text())
        return {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": dict(coll.bytes_by_kind),
            "counts": dict(coll.count_by_kind),
        }

    # ---- memory build: the deployment artifact (scan where the arch scans)
    t0 = time.time()
    mem_lowered = lower_step(base_cfg, unroll=False)
    timings["lower_s"] = round(time.time() - t0, 1)
    t0 = time.time()
    mem_compiled = mem_lowered.compile()
    timings["compile_s"] = round(time.time() - t0, 1)
    mem = mem_compiled.memory_analysis()
    print(mem)

    # ---- cost terms: exact totals.
    # Scanned archs under-report in cost_analysis (While bodies count once),
    # so their FLOPs/collective bytes come from shallow *unrolled* depth
    # variants extrapolated linearly (exact for homogeneous stacks).
    if base_cfg.scan_layers and not opt_flags.get("no_extrapolate"):
        t0 = time.time()
        variants, weights = _depth_variants(base_cfg)
        per_variant = []
        for v in variants:
            per_variant.append(costs_of(lower_step(v, unroll=True).compile()))
        timings["variant_compile_s"] = round(time.time() - t0, 1)

        def combine(key):
            if key in ("coll", "counts"):
                kinds = {k for pv in per_variant for k in pv[key]}
                return {
                    k: max(0.0, sum(w * pv[key].get(k, 0) for w, pv in zip(weights, per_variant)))
                    for k in kinds
                }
            return max(0.0, sum(w * pv[key] for w, pv in zip(weights, per_variant)))

        flops = combine("flops")
        hbm_bytes = combine("bytes")
        coll_by_kind = combine("coll")
        coll_counts = {k: int(v) for k, v in combine("counts").items()}
        cost_method = f"depth-extrapolated({len(variants)} variants)"
    else:
        c = costs_of(mem_compiled)
        flops, hbm_bytes = c["flops"], c["bytes"]
        coll_by_kind, coll_counts = c["coll"], c["counts"]
        cost_method = "direct (unrolled model)"

    if shape.kind == "train":
        model_flops = model_flops_train(base_cfg, shape)
    elif shape.kind == "prefill":
        model_flops = model_flops_train(base_cfg, shape) / 3.0  # fwd only
    else:
        model_flops = model_flops_decode(base_cfg, shape)

    hw = HW()
    coll_total = float(sum(coll_by_kind.values()))
    t_compute = flops / hw.peak_flops
    t_memory = hbm_bytes / hw.hbm_bw
    t_collective = coll_total / hw.ici_bw
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_collective}
    bottleneck = max(terms, key=terms.get)
    bound = max(terms.values())

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "ok": True,
        **timings,
        "cost_method": cost_method,
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": hbm_bytes,
        "collective_bytes_per_dev": coll_total,
        "collectives": coll_by_kind,
        "collective_counts": coll_counts,
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_collective": t_collective,
        "bottleneck": bottleneck,
        "model_flops": model_flops,
        "useful_flops_ratio": model_flops / (flops * chips) if flops else 0.0,
        "mfu_upper_bound": (
            model_flops / (chips * hw.peak_flops * bound) if bound else 0.0
        ),
        "arg_bytes_per_dev": getattr(mem, "argument_size_in_bytes", 0),
        "temp_bytes_per_dev": getattr(mem, "temp_size_in_bytes", 0),
        "out_bytes_per_dev": getattr(mem, "output_size_in_bytes", 0),
    }
    _persist(results_dir, result)
    print(json.dumps(result, indent=2))
    return result


def _cell_id(arch, shape, multi_pod):
    return f"{arch}__{shape}__{'multi' if multi_pod else 'single'}"


def _persist(results_dir, result):
    os.makedirs(results_dir, exist_ok=True)
    cid = _cell_id(result["arch"], result["shape"], result["mesh"] == "2x16x16")
    with open(os.path.join(results_dir, cid + ".json"), "w") as f:
        json.dump(result, f, indent=2)


def run_all(results_dir: str, *, timeout_s: int = 1800, only_arch: str | None = None):
    """Subprocess-per-cell sweep (resumable; finished cells skipped)."""
    import subprocess

    from repro.configs import all_configs

    cells = []
    for arch, cfg in all_configs().items():
        if only_arch and arch != only_arch:
            continue
        for shape in cfg.shapes():
            for multi in (False, True):
                cells.append((arch, shape.name, multi))
    print(f"{len(cells)} cells")
    failures = []
    for arch, shape, multi in cells:
        cid = _cell_id(arch, shape, multi)
        out = os.path.join(results_dir, cid + ".json")
        if os.path.exists(out):
            print(f"skip (done): {cid}")
            continue
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape, "--results", results_dir,
        ] + (["--multi-pod"] if multi else [])
        print(f"=== {cid}")
        t0 = time.time()
        try:
            proc = subprocess.run(
                cmd, timeout=timeout_s, capture_output=True, text=True,
                env={**os.environ, "PYTHONPATH": "src"},
            )
            if proc.returncode != 0:
                failures.append(cid)
                err = {
                    "arch": arch, "shape": shape,
                    "mesh": "2x16x16" if multi else "16x16",
                    "ok": False, "error": proc.stderr[-4000:],
                }
                with open(out, "w") as f:
                    json.dump(err, f, indent=2)
                print(f"FAILED ({time.time()-t0:.0f}s): see {out}")
            else:
                print(f"ok ({time.time()-t0:.0f}s)")
        except subprocess.TimeoutExpired:
            failures.append(cid)
            with open(out, "w") as f:
                json.dump({"arch": arch, "shape": shape, "ok": False,
                           "error": f"timeout {timeout_s}s"}, f)
            print("TIMEOUT")
    print(f"done; {len(failures)} failures: {failures}")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--only-arch")
    ap.add_argument("--results", default="results/dryrun")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--set", action="append", default=[],
                    help="experiment knob key=val (e.g. --set moe_capacity=1.0)")
    args = ap.parse_args()
    opt_flags = dict(kv.split("=", 1) for kv in args.set)
    if args.all:
        fails = run_all(args.results, timeout_s=args.timeout, only_arch=args.only_arch)
        sys.exit(1 if fails else 0)
    try:
        run_cell(args.arch, args.shape, args.multi_pod, args.results,
                 opt_flags=opt_flags)
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()

"""End-to-end training driver.

Runs any --arch (full or --reduced) on the available devices with the full
substrate: sharded synthetic/memmap data, AdamW (+ optional int8 gradient
compression with error feedback), async checkpointing, fault-tolerant runner
(restart-from-checkpoint, straggler accounting).

Examples
--------
CPU sanity (also exercised by examples/train_lm.py):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
      --steps 200 --batch 8 --seq 128

Production shape (on a real slice):
  python -m repro.launch.train --arch gemma2-9b --steps 10000
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.models import build_model, param_count
from repro.optim import (
    AdamWConfig, adamw_init, adamw_update, compress_grads_with_feedback,
    init_residual,
)
from repro.runtime import FaultConfig, run_training
from repro.sharding import batch_shardings, param_shardings


def make_state(spec, opt_cfg, rng, *, compression: bool):
    params = spec.init(rng)
    state = {"params": params, "opt": adamw_init(params, opt_cfg)}
    if compression:
        state["residual"] = init_residual(params)
    return state


def make_step(spec, opt_cfg, *, compression: bool):
    def step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(spec.loss_fn, has_aux=True)(
            state["params"], batch
        )
        if compression:
            grads, new_residual = compress_grads_with_feedback(
                grads, state["residual"]
            )
        new_params, new_opt, om = adamw_update(
            grads, state["opt"], state["params"], opt_cfg
        )
        new_state = {"params": new_params, "opt": new_opt}
        if compression:
            new_state["residual"] = new_residual
        return new_state, {"loss": loss, **metrics, **om}

    return jax.jit(step, donate_argnums=(0,))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--params100m", action="store_true",
                    help="~120M-param family member (the end-to-end driver scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.params100m:
        # ~120M-parameter member of the chosen family (end-to-end driver scale)
        cfg = dataclasses.replace(
            cfg, num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
            head_dim=64, d_ff=2048, vocab=50_304, scan_layers=False,
            dtype=jnp.float32,
        )
    elif args.reduced:
        cfg = cfg.reduced()
    spec = build_model(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps, warmup_steps=max(10, args.steps // 20))

    rng = jax.random.PRNGKey(args.seed)
    state = make_state(spec, opt_cfg, rng, compression=args.grad_compression)
    print(f"{args.arch}: {param_count(state['params'])/1e6:.2f}M params, "
          f"{len(jax.devices())} devices")

    data = SyntheticLM(cfg, args.batch, args.seq, seed=args.seed)
    step_fn = make_step(spec, opt_cfg, compression=args.grad_compression)

    losses = []
    t0 = time.perf_counter()

    def logged_step(state, batch):
        new_state, metrics = step_fn(state, batch)
        return new_state, metrics

    fault_cfg = FaultConfig(
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
    )
    state, report = run_training(
        logged_step, state, lambda s: data.batch_at(s), args.steps, fault_cfg,
    )
    dt = time.perf_counter() - t0
    n = max(1, len(report.losses))
    print(
        f"done: {report.steps_done} steps in {dt:.1f}s "
        f"({dt/max(1,report.steps_done)*1e3:.1f} ms/step), "
        f"loss {report.losses[0]:.4f} -> {report.losses[-1]:.4f}, "
        f"restarts={report.restarts}, stragglers={report.straggler_events}"
    )
    return report


if __name__ == "__main__":
    main()

from .elastic import best_mesh_shape, remesh, reshard_state
from .fault import FaultConfig, RunReport, run_training

__all__ = [
    "FaultConfig", "RunReport", "run_training",
    "best_mesh_shape", "remesh", "reshard_state",
]

"""Fault-tolerant training runner: checkpoint/restart, failure injection,
straggler watchdog, elastic re-meshing hooks.

Design for 1000+ nodes (what maps where in a real deployment):

  * checkpoint/restart — AsyncCheckpointer snapshots every ``ckpt_every``
    steps without stalling the step loop; on any step failure the runner
    restores the latest checkpoint and replays (the data pipeline is
    stateless-deterministic, so replayed batches are identical).
  * node failure — surfaces as a RuntimeError/XlaRuntimeError from the step;
    the runner treats N consecutive failures as a topology change and calls
    the elastic hook (runtime/elastic.py) to rebuild the mesh from surviving
    devices and re-place the restored checkpoint.
  * stragglers — per-step wall time is tracked with an EMA; steps slower than
    ``straggler_factor`` x EMA increment a counter surfaced in metrics. On a
    real fleet this is where you re-dispatch the slow host's shard /
    drop-and-average its replica gradients; here the detection + accounting
    layer is implemented and the mitigation is a pluggable callback.

The runner is deliberately framework-level (pure Python around a jitted
step): everything it does composes with any (params, opt, batch) step fn.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.checkpoint import AsyncCheckpointer, latest_step, restore


@dataclass
class FaultConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 3
    straggler_factor: float = 3.0
    straggler_grace_steps: int = 10
    on_straggler: Callable[[int, float, float], None] | None = None
    on_topology_change: Callable[[], Any] | None = None   # elastic hook


@dataclass
class RunReport:
    steps_done: int = 0
    restarts: int = 0
    straggler_events: int = 0
    losses: list = field(default_factory=list)


def run_training(
    step_fn: Callable[[Any, Any], tuple[Any, dict]],
    init_state: Any,
    batch_fn: Callable[[int], Any],
    num_steps: int,
    cfg: FaultConfig,
    *,
    state_like: Any | None = None,
    shardings: Any | None = None,
    fail_injector: Callable[[int], None] | None = None,
) -> tuple[Any, RunReport]:
    """Run `num_steps` with checkpoint/restart + straggler accounting.

    `step_fn(state, batch) -> (state, metrics)`; metrics must contain 'loss'.
    `fail_injector(step)` may raise to simulate node failures (tests do).
    """
    ckpt = AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)
    report = RunReport()
    state = init_state
    start_step = 0

    last = latest_step(cfg.ckpt_dir)
    if last is not None:
        state = restore(cfg.ckpt_dir, last, state_like or init_state, shardings)
        start_step = last
    ema = None
    step = start_step
    restarts = 0
    while step < num_steps:
        try:
            t0 = time.perf_counter()
            if fail_injector is not None:
                fail_injector(step)
            batch = batch_fn(step)
            state, metrics = step_fn(state, batch)
            dt = time.perf_counter() - t0
            # straggler accounting
            if ema is None:
                ema = dt
            if step - start_step > cfg.straggler_grace_steps and dt > cfg.straggler_factor * ema:
                report.straggler_events += 1
                if cfg.on_straggler:
                    cfg.on_straggler(step, dt, ema)
            ema = 0.9 * ema + 0.1 * dt
            loss = metrics.get("loss")
            if loss is not None:
                report.losses.append(float(loss))
            step += 1
            report.steps_done += 1
            if step % cfg.ckpt_every == 0 or step == num_steps:
                ckpt.save_async(step, state)
        except KeyboardInterrupt:
            raise
        except Exception:
            restarts += 1
            report.restarts += 1
            if restarts > cfg.max_restarts:
                if cfg.on_topology_change is not None:
                    # elastic path: rebuild mesh/state and keep going
                    state, shardings = cfg.on_topology_change()
                    restarts = 0
                    continue
                raise
            ckpt.wait()
            last = latest_step(cfg.ckpt_dir)
            if last is not None:
                state = restore(cfg.ckpt_dir, last, state_like or init_state, shardings)
                step = last
            else:
                state = init_state
                step = 0
    ckpt.wait()
    return state, report

"""Elastic re-meshing: resume a run on a different device count.

The checkpoint format is mesh-agnostic (full logical arrays), so elasticity
reduces to: build a new mesh from surviving devices, recompute shardings for
that mesh (the same rules scale to any axis sizes), and `restore` with the
new shardings. On 1000+ nodes you'd do the same with a device-set from the
cluster manager; the math below picks the largest (data x model) grid that
fits the survivors, preferring to shrink the data axis first (keeps TP
layouts, only changes gradient-reduction span).
"""

from __future__ import annotations

import math
from typing import Any, Sequence

import jax
from jax.sharding import Mesh

from repro.sharding import param_shardings


def best_mesh_shape(n_devices: int, *, model_parallel: int) -> tuple[int, int]:
    """(data, model) for the surviving device count; model axis preserved
    while possible, else reduced to the largest divisor that fits."""
    model = min(model_parallel, n_devices)
    while model > 1 and (n_devices % model or model > n_devices):
        model -= 1
    data = n_devices // model
    return data, model


def remesh(
    devices: Sequence[jax.Device],
    *,
    model_parallel: int,
    axis_names: tuple[str, str] = ("data", "model"),
) -> Mesh:
    data, model = best_mesh_shape(len(devices), model_parallel=model_parallel)
    usable = list(devices)[: data * model]
    import numpy as np

    return Mesh(np.asarray(usable).reshape(data, model), axis_names)


def reshard_state(state_like: Any, mesh: Mesh, params_key: str = "params") -> Any:
    """Shardings pytree for a {params, opt, step} state on the new mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    out = {}
    for key, sub in state_like.items():
        if key == params_key:
            out[key] = param_shardings(sub, mesh)
        else:
            out[key] = jax.tree.map(lambda _: NamedSharding(mesh, P()), sub)
    return out

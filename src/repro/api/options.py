"""Typed compiler configuration: :class:`CompileOptions` + named profiles.

One frozen dataclass absorbs every tuning knob that used to travel as loose
``map_dfg`` kwargs and untyped service dicts (DESIGN.md §11). The same object
configures single-shot compiles, the batch service, and window racing, so
policy lives in exactly one place:

* **Profiles** — :data:`PROFILES` maps a name (``fast``, ``quality``,
  ``deterministic-ci``, ``default``) to a fully-populated options value;
  :func:`resolve_options` starts from a profile and applies explicit
  overrides, which is the only resolution path the CLIs use.
* **CLI flags are defined once** — :func:`add_cli_args` installs the shared
  option flags on any argparse parser and :func:`options_from_args` turns the
  parsed namespace back into a resolved :class:`CompileOptions`; the
  ``repro.compile`` CLI, ``benchmarks/run.py`` and both examples all go
  through this pair.
* **JSON round-trip** — ``to_json``/``from_json`` serialise every field and
  reject unknown keys, so a report's embedded options block can be replayed
  byte-for-byte.

This module deliberately imports nothing from the rest of ``repro`` at module
level: ``core/mapper.py``'s compatibility shim builds a ``CompileOptions``
lazily, and a stdlib-only module can never close an import cycle.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from dataclasses import dataclass, fields

__all__ = [
    "CompileOptions",
    "PROFILES",
    "add_cli_args",
    "options_from_args",
    "resolve_options",
]

#: Fields forwarded verbatim to the portfolio mapper
#: (:func:`repro.core.mapper.map_dfg`). Order matches the historical kwarg
#: order for readability; the parity test pins the set itself.
MAPPER_FIELDS = (
    "max_ii",
    "max_slack",
    "connectivity",
    "backend",
    "space_backend",
    "time_budget_s",
    "space_timeout_s",
    "space_polish_timeout_s",
    "space_timeout_growth",
    "det_space_cap",
    "max_retries_per_window",
    "window_timeout_s",
    "max_register_pressure",
    "max_route_hops",
    "deterministic",
    "use_cache",
    "cache_dir",
    "window_offset",
    "window_stride",
    "seed",
)

#: Service-layer knobs (batch pool + racing) that never reach ``map_dfg``.
SERVICE_FIELDS = ("jobs", "deadline_s", "racing_workers")

_CONNECTIVITIES = ("strict", "paper")
_BACKENDS = ("auto", "cp", "cp-inc", "python", "z3")
_SPACE_BACKENDS = ("auto", "exact", "anneal")


@dataclass(frozen=True)
class CompileOptions:
    """Frozen, JSON-round-trippable compiler configuration (DESIGN.md §11).

    Field defaults are exactly the historical ``map_dfg`` defaults, so
    ``CompileOptions()`` reproduces a bare ``map_dfg(dfg, cgra)`` call.

    Example — resolve a profile, tighten one knob, and round-trip it::

        from repro.api import CompileOptions, resolve_options

        opts = resolve_options("fast", max_slack=1)
        assert opts.profile == "fast" and opts.max_slack == 1
        again = CompileOptions.from_json(opts.to_json())
        assert again == opts

    Unknown keys are rejected on every construction path: the dataclass
    ``__init__`` raises ``TypeError``, ``from_json``/``from_dict`` raise
    ``ValueError`` naming the offending keys.
    """

    # ------------------------------------------------------- search shape
    max_ii: int | None = None           # sweep upper bound (None = default_max_ii)
    max_slack: int = 3                  # slack depth of the (II, slack) sweep
    connectivity: str = "strict"        # "strict" | "paper" (DESIGN.md §7)
    backend: str = "auto"               # time backend: auto | cp | z3
    space_backend: str = "auto"         # space backend: auto | exact | anneal (§13)
    seed: int = 0                       # search diversification seed
    # ------------------------------------------------------------ budgets
    time_budget_s: float = 120.0        # total wall budget per compile
    space_timeout_s: float = 0.6        # per space-probe wall cap
    space_polish_timeout_s: float = 2.5  # polish-dive wall cap floor
    space_timeout_growth: float = 1.0   # per-round probe-cap growth factor
    det_space_cap: int = 400_000        # per-round space-node cap (deterministic)
    max_retries_per_window: int = 8     # pending-partition retry width
    window_timeout_s: float = 10.0      # per time-solver-call wall cap
    # -------------------------------------------------------- constraints
    max_register_pressure: int | None = None   # per-PE effective bound: min(this, registers_at(pe))
    max_route_hops: int = 0             # route-through mov budget per edge (0 = direct only)
    # -------------------------------------------------------- determinism
    deterministic: bool = False         # step-budgeted reproducible mode (§6.3)
    # ------------------------------------------------------- cache policy
    use_cache: bool = True              # both mapping-cache layers
    cache_dir: str | None = None        # persistent layer (None = $REPRO_CACHE_DIR)
    # ---------------------------------------------------- window striping
    window_offset: int = 0              # this worker's stripe (service racing)
    window_stride: int = 1              # stripe count
    # ------------------------------------------------------ observability
    trace: bool = False                 # structured span tracing (repro.obs, §15)
    # ------------------------------------------------------ service knobs
    jobs: int | None = None             # batch workers (None = os.cpu_count())
    deadline_s: float | None = None     # per-job wall budget in compile_batch
    racing_workers: int = 1             # compile_racing default worker count
    tenant: str | None = None           # daemon tenant label (provenance, §16)
    # ------------------------------------------------- exact certification
    exact_check: bool = False           # certify/improve each result (§14)
    exact_budget_s: float = 20.0        # wall budget per certification sweep
    # ----------------------------------------------------------- target
    arch: str | None = None             # preset name or ArchSpec JSON path
    # -------------------------------------------------------- provenance
    profile: str | None = None          # profile this value was resolved from

    # ------------------------------------------------------------ validation
    def validate(self) -> None:
        """Raise ValueError on a statically-invalid combination.

        Mapper-internal checks (e.g. ``deterministic`` × z3) stay in the
        mapper — this only rejects what no call could ever accept.
        """
        if self.connectivity not in _CONNECTIVITIES:
            raise ValueError(
                f"connectivity must be one of {_CONNECTIVITIES}, "
                f"got {self.connectivity!r}"
            )
        if self.backend not in _BACKENDS:
            raise ValueError(
                f"backend must be one of {_BACKENDS}, got {self.backend!r}"
            )
        if self.space_backend not in _SPACE_BACKENDS:
            raise ValueError(
                f"space_backend must be one of {_SPACE_BACKENDS}, "
                f"got {self.space_backend!r}"
            )
        if self.space_timeout_s <= 0 or self.space_polish_timeout_s <= 0:
            raise ValueError("space timeouts must be > 0")
        if self.space_timeout_growth < 0:
            raise ValueError("space_timeout_growth must be >= 0")
        if self.det_space_cap < 1:
            raise ValueError(f"det_space_cap must be >= 1, got {self.det_space_cap}")
        if self.max_slack < 0:
            raise ValueError(f"max_slack must be >= 0, got {self.max_slack}")
        if self.max_route_hops < 0:
            raise ValueError(
                f"max_route_hops must be >= 0, got {self.max_route_hops}"
            )
        if self.max_ii is not None and self.max_ii < 1:
            raise ValueError(f"max_ii must be >= 1, got {self.max_ii}")
        if self.time_budget_s <= 0:
            raise ValueError("time_budget_s must be > 0")
        if self.window_stride < 1 or not (0 <= self.window_offset < self.window_stride):
            raise ValueError(
                f"invalid window striping: offset {self.window_offset}, "
                f"stride {self.window_stride}"
            )
        if self.jobs is not None and self.jobs < 1:
            raise ValueError(f"jobs must be >= 1 (or None = auto), got {self.jobs}")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError("deadline_s must be > 0 (or None)")
        if self.racing_workers < 1:
            raise ValueError("racing_workers must be >= 1")
        if self.exact_budget_s <= 0:
            raise ValueError("exact_budget_s must be > 0")
        if self.profile is not None and self.profile not in PROFILES:
            raise ValueError(
                f"unknown profile {self.profile!r} "
                f"(choose from {', '.join(sorted(PROFILES))})"
            )

    # -------------------------------------------------------------- projection
    def replace(self, **changes) -> "CompileOptions":
        """A copy with ``changes`` applied (unknown keys raise TypeError)."""
        return dataclasses.replace(self, **changes)

    def mapper_kwargs(self, *, exclude: tuple[str, ...] = ()) -> dict:
        """The exact kwarg dict :func:`repro.core.mapper.map_dfg` accepts.

        ``exclude`` drops fields the caller owns — e.g. racing strips the
        striping fields because it assigns stripes per worker itself.
        """
        return {
            f: getattr(self, f) for f in MAPPER_FIELDS if f not in exclude
        }

    def batch_kwargs(self) -> dict:
        """Per-job ``map_dfg`` kwargs for the batch service.

        ``deadline_s`` (when set, non-deterministic) replaces the per-job
        ``time_budget_s`` — the service contract: a job's wall budget is its
        deadline, enforced inside the worker (DESIGN.md §8.1).
        """
        kw = self.mapper_kwargs()
        if self.deadline_s is not None and not self.deterministic:
            kw["time_budget_s"] = self.deadline_s
        return kw

    # ------------------------------------------------------------------- I/O
    def as_dict(self) -> dict:
        """All fields as a JSON-compatible dict (the report embedding)."""
        return {f.name: getattr(self, f.name) for f in fields(self)}

    def to_json(self) -> str:
        return json.dumps(self.as_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "CompileOptions":
        """Build from a dict, rejecting unknown keys (missing keys default)."""
        known = {f.name for f in fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(
                f"unknown CompileOptions keys: {', '.join(unknown)}"
            )
        opts = cls(**d)
        opts.validate()
        return opts

    @classmethod
    def from_json(cls, text: str) -> "CompileOptions":
        try:
            d = json.loads(text)
        except ValueError as exc:
            raise ValueError(f"malformed CompileOptions JSON: {exc}") from None
        if not isinstance(d, dict):
            raise ValueError("malformed CompileOptions JSON: not an object")
        return cls.from_dict(d)


# ------------------------------------------------------------------ profiles

#: Named option profiles. ``default`` mirrors the historical ``map_dfg``
#: defaults; ``fast`` trades II quality for latency (interactive / premap
#: warm-up); ``quality`` spends a long budget polishing toward mII;
#: ``deterministic-ci`` is the load-independent reproducible mode CI runs
#: (step budgets, no caches, sequential batch); ``certify`` is ``default``
#: plus the exact joint optimality sweep on every result (DESIGN.md §14).
PROFILES: dict[str, CompileOptions] = {
    "default": CompileOptions(profile="default"),
    "fast": CompileOptions(
        profile="fast",
        time_budget_s=20.0,
        max_slack=2,
        window_timeout_s=5.0,
        max_retries_per_window=4,
    ),
    "quality": CompileOptions(
        profile="quality",
        time_budget_s=300.0,
        max_slack=4,
        window_timeout_s=20.0,
    ),
    "deterministic-ci": CompileOptions(
        profile="deterministic-ci",
        deterministic=True,
        use_cache=False,
        backend="cp",
        jobs=1,
    ),
    "certify": CompileOptions(
        profile="certify",
        exact_check=True,
    ),
}


def resolve_options(profile: str | None = None, **overrides) -> CompileOptions:
    """THE options-resolution path: profile defaults + explicit overrides.

    Every CLI resolves its flags through this function (via
    :func:`options_from_args`), so a flag's meaning cannot drift between
    frontends. Unknown override keys raise TypeError (dataclass ``replace``),
    invalid values raise ValueError (:meth:`CompileOptions.validate`).
    """
    if profile is None:
        base = PROFILES["default"].replace(profile=None)
    else:
        try:
            base = PROFILES[profile]
        except KeyError:
            raise ValueError(
                f"unknown profile {profile!r} "
                f"(choose from {', '.join(sorted(PROFILES))})"
            ) from None
    opts = base.replace(**overrides) if overrides else base
    opts.validate()
    return opts


# ----------------------------------------------------------------- CLI glue

#: Flags installed by :func:`add_cli_args`; each maps 1:1 to an options field.
#: ``None`` in the parsed namespace means "not given → profile value wins".
_CLI_FIELDS = (
    "max_ii",
    "max_slack",
    "connectivity",
    "backend",
    "space_backend",
    "seed",
    "time_budget_s",
    "max_register_pressure",
    "max_route_hops",
    "deterministic",
    "use_cache",
    "cache_dir",
    "jobs",
    "deadline_s",
    "arch",
    "exact_check",
    "exact_budget_s",
)


def add_cli_args(parser: argparse.ArgumentParser) -> None:
    """Install the shared compiler-option flags on ``parser``.

    This is the single definition of the flag set: ``repro.compile``,
    ``benchmarks/run.py`` and both examples call this instead of re-declaring
    flags by hand. Pair with :func:`options_from_args`.
    """
    g = parser.add_argument_group("compiler options (repro.api)")
    g.add_argument("--profile", choices=sorted(PROFILES), default=None,
                   help="named options profile; explicit flags override it")
    g.add_argument("--max-ii", type=int, default=None, dest="max_ii",
                   help="upper II bound of the sweep")
    g.add_argument("--max-slack", type=int, default=None, dest="max_slack",
                   help="slack depth of the (II, slack) sweep")
    g.add_argument("--connectivity", choices=list(_CONNECTIVITIES),
                   default=None)
    g.add_argument("--backend", choices=list(_BACKENDS), default=None,
                   help="time backend")
    g.add_argument("--space-backend", choices=list(_SPACE_BACKENDS),
                   default=None, dest="space_backend",
                   help="space (placement) backend: exact bitset search, "
                        "anneal clustered placement, or auto (fabric-sized)")
    g.add_argument("--seed", type=int, default=None,
                   help="search diversification seed")
    g.add_argument("--time-budget-s", type=float, default=None,
                   dest="time_budget_s", help="wall budget per compile")
    g.add_argument("--max-register-pressure", type=int, default=None,
                   dest="max_register_pressure",
                   help="reject mappings exceeding min(this, registers_at(pe)) "
                        "live values on any PE")
    g.add_argument("--max-route-hops", type=int, default=None,
                   dest="max_route_hops",
                   help="allow routing a dataflow edge through up to this many "
                        "intermediate mov PEs when no direct embedding exists "
                        "(default 0 = paper behaviour)")
    g.add_argument("--deterministic", action="store_true", default=None,
                   help="step-budgeted reproducible mode (bypasses caches)")
    g.add_argument("--no-cache", action="store_false", default=None,
                   dest="use_cache", help="disable both mapping cache layers")
    g.add_argument("--cache-dir", default=None, dest="cache_dir",
                   help="persistent mapping cache directory "
                        "(default: $REPRO_CACHE_DIR if set)")
    g.add_argument("--jobs", type=int, default=None,
                   help="batch worker processes (default: all cores)")
    g.add_argument("--deadline-s", type=float, default=None, dest="deadline_s",
                   help="per-job wall budget in batch compiles")
    g.add_argument("--arch", metavar="PRESET|FILE.json", default=None,
                   help="architecture spec: a named preset "
                        "(repro.core.arch.presets) or an ArchSpec JSON file")
    g.add_argument("--exact-check", action="store_true", default=None,
                   dest="exact_check",
                   help="run the exact joint backend after each compile: "
                        "prove the II optimal or adopt a strictly better "
                        "mapping, and attach the certificate (DESIGN.md §14)")
    g.add_argument("--exact-budget-s", type=float, default=None,
                   dest="exact_budget_s",
                   help="wall budget per certification sweep (default 20)")
    g.add_argument("--trace", metavar="OUT.json", default=None,
                   dest="trace_out",
                   help="record structured compile-pipeline spans and write "
                        "a Perfetto-loadable Chrome trace-event JSON file "
                        "(summarize with tools/trace_report.py; DESIGN.md §15)")


def options_from_args(args: argparse.Namespace) -> CompileOptions:
    """Resolve a parsed namespace into options via :func:`resolve_options`.

    Only flags the user actually passed override the profile; everything
    else keeps the profile's value.
    """
    overrides = {
        f: getattr(args, f)
        for f in _CLI_FIELDS
        if getattr(args, f, None) is not None
    }
    # --trace OUT.json both enables tracing and names the output file; the
    # path itself stays CLI-side (args.trace_out) — options only carry the
    # enable bit so the field stays JSON-round-trippable.
    if getattr(args, "trace_out", None):
        overrides["trace"] = True
    return resolve_options(getattr(args, "profile", None), **overrides)

"""The :class:`Compiler` session: one target + one options value, reused.

A ``Compiler`` binds ``(ArchSpec | CGRA, CompileOptions, caches)`` once and
routes every compile through the existing mapper/service internals
(DESIGN.md §11.2): :meth:`Compiler.compile` is the in-process portfolio
mapper, :meth:`Compiler.compile_batch` fans a workload across the process
pool (``core/service/batch.compile_many``), and :meth:`Compiler.compile_racing`
stripes one hard problem's (II, slack) windows across workers. All three
return the unified :class:`~repro.api.result.CompileResult` schema.
"""

from __future__ import annotations

import dataclasses
import tempfile
import time as _time
from typing import Callable, Iterable, Sequence

from .. import obs
from ..core.arch import ArchSpec, resolve_arch
from ..core.cgra import CGRA
from ..core.dfg import DFG
from ..core.mapper import _map_dfg_impl
from ..core.service.batch import CompileJob, compile_many, map_dfg_racing
from ..core.service.cache import DiskMappingCache, resolve_cache_dir
from .options import CompileOptions, resolve_options
from .result import BatchResult, CompileResult

__all__ = ["Compiler"]


def _resolve_target(target) -> tuple[ArchSpec | None, CGRA]:
    """Normalise a target into (spec | None, cgra).

    Accepts a :class:`CGRA` (spec is None), an :class:`ArchSpec`, or a string
    (preset name / ArchSpec JSON path, via ``resolve_arch``) — the same
    resolution every CLI's ``--arch`` flag uses.
    """
    if isinstance(target, CGRA):
        return None, target
    if isinstance(target, ArchSpec):
        return target, target.cgra()
    if isinstance(target, str):
        spec = resolve_arch(target)
        return spec, spec.cgra()
    raise TypeError(
        f"target must be a CGRA, ArchSpec, or preset/path string, "
        f"got {type(target).__name__}"
    )


class Compiler:
    """A compilation session bound to one target machine and one policy.

    Example — a deterministic session over the SAT-MapIt-style preset::

        from repro.api import Compiler, resolve_options
        from repro.core import running_example

        comp = Compiler("satmapit_edge_mem_4x4",
                        resolve_options("deterministic-ci"))
        res = comp.compile(running_example())
        assert res.ok and res.mapping.validate() == []
        batch = comp.compile_batch([running_example()])
        assert batch.ok and batch.results[0].ii == res.ii

    Parameters:

    * ``target`` — a :class:`~repro.core.cgra.CGRA`, an
      :class:`~repro.core.arch.ArchSpec`, or a preset-name/JSON-path string;
      ``None`` falls back to ``options.arch`` (one of the two must name a
      machine).
    * ``options`` — a :class:`~repro.api.options.CompileOptions`, a profile
      name, or ``None`` (profile defaults); extra ``**overrides`` are applied
      on top via :func:`~repro.api.options.resolve_options` semantics.

    The session's persistent cache handle is exposed as :attr:`cache`
    (``None`` when no cache directory is configured) for pre-warming and
    inspection; compiles share its files through the content-addressed store
    (DESIGN.md §9).
    """

    def __init__(self, target=None, options=None, **overrides) -> None:
        if isinstance(options, str):
            options = resolve_options(options)
        elif options is None:
            options = resolve_options()
        elif not isinstance(options, CompileOptions):
            raise TypeError(
                f"options must be CompileOptions, a profile name, or None, "
                f"got {type(options).__name__}"
            )
        if overrides:
            options = options.replace(**overrides)
        options.validate()
        if target is None:
            if options.arch is None:
                raise ValueError(
                    "no target machine: pass target= or set options.arch"
                )
            target = options.arch
        self.spec, self.cgra = _resolve_target(target)
        self.options = options
        self._cache: DiskMappingCache | None = None
        if options.use_cache:
            root = resolve_cache_dir(options.cache_dir)
            if root is not None:
                self._cache = DiskMappingCache(root)

    # ------------------------------------------------------------- properties
    @property
    def cache(self) -> DiskMappingCache | None:
        """The session's persistent mapping-cache handle (or None).

        One stable object per session — compiles running in this process or
        in pool workers share its *files* (content-addressed, DESIGN.md §9)
        while its ``stats`` count only operations made through this handle.
        """
        return self._cache

    def validate_workload(self, dfgs: Iterable[DFG]) -> list[str]:
        """Feasibility problems of a workload against this target (empty =
        every op class has a capable PE); mirrors ``ArchSpec.validate_for``."""
        return sorted({p for d in dfgs for p in self.cgra.unsupported_ops(d)})

    def _opts(self, overrides: dict) -> CompileOptions:
        if not overrides:
            return self.options
        opts = self.options.replace(**overrides)
        opts.validate()
        return opts

    # --------------------------------------------------------- certification
    def _certify(self, dfg: DFG, result: CompileResult,
                 opts: CompileOptions) -> None:
        """Exact-check post-pass (DESIGN.md §14.4): attach a certificate to
        a successful result, adopting the joint backend's mapping when it
        strictly beats the portfolio's II.

        Adopted mappings are written into both mapping-cache layers under
        the portfolio's own key, so the next compile of this kernel serves
        the certified-optimal II instead of re-discovering it (skipped in
        deterministic mode, where the mapper bypasses caches entirely).
        """
        if not result.ok or result.mapping is None:
            return
        from ..core.exact_backends import certify_mapping
        from ..core.mapper import cache_store_mapping

        t0 = _time.perf_counter()
        with obs.span("certify", kernel=dfg.name, ii=result.ii) as sp:
            cert, better = certify_mapping(
                dfg, self.cgra, result.mapping,
                connectivity=opts.connectivity,
                max_route_hops=opts.max_route_hops,
                max_register_pressure=opts.max_register_pressure,
                budget_s=opts.exact_budget_s,
                deterministic=opts.deterministic,
            )
            sp.set(ii_opt=cert.ii_opt, adopted=better is not None)
        if better is not None:
            result.mapping = better
            result.ii = better.ii
            result.route_movs = better.num_route_movs
            result.space_backend = "joint"
            if opts.use_cache and not opts.deterministic:
                cache_store_mapping(
                    dfg, self.cgra, better,
                    connectivity=opts.connectivity,
                    max_register_pressure=opts.max_register_pressure,
                    max_route_hops=opts.max_route_hops,
                    space_backend=opts.space_backend,
                    cache_dir=opts.cache_dir,
                )
        result.ii_opt = cert.ii_opt
        result.certificate = cert.as_dict()
        # book the certification post-pass as its own phase (§14.4 / §15.3):
        # without this, certify wall time silently inflates nothing — it was
        # simply unaccounted — so total_s under-reported the compile
        dt = _time.perf_counter() - t0
        result.phases = dataclasses.replace(
            result.phases,
            exact_s=result.phases.exact_s + dt,
            total_s=result.phases.total_s + dt,
        )
        result.wall_s += dt
        result.metrics["phases"] = result.phases.as_dict()

    # --------------------------------------------------------------- compile
    def compile(
        self,
        dfg: DFG,
        *,
        should_stop: Callable[[], bool] | None = None,
        **overrides,
    ) -> CompileResult:
        """Map one DFG in-process through the portfolio mapper.

        ``should_stop`` is the cooperative-cancellation hook forwarded to the
        mapper; ``**overrides`` are per-call option changes (e.g.
        ``time_budget_s=5``) that do not mutate the session.
        """
        opts = self._opts(overrides)
        with obs.span("compile", kernel=dfg.name) as sp:
            res = _map_dfg_impl(
                dfg, self.cgra, should_stop=should_stop,
                **opts.mapper_kwargs()
            )
            result = CompileResult.from_map_result(res, name=dfg.name)
            if opts.exact_check:
                self._certify(dfg, result, opts)
            sp.set(ok=result.ok, ii=result.ii)
        return result

    def compile_batch(
        self,
        dfgs: Sequence[DFG],
        *,
        names: Sequence[str] | None = None,
        cancel=None,
        **overrides,
    ) -> BatchResult:
        """Map a workload across the process pool (DESIGN.md §8.1).

        ``options.jobs`` picks the worker count (None = all cores; 1 =
        sequential in-process, the deterministic-CI mode), ``options.
        deadline_s`` the per-job wall budget, and ``cancel`` an Event-like
        object for cooperative cancellation. Rows come back in input order.
        """
        opts = self._opts(overrides)
        if names is not None and len(names) != len(dfgs):
            raise ValueError(
                f"names has {len(names)} entries for {len(dfgs)} DFGs"
            )
        names = names or [d.name for d in dfgs]
        batch = [
            CompileJob(dfg, self.cgra, name=name)
            for dfg, name in zip(dfgs, names)
        ]
        t0 = _time.perf_counter()
        # cross-process span shards (DESIGN.md §15.2): pool workers append
        # per-pid shard files into a scratch dir that we merge back into this
        # process's tracer; the inline path (jobs<=1) records directly into
        # the active tracer and writes no shards
        tracer = obs.get_tracer()
        trace_tmp = (tempfile.TemporaryDirectory(prefix="repro-spans-")
                     if tracer is not None else None)
        try:
            report = compile_many(
                batch,
                jobs=opts.jobs,
                deterministic=opts.deterministic,
                cache_dir=opts.cache_dir,
                use_cache=opts.use_cache,
                cancel=cancel,
                map_options=opts.batch_kwargs(),
                trace_dir=trace_tmp.name if trace_tmp is not None else None,
            )
        finally:
            if trace_tmp is not None:
                events, counters = obs.merge_shards(trace_tmp.name)
                tracer.adopt(events)
                for key, n in counters.items():
                    tracer.counters[key] = tracer.counters.get(key, 0) + n
                trace_tmp.cleanup()
        result = BatchResult.from_report(
            report, pairs=[(job.dfg, job.cgra) for job in batch],
            max_register_pressure=opts.max_register_pressure,
        )
        if opts.exact_check:
            # certification is a caller-side post-pass (sequential, in
            # process): worker rows stay lean and the sweep sees the exact
            # reconstructed mapping every row was re-validated with
            for job, row in zip(batch, result.results):
                self._certify(job.dfg, row, opts)
        result.wall_s = _time.perf_counter() - t0
        return result

    def compile_racing(
        self,
        dfg: DFG,
        *,
        workers: int | None = None,
        **overrides,
    ) -> CompileResult:
        """Race one mapping's (II, slack) windows across workers (§8.2).

        ``workers`` defaults to ``options.racing_workers``; deterministic
        sessions fall back to the plain in-process compile (a wall-clock race
        cannot honor the reproducibility contract).
        """
        opts = self._opts(overrides)
        res = map_dfg_racing(
            dfg,
            self.cgra,
            workers=workers if workers is not None else opts.racing_workers,
            **opts.mapper_kwargs(exclude=("window_offset", "window_stride")),
        )
        return CompileResult.from_map_result(
            res, name=dfg.name, wall_s=res.stats.total_s
        )

    def __repr__(self) -> str:  # pragma: no cover
        tgt = self.spec.name if self.spec is not None else str(self.cgra)
        prof = self.options.profile or "custom"
        return f"Compiler(target={tgt}, options={prof})"

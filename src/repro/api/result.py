"""Structured compile outcomes: :class:`CompileResult` / :class:`BatchResult`.

One result schema unifies what used to diverge between the mapper's
``MapResult`` and the service's ``JobReport``/``CompileReport`` (DESIGN.md
§11.3): per-phase timings (time search, space search, validation), the
window/backoff trace, cache provenance, and a *machine-readable* failure
code next to the human-readable reason. ``CompileResult.as_dict()`` is the
canonical row serialisation — the CLI JSON report, the benchmark artifacts,
and service rows all emit exactly this shape.

Failure codes (:data:`FAILURE_KINDS`):

* ``infeasible`` — structurally impossible (an op class with no capable PE);
* ``budget-exhausted`` — the wall/step budget ran out before a mapping;
* ``search-exhausted`` — the whole (II, slack) space was proven empty;
* ``cancelled`` — cooperative cancellation (service stop event, or a
  daemon request whose deadline expired while still queued);
* ``overloaded`` — shed by daemon admission control before any solving
  (queue full / deadline budget exceeded, DESIGN.md §16.2) — the caller
  should back off and retry;
* ``worker-lost`` — a pool worker died mid-solve and the job could not be
  recovered after the one pool respawn (DESIGN.md §8.1);
* ``error`` — the compile raised (bad DFG, worker death, cache I/O);
* ``unknown`` — anything the classifier cannot attribute.
"""

from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass, field
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # imported lazily at runtime to keep this module cheap
    from ..core.cgra import CGRA
    from ..core.dfg import DFG
    from ..core.mapper import Mapping, MapResult
    from ..core.service.batch import CompileReport, JobReport

__all__ = [
    "BatchResult",
    "CompileResult",
    "FAILURE_KINDS",
    "PhaseTimings",
    "SearchTrace",
    "build_metrics",
    "classify_failure",
]

FAILURE_KINDS = (
    "infeasible",
    "budget-exhausted",
    "search-exhausted",
    "cancelled",
    "overloaded",
    "worker-lost",
    "error",
    "unknown",
)

# exception rows are formatted f"{type(exc).__name__}: {exc}" by the service
# layer; every mapper-produced reason starts lowercase, so an uppercase-
# leading identifier + colon is unambiguous (covers BrokenProcessPool,
# TimeoutError, KeyboardInterrupt, custom exception names alike)
_EXC_REASON_RE = re.compile(r"^[A-Z][A-Za-z0-9_]*: ")


def classify_failure(ok: bool, reason: str, cancelled: bool = False) -> str | None:
    """Map a human-readable failure reason to a machine-readable code.

    Returns None for successful compiles. The classifier is anchored on the
    reason strings the mapper/service actually produce (``core/mapper.py``
    ``finish()``/capability fail-fast, ``core/service/batch.py`` error rows);
    anything unrecognised lands in ``unknown`` rather than raising.
    """
    if ok:
        return None
    if cancelled:
        return "cancelled"
    r = reason or ""
    if r.startswith("overloaded"):
        return "overloaded"
    if r.startswith("worker lost"):
        return "worker-lost"
    if r.startswith("infeasible"):
        return "infeasible"
    if "search space exhausted" in r:
        return "search-exhausted"
    if "budget exhausted" in r or "within budget" in r:
        return "budget-exhausted"
    if "cancelled" in r:
        return "cancelled"
    if _EXC_REASON_RE.match(r):
        return "error"
    return "unknown"


@dataclass(frozen=True)
class PhaseTimings:
    """Wall seconds per pipeline phase (DESIGN.md §1 stages + validation)."""

    time_s: float = 0.0        # TIME: modulo-schedule search
    space_s: float = 0.0       # SPACE: monomorphism search
    validate_s: float = 0.0    # independent re-validation of candidate/served mappings
    exact_s: float = 0.0       # exact-check certification post-pass (§14)
    total_s: float = 0.0       # whole compile() call (incl. exact_s when run)

    def as_dict(self) -> dict:
        return {
            "time_s": round(self.time_s, 6),
            "space_s": round(self.space_s, 6),
            "validate_s": round(self.validate_s, 6),
            "exact_s": round(self.exact_s, 6),
            "total_s": round(self.total_s, 6),
        }


@dataclass(frozen=True)
class SearchTrace:
    """Window/backoff trace of the portfolio search (DESIGN.md §6)."""

    rounds: int = 0                 # portfolio rounds entered
    windows_opened: int = 0         # (II, slack) windows that got a time solver
    time_solutions_tried: int = 0   # label partitions proposed by TIME
    mono_failures: int = 0          # partitions SPACE failed to embed (backoffs)
    space_nodes_visited: int = 0    # monomorphism search nodes

    def as_dict(self) -> dict:
        return {
            "rounds": self.rounds,
            "windows_opened": self.windows_opened,
            "time_solutions_tried": self.time_solutions_tried,
            "mono_failures": self.mono_failures,
            "space_nodes_visited": self.space_nodes_visited,
        }


def _hit_rate(hits: int, lookups: int) -> float | None:
    return round(hits / lookups, 6) if lookups else None


def build_metrics(
    *,
    trace: "SearchTrace",
    phases: "PhaseTimings",
    time_steps: int = 0,
    space_restarts: int = 0,
    mem_lookups: int = 0,
    mem_hits: int = 0,
    disk_lookups: int = 0,
    disk_hits: int = 0,
    disk_promotions: int = 0,
) -> dict:
    """The aggregated per-row ``metrics`` block (DESIGN.md §15.3).

    One builder serves every frontend: ``from_map_result`` (CLI/bench
    in-process rows) and ``from_job_report`` (service rows) both call it
    with counters their respective stats objects carry, so the schema
    cannot diverge between outputs. ``hit_rate`` is None when the layer
    was never consulted (``use_cache=False`` / deterministic runs).
    """
    return {
        "solver": {
            "rounds": trace.rounds,
            "windows_opened": trace.windows_opened,
            "time_solutions_tried": trace.time_solutions_tried,
            "time_steps": time_steps,
            "mono_failures": trace.mono_failures,
            "space_nodes_visited": trace.space_nodes_visited,
            "space_restarts": space_restarts,
        },
        "cache": {
            "memory": {
                "lookups": mem_lookups,
                "hits": mem_hits,
                "hit_rate": _hit_rate(mem_hits, mem_lookups),
            },
            "disk": {
                "lookups": disk_lookups,
                "hits": disk_hits,
                "promotions": disk_promotions,
                "hit_rate": _hit_rate(disk_hits, disk_lookups),
            },
        },
        "phases": phases.as_dict(),
    }


@dataclass
class CompileResult:
    """One compile outcome in the unified schema (DESIGN.md §11.3).

    Example — compile and read the structured telemetry::

        from repro.api import Compiler, resolve_options
        from repro.core import CGRA, running_example

        comp = Compiler(CGRA(2, 2), resolve_options("deterministic-ci"))
        res = comp.compile(running_example())
        assert res.ok and res.ii == 4 and res.source == "solve"
        row = res.as_dict()          # the exact JSON row every frontend emits
        assert row["phases"]["time_s"] >= 0 and row["failure"] is None

    ``mapping`` is the full space-time mapping when available (always for
    in-process compiles; reconstructed from the worker's row for batch
    compiles), or None on failure.
    """

    name: str
    ok: bool
    ii: int | None = None
    m_ii: int = -1
    res_ii: int = -1
    rec_ii: int = -1
    backend: str = ""
    #: space (placement) engine that produced the mapping ("" when failed)
    space_backend: str = ""
    #: cache provenance: "memory" | "disk" | "solve" (None when failed)
    source: str | None = None
    wall_s: float = 0.0
    phases: PhaseTimings = field(default_factory=PhaseTimings)
    trace: SearchTrace = field(default_factory=SearchTrace)
    #: machine-readable failure code (see FAILURE_KINDS); None when ok
    failure: str | None = None
    reason: str = ""
    cancelled: bool = False
    #: route-through movs spliced into the mapping (0 = direct embedding)
    route_movs: int = 0
    #: optional ``simulate.utilization_report`` block (opt-in, see compile CLI)
    utilization: dict | None = None
    #: optional daemon/service provenance block (DESIGN.md §16.4): tenant,
    #: deadline, queue wait, coalescing and speculative-warm attribution —
    #: set only by the compile daemon, absent from in-process rows
    service: dict | None = None
    #: certified optimal II (exact-check runs; None = not proven / not run)
    ii_opt: int | None = None
    #: optimality certificate dict (``exact_backends.Certificate.as_dict``,
    #: DESIGN.md §14) — present only when the compile ran with exact_check
    certificate: dict | None = None
    #: aggregated observability block (:func:`build_metrics`, DESIGN.md §15.3):
    #: solver counters, both cache layers' hit rates, per-phase rollups —
    #: always emitted with an identical schema in CLI, bench, and service rows
    metrics: dict = field(default_factory=dict)
    mapping: "Mapping | None" = None

    # ------------------------------------------------------------ constructors
    @classmethod
    def from_map_result(
        cls, res: "MapResult", *, name: str = "", wall_s: float | None = None
    ) -> "CompileResult":
        """Lift a mapper ``MapResult`` into the unified schema."""
        s = res.stats
        if res.ok:
            source = ("memory" if s.cache_hit
                      else "disk" if s.disk_cache_hit else "solve")
        else:
            source = None
        phases = PhaseTimings(
            time_s=s.time_phase_s,
            space_s=s.space_phase_s,
            validate_s=s.validate_s,
            total_s=s.total_s,
        )
        trace = SearchTrace(
            rounds=s.rounds,
            windows_opened=s.windows_opened,
            time_solutions_tried=s.time_solutions_tried,
            mono_failures=s.mono_failures,
            space_nodes_visited=s.space_nodes_visited,
        )
        return cls(
            name=name or (res.mapping.dfg.name if res.ok else name),
            ok=res.ok,
            ii=res.mapping.ii if res.ok else None,
            m_ii=s.m_ii,
            res_ii=s.res_ii,
            rec_ii=s.rec_ii,
            backend=s.backend,
            space_backend=s.space_backend,
            source=source,
            wall_s=wall_s if wall_s is not None else s.total_s,
            phases=phases,
            trace=trace,
            failure=classify_failure(res.ok, res.reason),
            reason=res.reason,
            route_movs=res.mapping.num_route_movs if res.ok else 0,
            metrics=build_metrics(
                trace=trace,
                phases=phases,
                time_steps=s.time_steps,
                space_restarts=s.space_restarts,
                mem_lookups=s.mem_cache_lookups,
                mem_hits=s.mem_cache_hits,
                disk_lookups=s.disk_cache_lookups,
                disk_hits=s.disk_cache_hits,
                disk_promotions=s.disk_cache_promotions,
            ),
            mapping=res.mapping,
        )

    @classmethod
    def from_job_report(
        cls, job: "JobReport", dfg: "DFG | None" = None,
        cgra: "CGRA | None" = None, *,
        max_register_pressure: int | None = None,
    ) -> "CompileResult":
        """Lift a service row; reconstructs the Mapping when the worker
        shipped ``t_abs``/``placement`` (plus any route-through spec) back
        and the caller provides the (unpickled-once) DFG/CGRA pair.

        Reconstructed mappings are re-validated on the caller's side with
        the same checks the direct path runs — structure always, and the
        per-PE register guarantee (``min(max_register_pressure,
        registers_at(pe))``) whenever the batch requested one — so a stale
        worker cache or a version-skewed worker can never make the batch
        path accept what ``Compiler.compile`` would reject. A row failing
        re-validation is flipped to a failure (``failure == "error"``)."""
        mapping = None
        if (job.ok and dfg is not None and cgra is not None
                and job.t_abs is not None and job.placement is not None
                and job.ii is not None):
            from ..core.dfg import splice_routes
            from ..core.mapper import Mapping, _pressure_offenders

            try:
                routes = []
                if job.routes:
                    dfg, routes = splice_routes(
                        dfg, [tuple(r) for r in job.routes]
                    )
                mapping = Mapping(dfg=dfg, cgra=cgra, ii=job.ii,
                                  t_abs=list(job.t_abs),
                                  placement=list(job.placement),
                                  routes=routes)
                errs = mapping.validate(registers=False)
                if not errs and max_register_pressure is not None:
                    errs = [
                        f"register pressure over effective bound on PE {pe}"
                        for pe in _pressure_offenders(
                            mapping, max_register_pressure)
                    ]
            except (ValueError, IndexError) as exc:
                errs = [f"malformed worker mapping: {exc}"]
            if errs:
                job = dataclasses.replace(
                    job, ok=False, ii=None, t_abs=None, placement=None,
                    routes=None,
                    reason="ValidationError: worker mapping rejected "
                           f"caller-side: {'; '.join(errs)}",
                )
                mapping = None
        if job.ok:
            source = ("memory" if job.cache_hit
                      else "disk" if job.disk_cache_hit else "solve")
        else:
            source = None
        phases = PhaseTimings(
            time_s=job.time_phase_s,
            space_s=job.space_phase_s,
            validate_s=job.validate_s,
            total_s=job.wall_s,
        )
        trace = SearchTrace(
            rounds=job.rounds,
            windows_opened=job.windows_opened,
            time_solutions_tried=job.time_solutions_tried,
            mono_failures=job.mono_failures,
            space_nodes_visited=job.space_nodes_visited,
        )
        return cls(
            name=job.name,
            ok=job.ok,
            ii=job.ii,
            m_ii=job.m_ii,
            res_ii=job.res_ii,
            rec_ii=job.rec_ii,
            backend=job.backend,
            space_backend=job.space_backend,
            source=source,
            wall_s=job.wall_s,
            phases=phases,
            trace=trace,
            failure=classify_failure(job.ok, job.reason, job.cancelled),
            reason=job.reason,
            cancelled=job.cancelled,
            route_movs=mapping.num_route_movs if mapping is not None else 0,
            metrics=build_metrics(
                trace=trace,
                phases=phases,
                time_steps=job.time_steps,
                space_restarts=job.space_restarts,
                mem_lookups=job.mem_cache_lookups,
                mem_hits=job.mem_cache_hits,
                disk_lookups=job.disk_cache_lookups,
                disk_hits=job.disk_cache_hits,
                disk_promotions=job.disk_cache_promotions,
            ),
            mapping=mapping,
        )

    # -------------------------------------------------------------------- I/O
    def as_dict(self) -> dict:
        """The canonical JSON row (CLI report, benchmarks, service rows).

        The ``utilization`` key is opt-in (only present when the block was
        computed, e.g. ``repro.compile --report-utilization``) so existing
        row consumers keep seeing the exact historical shape by default.
        """
        row = {
            "name": self.name,
            "ok": self.ok,
            "ii": self.ii,
            "mII": self.m_ii,
            "resII": self.res_ii,
            "recII": self.rec_ii,
            "backend": self.backend,
            "space_backend": self.space_backend,
            "source": self.source,
            "wall_s": round(self.wall_s, 6),
            "phases": self.phases.as_dict(),
            "trace": self.trace.as_dict(),
            "failure": self.failure,
            "reason": self.reason,
            "cancelled": self.cancelled,
            "route_movs": self.route_movs,
            "metrics": self.metrics or build_metrics(
                trace=self.trace, phases=self.phases),
        }
        if self.utilization is not None:
            row["utilization"] = self.utilization
        if self.service is not None:
            # daemon rows only (DESIGN.md §16.4): tenant/deadline/queue/
            # coalescing provenance; plain compiles keep the historical shape
            row["service"] = self.service
        if self.certificate is not None:
            # exact-check rows (DESIGN.md §14.4): the certified-optimal II
            # (None while status is "timeout") next to the full certificate
            row["ii_opt"] = self.ii_opt
            row["certificate"] = self.certificate
        return row


@dataclass
class BatchResult:
    """A batch of :class:`CompileResult` rows + aggregate counters."""

    results: list[CompileResult]
    wall_s: float = 0.0
    num_workers: int = 1

    @property
    def ok(self) -> bool:
        return all(r.ok for r in self.results)

    @property
    def cache_counters(self) -> dict:
        """Aggregate provenance counters (memory/disk/solved/failed)."""
        return {
            "memory_hits": sum(r.source == "memory" for r in self.results),
            "disk_hits": sum(r.source == "disk" for r in self.results),
            "solved": sum(r.source == "solve" for r in self.results),
            "failed": sum(not r.ok for r in self.results),
        }

    @property
    def metrics(self) -> dict:
        """Batch-level rollup of the per-row metrics blocks (§15.3):
        summed solver counters and both cache layers' aggregate hit rates
        (the ROADMAP compile-daemon "hit-rate telemetry" numbers)."""
        rows = [r.metrics for r in self.results if r.metrics]
        solver: dict[str, int] = {}
        cache = {
            "memory": {"lookups": 0, "hits": 0},
            "disk": {"lookups": 0, "hits": 0, "promotions": 0},
        }
        for m in rows:
            for k, v in m.get("solver", {}).items():
                solver[k] = solver.get(k, 0) + v
            for layer, counters in cache.items():
                src = m.get("cache", {}).get(layer, {})
                for k in counters:
                    counters[k] += src.get(k, 0) or 0
        for layer, counters in cache.items():
            counters["hit_rate"] = _hit_rate(
                counters["hits"], counters["lookups"])
        return {"solver": solver, "cache": cache}

    def __iter__(self):
        return iter(self.results)

    def __len__(self) -> int:
        return len(self.results)

    @classmethod
    def from_report(
        cls, report: "CompileReport", pairs=None, *,
        max_register_pressure: int | None = None,
    ) -> "BatchResult":
        """Lift a service ``CompileReport``; ``pairs`` is the matching list
        of (dfg, cgra) used to reconstruct mappings from worker rows, and
        ``max_register_pressure`` the batch's per-PE pressure guarantee
        (rows failing caller-side re-validation become failures)."""
        pairs = pairs or [(None, None)] * len(report.jobs)
        return cls(
            results=[
                CompileResult.from_job_report(
                    j, dfg, cgra,
                    max_register_pressure=max_register_pressure,
                )
                for j, (dfg, cgra) in zip(report.jobs, pairs)
            ],
            wall_s=report.wall_s,
            num_workers=report.num_workers,
        )

    def as_dict(self) -> dict:
        return {
            "ok": self.ok,
            "wall_s": round(self.wall_s, 4),
            "num_workers": self.num_workers,
            "cache": self.cache_counters,
            "metrics": self.metrics,
            "jobs": [r.as_dict() for r in self.results],
        }

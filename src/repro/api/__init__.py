"""Stable public compiler API (DESIGN.md §11).

The one import users and frontends should reach for::

    from repro.api import Compiler, CompileOptions, resolve_options

    comp = Compiler("satmapit_edge_mem_4x4", resolve_options("fast"))
    res = comp.compile(dfg)            # -> CompileResult (unified schema)
    batch = comp.compile_batch(dfgs)   # -> BatchResult (process pool)

Three pieces:

* :class:`~repro.api.options.CompileOptions` — frozen, JSON-round-trippable
  configuration with named profiles (``fast`` / ``quality`` /
  ``deterministic-ci``) and the single CLI flag definition
  (:func:`~repro.api.options.add_cli_args` /
  :func:`~repro.api.options.resolve_options`).
* :class:`~repro.api.compiler.Compiler` — a session binding
  ``(target, options, caches)`` with ``compile`` / ``compile_batch`` /
  ``compile_racing`` routed to the mapper and service internals.
* :class:`~repro.api.result.CompileResult` — the unified structured outcome
  (phase timings, search trace, cache provenance, machine-readable failure
  codes) serialised identically by every frontend.

``repro.core.map_dfg(**kwargs)`` remains as a thin compatibility shim that
builds a ``CompileOptions`` and delegates — old call sites keep working and
stay bit-identical (see ``tests/test_api.py`` parity tests).
"""

from .compiler import Compiler
from .options import (
    MAPPER_FIELDS,
    PROFILES,
    SERVICE_FIELDS,
    CompileOptions,
    add_cli_args,
    options_from_args,
    resolve_options,
)
from .result import (
    FAILURE_KINDS,
    BatchResult,
    CompileResult,
    PhaseTimings,
    SearchTrace,
    classify_failure,
)

__all__ = [
    "Compiler",
    "CompileOptions",
    "CompileResult",
    "BatchResult",
    "PhaseTimings",
    "SearchTrace",
    "PROFILES",
    "MAPPER_FIELDS",
    "SERVICE_FIELDS",
    "FAILURE_KINDS",
    "add_cli_args",
    "options_from_args",
    "resolve_options",
    "classify_failure",
]

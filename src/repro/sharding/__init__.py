"""Sharding rules for pjit distribution."""

from .rules import batch_shardings, cache_shardings, param_shardings, spec_for_param

__all__ = ["batch_shardings", "cache_shardings", "param_shardings", "spec_for_param"]

"""Logical-axis sharding rules: parameter/activation paths -> PartitionSpecs.

Rules pattern-match the *last key* of each parameter path (the layers use a
stable naming convention) and align to the trailing dims, so stacked-layer
params ([L, ...]) pick up a leading None automatically. A dim is only sharded
if its size is divisible by the product of the requested mesh axes AND at
least ``min_shard_size`` — small tensors (norms, gates, tiny models) stay
replicated rather than forcing XLA into pathological reshard chains.

TP layout: column-parallel in-projections (w_q/w_k/w_v/w_up/w_gate...),
row-parallel out-projections (w_o/w_down), vocab-sharded embedding + head,
expert-sharded MoE tensors (EP), everything else replicated. DP/ZeRO handling
for optimizer state lives in optim/adamw.py (extra 'data' sharding).
"""

from __future__ import annotations

import math
import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (last-key regex, trailing spec) — first match wins. 'M' = model axis.
_RULES: list[tuple[str, tuple]] = [
    (r"^(embed)$", ("M", None)),
    (r"^(meta_tokens|pos_embed)$", (None, None)),
    (r"^(lm_head)$", (None, "M")),
    (r"^(w_q|w_k|w_v|w_uq|w_uk|w_uv|w_gate|w_up|w_if|w_b|w_c|w_dt|w_x)$", (None, "M")),
    (r"^(shared_gate|shared_up)$", (None, "M")),
    (r"^(w_o|w_down|shared_down)$", ("M", None)),
    (r"^(expert_gate|expert_up|expert_down)$", ("M", None, None)),
    (r"^(w_dq|w_dkv|router|mtp_proj)$", (None, None)),
    (r"^(r_h)$", (None, None, None)),
]


def spec_for_param(
    path: str,
    shape: tuple[int, ...],
    *,
    model_axis: str | tuple[str, ...] = "model",
    model_size: int = 1,
    min_shard_size: int = 256,
) -> P:
    key = path.split("/")[-1]
    for pattern, trailing in _RULES:
        if re.match(pattern, key):
            spec = [None] * (len(shape) - len(trailing)) + [
                (model_axis if t == "M" else None) for t in trailing
            ]
            # divisibility gate per dim; size gate on the whole tensor (a
            # 64-expert dim on a huge tensor must still shard)
            total = math.prod(shape) if shape else 0
            for i, ax in enumerate(spec):
                if ax is None:
                    continue
                if shape[i] % model_size or total < min_shard_size:
                    spec[i] = None
            return P(*spec)
    return P()  # replicated (norms, biases, scalars)


def _paths(tree: Any, prefix: str = "") -> Any:
    """Mirror pytree with 'a/b/c' path strings at the leaves."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, _: prefix + "/".join(_key_str(k) for k in kp), tree
    )


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def param_shardings(
    params_shape: Any,
    mesh: Mesh,
    *,
    model_axis: str = "model",
    min_shard_size: int = 256,
    fsdp_threshold_bytes: float = 4e9,
    force_fsdp: bool | None = None,
    replicate_patterns: tuple[str, ...] = (),
    expert_axes: tuple[str, ...] | None = None,
) -> Any:
    """NamedShardings for a params pytree (of arrays or ShapeDtypeStructs).

    If the TP-sharded per-device parameter footprint exceeds
    ``fsdp_threshold_bytes``, large tensors additionally shard their biggest
    free dim over the data axes (FSDP/ZeRO-3): XLA all-gathers weights per use
    and reduce-scatters their grads — mandatory for the 671B-class config to
    fit HBM, unnecessary overhead for small models (hence the gate).
    """
    model_size = mesh.shape[model_axis]
    data_axes = tuple(a for a in mesh.axis_names if a != model_axis)
    dsize = math.prod(mesh.shape[a] for a in data_axes)
    paths = _paths(params_shape)

    ep_size = math.prod(mesh.shape[a] for a in expert_axes) if expert_axes else 0

    def base_spec(leaf, path):
        key = path.split("/")[-1]
        if any(re.match(p, key) for p in replicate_patterns):
            return P(*([None] * leaf.ndim))
        if expert_axes is not None:
            # full-EP serving layout: expert/shared-FFN tensors sharded over
            # every mesh axis (weights stationary; see models/build.py)
            if re.match(r"^(expert_gate|expert_up|expert_down)$", key):
                if leaf.shape[-3] % ep_size == 0:
                    return P(*([None] * (leaf.ndim - 3)), expert_axes, None, None)
            if re.match(r"^(shared_gate|shared_up)$", key):
                if leaf.shape[-1] % ep_size == 0:
                    return P(*([None] * (leaf.ndim - 1)), expert_axes)
            if re.match(r"^(shared_down)$", key):
                if leaf.shape[-2] % ep_size == 0:
                    return P(*([None] * (leaf.ndim - 2)), expert_axes, None)
        return spec_for_param(
            path, leaf.shape,
            model_axis=model_axis, model_size=model_size,
            min_shard_size=min_shard_size,
        )

    def per_dev_bytes(leaf, spec):
        n = math.prod(leaf.shape)
        for i, ax in enumerate(spec):
            if ax == model_axis:
                n //= model_size
        return n * leaf.dtype.itemsize

    leaves = jax.tree.leaves(params_shape)
    specs = jax.tree.leaves(jax.tree.map(base_spec, params_shape, paths))
    total_per_dev = sum(per_dev_bytes(l, s) for l, s in zip(leaves, specs))
    use_fsdp = (
        force_fsdp if force_fsdp is not None
        else total_per_dev > fsdp_threshold_bytes
    )

    def final_spec(leaf, path):
        spec = list(base_spec(leaf, path))
        spec += [None] * (leaf.ndim - len(spec))
        used = {
            a
            for s in spec
            if s is not None
            for a in (s if isinstance(s, tuple) else (s,))
        }
        if (
            use_fsdp
            and math.prod(leaf.shape) >= 2**20
            and not any(a in used for a in data_axes)
        ):
            best, best_size = -1, 0
            for i, (ax, dim) in enumerate(zip(spec, leaf.shape)):
                if ax is None and dim % dsize == 0 and dim > best_size:
                    best, best_size = i, dim
            if best >= 0:
                spec[best] = data_axes if len(data_axes) > 1 else data_axes[0]
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(final_spec, params_shape, paths)


def batch_shardings(batch_specs: Any, mesh: Mesh, data_axes: tuple[str, ...]) -> Any:
    """Inputs: shard dim0 (global batch) over the data axes when divisible."""
    dsize = math.prod(mesh.shape[a] for a in data_axes)

    def spec(leaf):
        if leaf.ndim >= 1 and leaf.shape[0] % dsize == 0 and leaf.shape[0] >= dsize:
            return NamedSharding(mesh, P(data_axes, *([None] * (leaf.ndim - 1))))
        return NamedSharding(mesh, P())

    return jax.tree.map(spec, batch_specs)


def cache_shardings(
    caches: Any,
    mesh: Mesh,
    data_axes: tuple[str, ...],
    *,
    model_axis: str = "model",
    seq_dim_by_rank: dict[int, int] | None = None,
) -> Any:
    """Decode caches: batch dim over data axes; if batch is unshardable
    (long-context batch=1), shard the sequence dim over the model axis (cache
    sequence-parallelism) — and over everything for 500k caches."""
    dsize = math.prod(mesh.shape[a] for a in data_axes)
    msize = mesh.shape[model_axis]

    def spec(leaf):
        nd = leaf.ndim
        parts: list = [None] * nd
        if nd >= 1 and leaf.shape[0] % dsize == 0 and leaf.shape[0] >= dsize:
            parts[0] = data_axes
            # additionally shard long sequence dims over model
            for i in range(1, nd):
                if leaf.shape[i] >= 16_384 and leaf.shape[i] % msize == 0:
                    parts[i] = model_axis
                    break
        else:
            # batch unshardable: find a long dim to shard over everything
            for i in range(1, nd):
                if leaf.shape[i] >= 16_384 and leaf.shape[i] % (dsize * msize) == 0:
                    parts[i] = (*data_axes, model_axis)
                    break
                if leaf.shape[i] >= 16_384 and leaf.shape[i] % msize == 0:
                    parts[i] = model_axis
                    break
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(spec, caches)

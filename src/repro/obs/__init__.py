"""Structured compile-pipeline tracing + metrics (DESIGN.md §15).

A hierarchical span tracer threaded through the whole pipeline::

    from repro import obs

    with obs.span("time.probe", ii=4):
        ...                       # timed; nests under the enclosing span
    obs.event("cache.memory.hit", ii=4)   # zero-duration instant
    obs.incr("space.restarts")            # named counter on the tracer

Design contract (the "overhead contract"):

* **Disabled is the default and costs almost nothing.** The module-level
  ``_ACTIVE`` tracer is ``None`` unless a CLI or test installs one;
  ``span()`` / ``event()`` / ``incr()`` check it first and return a shared
  ``_NULL_SPAN`` singleton without allocating. Instrumentation sites can
  therefore stay inline in hot loops (mapper rounds, solver probes).
* **Stdlib only, imports nothing from ``repro``.** Like
  ``repro.api.options``, this module must be importable from every layer
  (core, service workers, CLIs) without cycles.
* **One timeline across processes.** Timestamps are wall-epoch anchored
  (``time.time()`` at tracer start + ``perf_counter`` deltas), so span
  shards written by service worker processes merge onto the parent's
  timeline with pid/tid attribution intact.

Serialization is the Chrome trace-event JSON flavor (``"X"`` complete
events, ``"i"`` instants, ``"M"`` metadata) that Perfetto / ``chrome://
tracing`` load directly; ``tools/trace_report.py`` summarizes the same
file into a self-time table.
"""

from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager

__all__ = [
    "Tracer",
    "append_shard",
    "enabled",
    "env_enabled",
    "event",
    "get_tracer",
    "incr",
    "install_tracer",
    "merge_shards",
    "session",
    "span",
    "tracing",
]

# The process-global active tracer. ``None`` means tracing is disabled and
# every obs call short-circuits through the no-op fast path below.
_ACTIVE: "Tracer | None" = None


def env_enabled() -> bool:
    """True when the ``REPRO_TRACE`` environment variable is truthy."""
    return os.environ.get("REPRO_TRACE", "").strip().lower() not in (
        "", "0", "false", "no", "off",
    )


def enabled() -> bool:
    """True when a tracer is currently installed."""
    return _ACTIVE is not None


def get_tracer() -> "Tracer | None":
    return _ACTIVE


class _NullSpan:
    """Shared no-op span: the disabled-mode fast path (zero allocation)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):  # pragma: no cover - trivial
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    """A live span: records an ``"X"`` complete event on exit."""

    __slots__ = ("_tracer", "name", "args", "_t0", "_ts")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.args = args
        self._t0 = 0.0
        self._ts = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        self._ts = self._tracer._now_us()
        self._tracer._push(self.name)
        return self

    def __exit__(self, *exc):
        dur_us = (time.perf_counter() - self._t0) * 1e6
        self._tracer._pop()
        self._tracer._emit_complete(self.name, self._ts, dur_us, self.args)
        return False

    def set(self, **attrs):
        """Attach/override attributes after the span started."""
        self.args.update(attrs)
        return self


class Tracer:
    """Collects trace events for one process; thread-safe appends.

    Events are stored as Chrome trace-event dicts (``ts``/``dur`` in
    microseconds since the Unix epoch, so shards from different processes
    share one timeline).
    """

    def __init__(self, process_name: str = "repro"):
        self.process_name = process_name
        self.pid = os.getpid()
        # wall-epoch anchor: wall time at construction + perf_counter deltas
        self._epoch_us = time.time() * 1e6
        self._anchor = time.perf_counter()
        self._lock = threading.Lock()
        self.events: list[dict] = []
        self.counters: dict[str, int] = {}
        self._stacks: "threading.local" = threading.local()

    # -- time ------------------------------------------------------------
    def _now_us(self) -> float:
        return self._epoch_us + (time.perf_counter() - self._anchor) * 1e6

    # -- span-stack bookkeeping (per thread, for depth-aware reports) -----
    def _stack(self) -> list:
        st = getattr(self._stacks, "stack", None)
        if st is None:
            st = self._stacks.stack = []
        return st

    def _push(self, name: str) -> None:
        self._stack().append(name)

    def _pop(self) -> None:
        st = self._stack()
        if st:
            st.pop()

    def depth(self) -> int:
        return len(self._stack())

    # -- event emission ---------------------------------------------------
    def _emit_complete(self, name, ts_us, dur_us, args) -> None:
        ev = {
            "name": name,
            "cat": name.split(".", 1)[0],
            "ph": "X",
            "ts": round(ts_us, 1),
            "dur": round(dur_us, 1),
            "pid": self.pid,
            "tid": threading.get_ident() % 1_000_000,
            "args": args,
        }
        with self._lock:
            self.events.append(ev)

    def emit_instant(self, name: str, args: dict) -> None:
        ev = {
            "name": name,
            "cat": name.split(".", 1)[0],
            "ph": "i",
            "ts": round(self._now_us(), 1),
            "pid": self.pid,
            "tid": threading.get_ident() % 1_000_000,
            "s": "t",
            "args": args,
        }
        with self._lock:
            self.events.append(ev)

    def incr(self, name: str, n: int = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + n

    def adopt(self, events: list) -> None:
        """Merge externally produced events (worker shards) into this trace."""
        with self._lock:
            self.events.extend(events)

    def drain(self) -> list[dict]:
        """Atomically take (and clear) the accumulated events.

        The rotation primitive for unbounded-lifetime sessions (the compile
        daemon, DESIGN.md §16.5): the caller serializes each drained segment
        to its own Chrome-JSON file so the in-memory event list never grows
        for the life of the process. Counters are cumulative and are NOT
        cleared — they describe the session, not the segment.
        """
        with self._lock:
            events, self.events = self.events, []
        return events

    def write_segment(self, path: str, events: list[dict]) -> None:
        """Write one drained segment as a standalone Chrome trace document
        (same schema as :meth:`write`, so ``tools/trace_report.py`` loads
        rotated daemon segments and one-shot CLI traces identically)."""
        pids = sorted({e["pid"] for e in events} | {self.pid})
        meta = [{
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": self.process_name if pid == self.pid
                     else f"worker-{pid}"},
        } for pid in pids]
        doc = {"traceEvents": meta + events, "displayTimeUnit": "ms"}
        with self._lock:
            if self.counters:
                doc["otherData"] = {"counters": dict(self.counters)}
        with open(path, "w") as f:
            json.dump(doc, f)

    # -- serialization ----------------------------------------------------
    def metadata_events(self) -> list[dict]:
        pids = sorted({e["pid"] for e in self.events} | {self.pid})
        meta = []
        for pid in pids:
            label = self.process_name if pid == self.pid else f"worker-{pid}"
            meta.append({
                "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                "args": {"name": label},
            })
        return meta

    def to_chrome(self) -> dict:
        """The Perfetto-loadable Chrome trace-event JSON document."""
        with self._lock:
            events = list(self.events)
        doc = {
            "traceEvents": self.metadata_events() + events,
            "displayTimeUnit": "ms",
        }
        if self.counters:
            doc["otherData"] = {"counters": dict(self.counters)}
        return doc

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)

    # -- rollups ----------------------------------------------------------
    def span_totals(self) -> dict[str, float]:
        """Total duration (seconds) per span name, across all processes."""
        totals: dict[str, float] = {}
        with self._lock:
            for e in self.events:
                if e.get("ph") == "X":
                    totals[e["name"]] = totals.get(e["name"], 0.0) + e["dur"] / 1e6
        return totals


# -- module-level API (the only names instrumentation sites use) ----------

def span(name: str, **attrs):
    """Context manager timing a named span; no-op when tracing is disabled."""
    t = _ACTIVE
    if t is None:
        return _NULL_SPAN
    return _Span(t, name, attrs)


def event(name: str, **attrs) -> None:
    """Record a zero-duration instant event; no-op when disabled."""
    t = _ACTIVE
    if t is not None:
        t.emit_instant(name, attrs)


def incr(name: str, n: int = 1) -> None:
    """Bump a named counter on the active tracer; no-op when disabled."""
    t = _ACTIVE
    if t is not None:
        t.incr(name, n)


def install_tracer(tracer: "Tracer | None") -> "Tracer | None":
    """Install ``tracer`` as the process-global tracer; return the previous.

    The non-scoped variant of :func:`tracing` for callers whose lifetime is
    not a ``with`` block — the compile daemon installs its session tracer at
    start and restores the previous one at shutdown (DESIGN.md §16.5).
    """
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = tracer
    return prev


@contextmanager
def tracing(tracer: "Tracer | None" = None):
    """Install ``tracer`` (or a fresh one) as the process-global tracer."""
    global _ACTIVE
    t = tracer if tracer is not None else Tracer()
    prev = _ACTIVE
    _ACTIVE = t
    try:
        yield t
    finally:
        _ACTIVE = prev


@contextmanager
def session(path: "str | None" = None, *, enable: bool = False,
            process_name: str = "repro"):
    """CLI entry point: trace when asked, write Chrome JSON on exit.

    Installs a tracer when ``path`` is given, ``enable`` is true, or
    ``REPRO_TRACE`` is set — otherwise yields ``None`` and the whole
    pipeline stays on the no-op fast path. When a tracer is already
    active (nested session), it is reused and ownership stays outside.
    """
    global _ACTIVE
    if not (path or enable or env_enabled()):
        yield None
        return
    if _ACTIVE is not None:
        yield _ACTIVE
        return
    t = Tracer(process_name=process_name)
    _ACTIVE = t
    try:
        yield t
    finally:
        _ACTIVE = None
        if path:
            t.write(path)


# -- cross-process shards -------------------------------------------------

def append_shard(trace_dir: str, events: list, counters: "dict | None" = None) -> None:
    """Append this process's events to its per-pid JSONL shard file.

    Workers call this after each job; the parent merges with
    :func:`merge_shards`. One file per pid means no cross-process locking.
    """
    if not events and not counters:
        return
    path = os.path.join(trace_dir, f"shard-{os.getpid()}.jsonl")
    lines = [json.dumps(e) for e in events]
    if counters:
        lines.append(json.dumps({"_counters": counters}))
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def merge_shards(trace_dir: str) -> "tuple[list[dict], dict[str, int]]":
    """Read every per-pid shard in ``trace_dir``; return (events, counters)."""
    events: list[dict] = []
    counters: dict[str, int] = {}
    try:
        names = sorted(os.listdir(trace_dir))
    except OSError:
        return events, counters
    for fn in names:
        if not (fn.startswith("shard-") and fn.endswith(".jsonl")):
            continue
        try:
            with open(os.path.join(trace_dir, fn)) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    rec = json.loads(line)
                    if "_counters" in rec:
                        for k, v in rec["_counters"].items():
                            counters[k] = counters.get(k, 0) + v
                    else:
                        events.append(rec)
        except (OSError, ValueError):
            continue  # a torn shard must not sink the batch
    return events, counters

"""hymba-1.5b [hybrid] — arXiv:2411.13676 (parallel attention + mamba heads).

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16, 128 meta
tokens, SWA everywhere except {first, middle, last} global layers.
long_500k RUNS: SSM state is O(1) and SWA bounds local caches (DESIGN.md §5).
"""

from repro.models.api import ArchConfig, SSMSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="hymba-1.5b",
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab=32001,
        sliding_window=1024,
        window_pattern="hymba",
        ssm=SSMSpec(state_dim=16, chunk=128),
        num_meta_tokens=128,
        long_context_ok=True,
        scan_layers=False,
    )

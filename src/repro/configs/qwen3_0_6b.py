"""qwen3-0.6b [dense] — hf:Qwen/Qwen3-0.6B family.

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936, qk_norm.
long_500k skipped: pure full attention (DESIGN.md §5).
"""

from repro.models.api import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-0.6b",
        family="dense",
        num_layers=28,
        d_model=1024,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=3072,
        vocab=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        long_context_ok=False,
    )

"""gemma2-27b [dense] — arXiv:2408.00118.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000; local/global
alternating, softcaps, sandwich norms, GeGLU, tied embeddings; query scale
sqrt(d_model/heads) per the tech report. long_500k RUNS (see gemma2-9b).
"""

from repro.models.api import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="gemma2-27b",
        family="dense",
        num_layers=46,
        d_model=4608,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        d_ff=36864,
        vocab=256000,
        sliding_window=4096,
        window_pattern="alternating",
        attn_softcap=50.0,
        final_softcap=30.0,
        sandwich_norm=True,
        mlp_kind="geglu",
        tie_embeddings=True,
        embed_scale=True,
        attn_scale=(4608 / 32) ** -0.5,
        long_context_ok=True,
    )

"""paligemma-3b [vlm] — arXiv:2407.07726 (SigLIP + gemma backbone).

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216. SigLIP vision tower
stubbed: input_specs supply 256 patch embeddings [B, 256, 2048]; prefix-LM
masking (bidirectional over image+prompt prefix). long_500k skipped: full
attention (DESIGN.md §5).
"""

from repro.models.api import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="paligemma-3b",
        family="vlm",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab=257216,
        mlp_kind="geglu",
        tie_embeddings=True,
        embed_scale=True,
        frontend="vision",
        frontend_len=256,
        prefix_lm=True,
        long_context_ok=False,
    )

"""deepseek-v3-671b [moe] — arXiv:2412.19437.

61L d_model=7168 128H (MLA) moe_d_ff=2048 vocab=129280, 1 shared + 256 routed
top-8 (sigmoid scores, gate-normalised), first 3 layers dense (d_ff=18432),
MTP enabled. long_500k skipped: MLA is full attention (DESIGN.md §5).
"""

from repro.models.api import ArchConfig, MLASpec, MoESpec


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v3-671b",
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,
        head_dim=128,
        d_ff=18432,              # dense leading layers
        moe_d_ff=2048,
        vocab=129280,
        num_dense_layers=3,
        moe=MoESpec(
            num_experts=256,
            top_k=8,
            num_shared=1,
            score_fn="sigmoid",
            normalize_gates=True,
            routed_scale=2.5,
            capacity_factor=1.25,
            aux_loss_coef=0.0001,
        ),
        mla=MLASpec(q_lora=1536, kv_lora=512, rope_dim=64, qk_nope_dim=128, v_dim=128),
        mtp=True,
        rope_theta=10_000.0,
        long_context_ok=False,
    )

"""mistral-nemo-12b [dense] — hf:mistralai/Mistral-Nemo-Base-2407 (128k ctx).

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128.
long_500k skipped: pure full attention (DESIGN.md §5).
"""

from repro.models.api import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mistral-nemo-12b",
        family="dense",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=131072,
        rope_theta=1_000_000.0,
        long_context_ok=False,
    )

"""xlstm-125m [ssm] — arXiv:2405.04517 (sLSTM + mLSTM blocks).

12L d_model=768 4H d_ff=0 (mixing blocks only) vocab=50304; even layers mLSTM
(chunk-parallel), odd layers sLSTM (sequential scan). long_500k RUNS: decode
carries O(1) recurrent state (DESIGN.md §5).
"""

from repro.models.api import ArchConfig, SSMSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="xlstm-125m",
        family="ssm",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        head_dim=192,
        d_ff=0,
        vocab=50304,
        use_rope=False,
        ssm=SSMSpec(state_dim=0, chunk=128),
        long_context_ok=True,
        scan_layers=False,
    )

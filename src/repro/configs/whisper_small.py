"""whisper-small [audio] — arXiv:2212.04356, encoder-decoder.

12L (x2: encoder + decoder) d_model=768 12H (MHA) d_ff=3072 vocab=51865.
Conv/mel frontend stubbed: input_specs supply frame embeddings [B, 1500, 768].
Learned decoder positions sized for the serving shapes. long_500k skipped:
full attention enc-dec (DESIGN.md §5).
"""

from repro.models.api import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-small",
        family="audio",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab=51865,
        mlp_kind="gelu",
        use_rope=False,
        frontend="audio",
        frontend_len=1500,
        max_positions=32_768 + 8,   # decode_32k cache
        long_context_ok=False,
        scan_layers=False,          # python-loop builder: cost_analysis exact
    )

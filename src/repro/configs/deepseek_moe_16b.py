"""deepseek-moe-16b [moe] — arXiv:2401.06066 (fine-grained experts).

28L d_model=2048 16H (kv=16) moe_d_ff=1408 vocab=102400, 2 shared + 64 routed
top-6 (softmax), first layer dense (d_ff=10944). long_500k skipped: full
attention (DESIGN.md §5).
"""

from repro.models.api import ArchConfig, MoESpec


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b",
        family="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=10944,              # dense first layer
        moe_d_ff=1408,
        vocab=102400,
        num_dense_layers=1,
        moe=MoESpec(
            num_experts=64,
            top_k=6,
            num_shared=2,
            score_fn="softmax",
            normalize_gates=False,
            capacity_factor=1.25,
            aux_loss_coef=0.001,
        ),
        long_context_ok=False,
    )

"""Architecture registry: one module per assigned architecture.

Usage: ``get_config("qwen3-0.6b")`` or via ``--arch`` on any launcher.
"""

from __future__ import annotations

from repro.models.api import ArchConfig

from . import (
    deepseek_moe_16b,
    deepseek_v3_671b,
    gemma2_9b,
    gemma2_27b,
    hymba_1_5b,
    mistral_nemo_12b,
    paligemma_3b,
    qwen3_0_6b,
    whisper_small,
    xlstm_125m,
)

_MODULES = {
    "deepseek-v3-671b": deepseek_v3_671b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "whisper-small": whisper_small,
    "qwen3-0.6b": qwen3_0_6b,
    "mistral-nemo-12b": mistral_nemo_12b,
    "gemma2-9b": gemma2_9b,
    "gemma2-27b": gemma2_27b,
    "paligemma-3b": paligemma_3b,
    "xlstm-125m": xlstm_125m,
    "hymba-1.5b": hymba_1_5b,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_NAMES}")
    return _MODULES[name].config()


def all_configs() -> dict[str, ArchConfig]:
    return {name: get_config(name) for name in ARCH_NAMES}

"""gemma2-9b [dense] — arXiv:2408.00118.

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000; local(4096)/global
alternating, attn softcap 50, final softcap 30, sandwich norms, GeGLU, tied
embeddings, embed scaling. long_500k RUNS: alternating local layers give the
sub-quadratic component; global-layer caches shard over 'model' (DESIGN.md §5).
"""

from repro.models.api import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="gemma2-9b",
        family="dense",
        num_layers=42,
        d_model=3584,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab=256000,
        sliding_window=4096,
        window_pattern="alternating",
        attn_softcap=50.0,
        final_softcap=30.0,
        sandwich_norm=True,
        mlp_kind="geglu",
        tie_embeddings=True,
        embed_scale=True,
        attn_scale=256.0**-0.5,
        long_context_ok=True,
    )

"""``python -m repro.compile`` — batch-compile DFGs and emit a JSON report.

The CLI front-end of the compilation service (``repro.core.service``,
DESIGN.md §8): it gathers a workload (the built-in Table III suite and/or a
directory of ``DFG.to_json`` files), maps every DFG onto the requested CGRA
across a process pool, and writes a machine-readable report with per-job wall
times, IIs, and cache hit/miss counters.

Examples::

    # the 17-benchmark suite on a 5x5 CGRA, 4 workers, persistent cache
    PYTHONPATH=src python -m repro.compile --suite --size 5 --jobs 4 \\
        --cache-dir ~/.cache/repro-maps --report report.json

    # a directory of extracted DFG JSON files, sequential + deterministic
    PYTHONPATH=src python -m repro.compile --dfg-dir kernels/ --size 8 \\
        --jobs 1 --deterministic

    # a heterogeneous target: named preset or ArchSpec JSON (core/arch)
    PYTHONPATH=src python -m repro.compile --suite \\
        --arch satmapit_edge_mem_4x4 --jobs 4

A second run against the same ``--cache-dir`` serves every job from the
persistent cache (``"solved": 0`` in the report's cache counters) — warm
restarts of a compile server never re-solve.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from repro.core.cgra import CGRA
from repro.core.dfg import DFG
from repro.core.service import CompileJob, compile_many


def _load_dfg_dir(path: str) -> list[DFG]:
    dfgs = []
    for fn in sorted(os.listdir(path)):
        if not fn.endswith(".json"):
            continue
        full = os.path.join(path, fn)
        try:
            with open(full, "r", encoding="utf-8") as f:
                dfg = DFG.from_json(f.read())
            dfg.validate()
        except (OSError, ValueError, KeyError) as exc:
            print(f"skipping {full}: {exc}", file=sys.stderr)
            continue
        if dfg.name == "dfg":
            dfg.name = os.path.splitext(fn)[0]
        dfgs.append(dfg)
    return dfgs


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.compile",
        description="Batch-compile DFGs onto a CGRA and emit a JSON report.",
    )
    src = ap.add_argument_group("workload")
    src.add_argument("--suite", action="store_true",
                     help="compile the built-in 17-benchmark Table III suite")
    src.add_argument("--bench", action="append", default=[],
                     help="one suite benchmark by name (repeatable)")
    src.add_argument("--dfg-dir", metavar="DIR",
                     help="directory of DFG.to_json files (*.json)")
    tgt = ap.add_argument_group("target CGRA")
    tgt.add_argument("--size", type=int, default=5,
                     help="square grid size N (NxN, default 5)")
    tgt.add_argument("--rows", type=int, help="grid rows (overrides --size)")
    tgt.add_argument("--cols", type=int, help="grid cols (overrides --size)")
    tgt.add_argument("--topology",
                     choices=["mesh", "torus", "diagonal", "one-hop"],
                     default="mesh")
    tgt.add_argument("--arch", metavar="PRESET|FILE.json", default=None,
                     help="architecture spec: a named preset (see "
                          "repro.core.arch.presets) or an ArchSpec JSON file; "
                          "overrides --size/--rows/--cols/--topology")
    svc = ap.add_argument_group("service")
    svc.add_argument("--jobs", type=int, default=os.cpu_count() or 1,
                     help="worker processes (1 = sequential in-process)")
    svc.add_argument("--deadline-s", type=float, default=60.0,
                     help="per-job wall budget in seconds")
    svc.add_argument("--deterministic", action="store_true",
                     help="step-budgeted reproducible mode (bypasses caches)")
    svc.add_argument("--cache-dir", default=None,
                     help="persistent mapping cache directory "
                          "(default: $REPRO_CACHE_DIR if set)")
    svc.add_argument("--no-cache", action="store_true",
                     help="disable both mapping cache layers")
    mp_ = ap.add_argument_group("mapper")
    mp_.add_argument("--max-slack", type=int, default=3)
    mp_.add_argument("--connectivity", choices=["strict", "paper"],
                     default="strict")
    mp_.add_argument("--backend", default="auto",
                     help="time backend: auto | cp | z3")
    mp_.add_argument("--max-register-pressure", type=int, default=None)
    ap.add_argument("--report", metavar="PATH", default=None,
                    help="write the JSON report here (default: stdout summary only)")
    ap.add_argument("--quiet", action="store_true")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)

    dfgs: list[DFG] = []
    if args.suite or args.bench:
        from repro.core.benchsuite import load_suite

        # --suite always means the full 17 (it subsumes any --bench names)
        suite = load_suite(names=None if args.suite else args.bench)
        dfgs.extend(suite.values())
    if args.dfg_dir:
        dfgs.extend(_load_dfg_dir(args.dfg_dir))
    if not dfgs:
        print("no DFGs to compile: pass --suite, --bench, or --dfg-dir",
              file=sys.stderr)
        return 2

    arch_meta = None
    if args.arch:
        from repro.core.arch import resolve_arch

        try:
            spec = resolve_arch(args.arch)
        except ValueError as exc:
            print(str(exc), file=sys.stderr)
            return 2
        cgra = spec.cgra()
        rows, cols = spec.rows, spec.cols
        arch_meta = {"name": spec.name, "spec_hash": spec.spec_hash()}
        problems = sorted({p for d in dfgs for p in spec.validate_for(d)})
        if problems:
            for p in problems:
                print(f"workload incompatible with {spec.name}: {p}",
                      file=sys.stderr)
            return 2
    else:
        rows = args.rows if args.rows is not None else args.size
        cols = args.cols if args.cols is not None else args.size
        cgra = CGRA(rows, cols, topology=args.topology)

    batch = [CompileJob(d, cgra) for d in dfgs]
    report = compile_many(
        batch,
        jobs=args.jobs,
        deadline_s=args.deadline_s,
        deterministic=args.deterministic,
        cache_dir=args.cache_dir,
        use_cache=not args.no_cache,
        map_options={
            "max_slack": args.max_slack,
            "connectivity": args.connectivity,
            "backend": args.backend,
            "max_register_pressure": args.max_register_pressure,
        },
    )

    if not args.quiet:
        for j in report.jobs:
            status = f"II={j.ii}" if j.ok else f"FAILED ({j.reason})"
            src_ = ("memory" if j.cache_hit
                    else "disk" if j.disk_cache_hit else "solved")
            print(f"{j.name:20s} {status:24s} {j.wall_s:7.3f}s  [{src_}]")
        c = report.cache_counters
        print(f"--- {len(report.jobs)} jobs on {cgra} in {report.wall_s:.2f}s "
              f"({report.num_workers} workers): {c['solved']} solved, "
              f"{c['memory_hits']} memory hits, {c['disk_hits']} disk hits, "
              f"{c['failed']} failed")

    if args.report:
        payload = {
            "cgra": {"rows": rows, "cols": cols, "topology": cgra.topology},
            "arch": arch_meta,
            "deterministic": args.deterministic,
            **report.as_dict(),
        }
        with open(args.report, "w", encoding="utf-8") as f:
            json.dump(payload, f, indent=2)
        if not args.quiet:
            print(f"wrote {os.path.abspath(args.report)}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())

"""``python -m repro.compile`` — batch-compile DFGs and emit a JSON report.

The CLI front-end of the :mod:`repro.api` compiler layer (DESIGN.md §11): it
gathers a workload (the built-in Table III suite and/or a directory of
``DFG.to_json`` files), resolves its flags through the single
``resolve_options`` path shared by every frontend, and maps the workload
through a :class:`repro.api.Compiler` session. The JSON report embeds the
resolved options block and one unified ``CompileResult`` row per job.

Examples::

    # the 17-benchmark suite on a 5x5 CGRA, 4 workers, persistent cache
    PYTHONPATH=src python -m repro.compile --suite --size 5 --jobs 4 \\
        --cache-dir ~/.cache/repro-maps --report report.json

    # a directory of extracted DFG JSON files, reproducible CI profile
    PYTHONPATH=src python -m repro.compile --dfg-dir kernels/ --size 8 \\
        --profile deterministic-ci

    # a heterogeneous target: named preset or ArchSpec JSON (core/arch)
    PYTHONPATH=src python -m repro.compile --suite \\
        --arch satmapit_edge_mem_4x4 --jobs 4

A second run against the same ``--cache-dir`` serves every job from the
persistent cache (``"solved": 0`` in the report's cache counters) — warm
restarts of a compile server never re-solve.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from repro import obs
from repro.api import Compiler, add_cli_args, options_from_args
from repro.core.cgra import CGRA
from repro.core.dfg import DFG


def _load_dfg_dir(path: str) -> list[DFG]:
    dfgs = []
    for fn in sorted(os.listdir(path)):
        if not fn.endswith(".json"):
            continue
        full = os.path.join(path, fn)
        try:
            with open(full, "r", encoding="utf-8") as f:
                dfg = DFG.from_json(f.read())
            dfg.validate()
        except (OSError, ValueError, KeyError) as exc:
            print(f"skipping {full}: {exc}", file=sys.stderr)
            continue
        if dfg.name == "dfg":
            dfg.name = os.path.splitext(fn)[0]
        dfgs.append(dfg)
    return dfgs


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.compile",
        description="Batch-compile DFGs onto a CGRA and emit a JSON report.",
    )
    src = ap.add_argument_group("workload")
    src.add_argument("--suite", action="store_true",
                     help="compile the built-in 17-benchmark Table III suite")
    src.add_argument("--bench", action="append", default=[],
                     help="one suite benchmark by name (repeatable)")
    src.add_argument("--dfg-dir", metavar="DIR",
                     help="directory of DFG.to_json files (*.json)")
    tgt = ap.add_argument_group("target CGRA")
    tgt.add_argument("--size", type=int, default=5,
                     help="square grid size N (NxN, default 5)")
    tgt.add_argument("--rows", type=int, help="grid rows (overrides --size)")
    tgt.add_argument("--cols", type=int, help="grid cols (overrides --size)")
    tgt.add_argument("--topology",
                     choices=["mesh", "torus", "diagonal", "one-hop"],
                     default="mesh")
    # the shared compiler-option flags (--profile, --jobs, --cache-dir,
    # --deterministic, --arch, ...) — defined ONCE in repro.api.options
    add_cli_args(ap)
    ap.add_argument("--report", metavar="PATH", default=None,
                    help="write the JSON report here (default: stdout summary only)")
    ap.add_argument("--report-utilization", action="store_true",
                    help="attach a fabric-utilization block (per-PE occupancy, "
                         "route wire hops) to every successful row")
    ap.add_argument("--quiet", action="store_true")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    try:
        opts = options_from_args(args)
    except ValueError as exc:
        print(str(exc), file=sys.stderr)
        return 2
    if opts.deadline_s is None and not opts.deterministic:
        # this CLI's historical per-job wall budget; --deadline-s overrides
        opts = opts.replace(deadline_s=60.0)

    dfgs: list[DFG] = []
    if args.suite or args.bench:
        from repro.core.benchsuite import load_suite

        # --suite always means the full 17 (it subsumes any --bench names)
        suite = load_suite(names=None if args.suite else args.bench)
        dfgs.extend(suite.values())
    if args.dfg_dir:
        dfgs.extend(_load_dfg_dir(args.dfg_dir))
    if not dfgs:
        print("no DFGs to compile: pass --suite, --bench, or --dfg-dir",
              file=sys.stderr)
        return 2

    if opts.arch:
        try:
            compiler = Compiler(options=opts)
        except ValueError as exc:
            print(str(exc), file=sys.stderr)
            return 2
    else:
        rows = args.rows if args.rows is not None else args.size
        cols = args.cols if args.cols is not None else args.size
        compiler = Compiler(CGRA(rows, cols, topology=args.topology), opts)

    problems = compiler.validate_workload(dfgs)
    if problems:
        target = compiler.spec.name if compiler.spec else str(compiler.cgra)
        for p in problems:
            print(f"workload incompatible with {target}: {p}", file=sys.stderr)
        return 2

    # structured tracing (DESIGN.md §15): --trace records the whole batch
    # under one tracer and writes a Perfetto-loadable Chrome trace JSON
    with obs.session(getattr(args, "trace_out", None), enable=opts.trace):
        batch = compiler.compile_batch(dfgs)
    if getattr(args, "trace_out", None) and not args.quiet:
        print(f"wrote trace {os.path.abspath(args.trace_out)}")

    if args.report_utilization:
        from repro.core.simulate import utilization_report

        for r in batch:
            if r.ok and r.mapping is not None:
                r.utilization = utilization_report(r.mapping)

    if not args.quiet:
        for r in batch:
            status = f"II={r.ii}" if r.ok else f"FAILED ({r.reason})"
            print(f"{r.name:20s} {status:24s} {r.wall_s:7.3f}s  [{r.source or r.failure}]")
            if r.utilization is not None:
                u = r.utilization
                print(f"{'':20s}   util: {u['pes_used']}/{u['num_pes']} PEs, "
                      f"{u['slots_used']}/{u['slots_total']} slots "
                      f"({100 * u['occupancy']:.1f}%), "
                      f"{u['route_wire_hops']} route wire hops")
        c = batch.cache_counters
        print(f"--- {len(batch)} jobs on {compiler.cgra} in {batch.wall_s:.2f}s "
              f"({batch.num_workers} workers): {c['solved']} solved, "
              f"{c['memory_hits']} memory hits, {c['disk_hits']} disk hits, "
              f"{c['failed']} failed")

    if args.report:
        spec = compiler.spec
        payload = {
            "cgra": {"rows": compiler.cgra.rows, "cols": compiler.cgra.cols,
                     "topology": compiler.cgra.topology},
            "arch": (None if spec is None
                     else {"name": spec.name, "spec_hash": spec.spec_hash()}),
            "deterministic": opts.deterministic,
            "options": opts.as_dict(),
            **batch.as_dict(),
        }
        with open(args.report, "w", encoding="utf-8") as f:
            json.dump(payload, f, indent=2)
        if not args.quiet:
            print(f"wrote {os.path.abspath(args.report)}")
    return 0 if batch.ok else 1


if __name__ == "__main__":
    sys.exit(main())

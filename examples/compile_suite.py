"""Compile the full 17-benchmark suite (paper §V) through the compiler API.

    PYTHONPATH=src python examples/compile_suite.py [size] [--jobs N]
        [--cache-dir DIR] [--joint] [--arch PRESET|FILE.json]
        [--profile fast|quality|deterministic-ci]

One :class:`repro.api.Compiler` session maps the whole suite via
``compile_batch`` (N worker processes when ``--jobs N``); with
``--cache-dir`` a second run is served from the persistent mapping cache
instead of re-solving. ``--joint`` additionally times the SAT-MapIt-style
joint baseline per kernel (needs z3). ``--arch`` targets a heterogeneous
architecture spec (DESIGN.md §10) instead of the homogeneous ``size×size``
mesh. All compiler flags are the shared ``repro.api`` set, resolved through
the same ``resolve_options`` path as every other CLI.
"""

import argparse

from repro import obs
from repro.api import Compiler, add_cli_args, options_from_args
from repro.core import CGRA
from repro.core.benchsuite import load_suite
from repro.core.simulate import check_equivalence

ap = argparse.ArgumentParser()
ap.add_argument("size", type=int, nargs="?", default=5)
ap.add_argument("--joint", action="store_true")
add_cli_args(ap)          # --jobs/--cache-dir/--arch/--profile/... (repro.api)
args = ap.parse_args()
options = options_from_args(args)
if options.deadline_s is None:
    options = options.replace(deadline_s=30.0)

if options.arch:
    compiler = Compiler(options=options)
    target = compiler.spec.name
else:
    compiler = Compiler(CGRA(args.size, args.size), options)
    target = f"{args.size}x{args.size}"
suite = load_suite()
jobs = options.jobs if options.jobs is not None else "auto"
print(f"=== {target} CGRA, 17 benchmarks, jobs={jobs} ===")

dfgs = list(suite.values())
# --trace OUT.json records every job's spans — pool workers shard per pid,
# merged into one Perfetto-loadable timeline (DESIGN.md §15.2)
with obs.session(getattr(args, "trace_out", None), enable=options.trace):
    batch = compiler.compile_batch(dfgs)

for dfg, r in zip(dfgs, batch):
    if not r.ok:
        print(f"{r.name:16s} n={dfg.num_nodes:3d} FAILED "
              f"({r.failure}: {r.reason})")
        continue
    line = (
        f"{r.name:16s} n={dfg.num_nodes:3d} II={r.ii:3d} "
        f"(mII={r.m_ii:3d}) wall={r.wall_s:6.3f}s [{r.source}]"
    )
    if args.joint:
        from repro.core.baseline import map_dfg_joint

        jb = map_dfg_joint(dfg, compiler.cgra, time_budget_s=60)
        line += (
            f" | joint II={jb.mapping.ii if jb.ok else '--'} "
            f"t={jb.stats.total_s:6.1f}s "
            f"CTR={jb.stats.total_s / max(1e-3, r.wall_s):7.1f}x"
        )
    print(line)

c = batch.cache_counters
print(f"--- batch wall {batch.wall_s:.2f}s on {batch.num_workers} workers: "
      f"{c['solved']} solved, {c['memory_hits']} memory hits, "
      f"{c['disk_hits']} disk hits, {c['failed']} failed")

# functional spot-check of one mapping reconstructed from the batch rows
# (cache hits were validated on read): execute the smallest kernel's mapping
bit = next(r for r in batch if r.name == "bitcount")
assert bit.ok and bit.mapping is not None
check_equivalence(bit.mapping, num_iters=4)
print("functional equivalence spot-check (bitcount): OK")

"""Compile the full 17-benchmark suite (paper §V) on a chosen CGRA size.

    PYTHONPATH=src python examples/compile_suite.py [size] [--joint]
"""

import sys

from repro.core import CGRA, map_dfg
from repro.core.benchsuite import load_suite
from repro.core.simulate import check_equivalence

size = int(sys.argv[1]) if len(sys.argv) > 1 else 5
run_joint = "--joint" in sys.argv
cgra = CGRA(size, size)
print(f"=== {size}x{size} CGRA, 17 benchmarks ===")

for name, dfg in load_suite().items():
    res = map_dfg(dfg, cgra, time_budget_s=30)
    if not res.ok:
        print(f"{name:16s} n={dfg.num_nodes:3d} FAILED ({res.reason})")
        continue
    check_equivalence(res.mapping, num_iters=4)
    line = (
        f"{name:16s} n={dfg.num_nodes:3d} II={res.mapping.ii:3d} "
        f"(mII={res.stats.m_ii:3d}) time={res.stats.time_phase_s:6.3f}s "
        f"space={res.stats.space_phase_s:7.4f}s"
    )
    if run_joint:
        from repro.core.baseline import map_dfg_joint

        j = map_dfg_joint(dfg, cgra, time_budget_s=60)
        line += (
            f" | joint II={j.mapping.ii if j.ok else '--'} "
            f"t={j.stats.total_s:6.1f}s "
            f"CTR={j.stats.total_s / max(1e-3, res.stats.total_s):7.1f}x"
        )
    print(line)

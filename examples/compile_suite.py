"""Compile the full 17-benchmark suite (paper §V) through the batch service.

    PYTHONPATH=src python examples/compile_suite.py [size] [--jobs N]
        [--cache-dir DIR] [--joint] [--arch PRESET|FILE.json]

With ``--jobs N`` the suite is mapped by N worker processes
(``repro.core.service.compile_many``); with ``--cache-dir`` a second run is
served from the persistent mapping cache instead of re-solving. ``--joint``
additionally times the SAT-MapIt-style joint baseline per kernel (needs z3).
``--arch`` targets a heterogeneous architecture spec (DESIGN.md §10)
instead of the homogeneous ``size×size`` mesh.
"""

import argparse

from repro.core import CGRA
from repro.core.benchsuite import load_suite
from repro.core.service import CompileJob, compile_many
from repro.core.simulate import check_equivalence

ap = argparse.ArgumentParser()
ap.add_argument("size", type=int, nargs="?", default=5)
ap.add_argument("--jobs", type=int, default=1)
ap.add_argument("--cache-dir", default=None)
ap.add_argument("--joint", action="store_true")
ap.add_argument("--arch", default=None,
                help="architecture preset name or ArchSpec JSON file")
args = ap.parse_args()

if args.arch:
    from repro.core.arch import resolve_arch

    spec = resolve_arch(args.arch)
    cgra = spec.cgra()
    target = spec.name
else:
    cgra = CGRA(args.size, args.size)
    target = f"{args.size}x{args.size}"
suite = load_suite()
print(f"=== {target} CGRA, 17 benchmarks, jobs={args.jobs} ===")

batch = [CompileJob(dfg, cgra) for dfg in suite.values()]
report = compile_many(batch, jobs=args.jobs, deadline_s=30,
                      cache_dir=args.cache_dir)

for job, j in zip(batch, report.jobs):
    if not j.ok:
        print(f"{j.name:16s} n={job.dfg.num_nodes:3d} FAILED ({j.reason})")
        continue
    src = "memory" if j.cache_hit else "disk" if j.disk_cache_hit else "solved"
    line = (
        f"{j.name:16s} n={job.dfg.num_nodes:3d} II={j.ii:3d} "
        f"(mII={j.m_ii:3d}) wall={j.wall_s:6.3f}s [{src}]"
    )
    if args.joint:
        from repro.core.baseline import map_dfg_joint

        jb = map_dfg_joint(job.dfg, cgra, time_budget_s=60)
        line += (
            f" | joint II={jb.mapping.ii if jb.ok else '--'} "
            f"t={jb.stats.total_s:6.1f}s "
            f"CTR={jb.stats.total_s / max(1e-3, j.wall_s):7.1f}x"
        )
    print(line)

c = report.cache_counters
print(f"--- batch wall {report.wall_s:.2f}s on {report.num_workers} workers: "
      f"{c['solved']} solved, {c['memory_hits']} memory hits, "
      f"{c['disk_hits']} disk hits, {c['failed']} failed")

# functional spot-check of one freshly solved mapping (cache hits were
# validated on read): re-map the smallest kernel in-process and execute it
from repro.core import map_dfg

res = map_dfg(suite["bitcount"], cgra, time_budget_s=30)
assert res.ok
check_equivalence(res.mapping, num_iters=4)
print("functional equivalence spot-check (bitcount): OK")

"""The paper's technique applied to the TPU pod itself (DESIGN.md §3):

map a pipeline-parallel stage graph (= "DFG") onto a chip/pod grid
(= torus "CGRA") with the same SMT time solution + monomorphism space
solution, so every stage boundary is a single ICI hop — lowerable to
collective_permute instead of long-haul routes.

    PYTHONPATH=src python examples/pipeline_placement.py
"""

from repro.core.placement import (
    device_order_for_pipeline, linear_pipeline, place_stages,
)

for num_stages, mesh_shape in [(8, (4, 4)), (16, (4, 4)), (12, (4, 8)), (16, (16, 16))]:
    placement = place_stages(linear_pipeline(num_stages), mesh_shape)
    if placement is None:
        print(f"{num_stages} stages on {mesh_shape}: mapper declined (snake fallback)")
        continue
    frac = placement.single_hop_fraction()
    print(
        f"{num_stages} stages on {mesh_shape[0]}x{mesh_shape[1]} mesh: "
        f"II={placement.ii}, single-hop flows {frac*100:.0f}%, "
        f"permute pairs {placement.permute_pairs()[:6]}..."
    )
    assert frac == 1.0, "monomorphic placement must be all single-hop"

order = device_order_for_pipeline(16, (4, 4))
print("\ndevice order for a 16-stage pipeline on a 4x4 slice:", order)
print("(feed this to jax.sharding.Mesh device assignment so stage i+1 is "
      "always an ICI neighbour of stage i)")

# ---- the same mapper placing MoE expert groups (deepseek-style EP):
# profiled hot expert-pair traffic becomes edges; placement puts each hot
# pair on one ICI hop.
from repro.core.placement import expert_groups_graph

hot_pairs = [(0, 5), (2, 9), (7, 12), (3, 14)]
g = expert_groups_graph(16, heavy_routes=hot_pairs, name="moe_ep")
placement = place_stages(g, (4, 4))
print(
    f"\n16 expert groups on a 4x4 mesh with hot routes {hot_pairs}: "
    f"single-hop flows {placement.single_hop_fraction()*100:.0f}%, "
    f"group->chip {placement.stage_to_device}"
)

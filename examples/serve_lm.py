"""Batched serving example: queue of requests through prefill + decode.

    PYTHONPATH=src python examples/serve_lm.py [--arch gemma2-9b]
"""

import argparse

from repro.launch import serve as serve_mod

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gemma2-9b")
args = ap.parse_args()

serve_mod.main([
    "--arch", args.arch,
    "--reduced",
    "--requests", "12",
    "--batch", "4",
    "--prompt-len", "24",
    "--gen", "12",
])

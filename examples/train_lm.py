"""End-to-end training driver: ~100M-parameter qwen3-family model for a few
hundred steps on synthetic data, with checkpointing + fault-tolerant runner.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="qwen3-0.6b")
    args = ap.parse_args()

    # ~100M-parameter variant of the qwen3 family (CPU-trainable)
    report = train_mod.main([
        "--arch", args.arch,
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "128",
        "--reduced",
        "--ckpt-dir", "/tmp/repro_example_ckpt",
    ])
    assert report.losses[-1] < report.losses[0], "loss must decrease"
    print("training example OK — loss decreased "
          f"{report.losses[0]:.3f} -> {report.losses[-1]:.3f}")


if __name__ == "__main__":
    main()

"""Quickstart: map a loop DFG onto a CGRA through the ``repro.api`` compiler,
validate it by execution, and run it batched through the Pallas kernel.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --profile deterministic-ci
    PYTHONPATH=src python examples/quickstart.py --cache-dir /tmp/repro-maps

This is the pattern to copy: resolve a :class:`repro.api.CompileOptions`
(profile + flag overrides, one shared flag set across every CLI), bind a
:class:`repro.api.Compiler` session to a target, and read the structured
:class:`repro.api.CompileResult`. With ``--cache-dir`` the session exercises
the persistent mapping cache exactly like the batch service does — a second
run is served from disk instead of re-solved.
"""

import argparse

import numpy as np

from repro import obs
from repro.api import Compiler, add_cli_args, options_from_args
from repro.core import CGRA, running_example
from repro.core.simulate import check_equivalence
from repro.kernels.ops import cgra_run, compile_program

ap = argparse.ArgumentParser()
add_cli_args(ap)                      # --profile/--cache-dir/--deterministic/...
args = ap.parse_args()
options = options_from_args(args)     # THE resolution path (DESIGN.md §11.1)

# 1. the paper's running example: 14-op loop body with two loop-carried deps
dfg = running_example()
compiler = Compiler(CGRA(2, 2), options)

# 2. decoupled mapping: SMT time solution -> monomorphism space solution
# (--trace OUT.json records the compile's span tree, DESIGN.md §15)
with obs.session(getattr(args, "trace_out", None), enable=options.trace):
    result = compiler.compile(dfg)
assert result.ok, result.reason
m = result.mapping
print(m.pretty())
print(
    f"time phase {result.phases.time_s*1e3:.1f} ms, "
    f"space phase {result.phases.space_s*1e3:.1f} ms "
    f"(II={result.ii}, mII={result.m_ii}, source={result.source})"
)

# 3. validate by execution: cycle-accurate modulo-scheduled run == reference
report = check_equivalence(m, num_iters=8)
print(f"functional equivalence OK over {report.cycles} cycles; "
      f"max register pressure {max(report.max_register_pressure.values())}")

# 4. run 256 independent instances of the loop through the Pallas CGRA kernel
prog = compile_program(m)
rng = np.random.default_rng(0)
inputs = {
    v: rng.uniform(-2, 2, (8, 256)).astype(np.float32)
    for v in dfg.nodes
    if dfg.ops[v] == "input"
}
outs, trace = cgra_run(prog, inputs, num_iters=8)
# read the sink node's stream from the trace (the running example's final op)
sink = max(dfg.nodes, key=lambda v: m.t_abs[v])
cycles = [m.t_abs[sink] + it * m.ii for it in range(8)]
stream = trace[cycles, m.placement[sink], :]
print(f"pallas cgra_sim: sink node {sink} stream {stream.shape} (iters x batch); "
      f"sample: {stream[:3, 0]}")

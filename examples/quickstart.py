"""Quickstart: map a loop DFG onto a CGRA with the paper's decoupled mapper,
validate it by execution, and run it batched through the Pallas kernel.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import CGRA, map_dfg, running_example
from repro.core.simulate import check_equivalence
from repro.kernels.ops import cgra_run, compile_program

# 1. the paper's running example: 14-op loop body with two loop-carried deps
dfg = running_example()
cgra = CGRA(2, 2)

# 2. decoupled mapping: SMT time solution -> monomorphism space solution
result = map_dfg(dfg, cgra)
assert result.ok, result.reason
m = result.mapping
print(m.pretty())
print(
    f"time phase {result.stats.time_phase_s*1e3:.1f} ms, "
    f"space phase {result.stats.space_phase_s*1e3:.1f} ms "
    f"(II={m.ii}, mII={result.stats.m_ii})"
)

# 3. validate by execution: cycle-accurate modulo-scheduled run == reference
report = check_equivalence(m, num_iters=8)
print(f"functional equivalence OK over {report.cycles} cycles; "
      f"max register pressure {max(report.max_register_pressure.values())}")

# 4. run 256 independent instances of the loop through the Pallas CGRA kernel
prog = compile_program(m)
rng = np.random.default_rng(0)
inputs = {
    v: rng.uniform(-2, 2, (8, 256)).astype(np.float32)
    for v in dfg.nodes
    if dfg.ops[v] == "input"
}
outs, trace = cgra_run(prog, inputs, num_iters=8)
# read the sink node's stream from the trace (the running example's final op)
sink = max(dfg.nodes, key=lambda v: m.t_abs[v])
cycles = [m.t_abs[sink] + it * m.ii for it in range(8)]
stream = trace[cycles, m.placement[sink], :]
print(f"pallas cgra_sim: sink node {sink} stream {stream.shape} (iters x batch); "
      f"sample: {stream[:3, 0]}")

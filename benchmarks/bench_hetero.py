"""Heterogeneous-architecture benchmark leg (DESIGN.md §10).

Maps the full 17-kernel Table III suite onto a named heterogeneous preset
(default: SAT-MapIt-style ``satmapit_edge_mem_4x4`` — memory only on border
PEs, 4 ports) and *independently verifies* every mapping by cycle-accurate
execution: ``execute_mapping``'s capability and memory-port assertions fire
on any op placed on an incapable PE, so a passing run certifies placement
legality beyond the mapper's own bookkeeping.

Emits ``BENCH_hetero.json`` so CI can gate II/wall-time regressions on
non-homogeneous targets, mirroring ``BENCH_table3.json`` for the paper grid.
"""

from __future__ import annotations

from repro.core.arch import resolve_arch
from repro.core.benchsuite import load_suite
from repro.core.mapper import map_dfg
from repro.core.simulate import check_equivalence


def run(
    *,
    arch: str = "satmapit_edge_mem_4x4",
    budget_s: float = 60.0,
    benchmarks=None,
    cache_dir: str | None = None,
) -> dict:
    spec = resolve_arch(arch)
    cgra = spec.cgra()
    suite = load_suite(names=benchmarks)
    rows = []
    for name, dfg in suite.items():
        problems = spec.validate_for(dfg)
        res = None
        if not problems:
            res = map_dfg(dfg, cgra, time_budget_s=budget_s,
                          cache_dir=cache_dir)
        row = {
            "bench": name,
            "nodes": dfg.num_nodes,
            "arch": spec.name,
            "mII": res.stats.m_ii if res else None,
            "II": res.mapping.ii if res and res.ok else None,
            "wall_s": round(res.stats.total_s, 6) if res else 0.0,
            "cache_hit": bool(res and (res.stats.cache_hit
                                       or res.stats.disk_cache_hit)),
            "ok": bool(res and res.ok),
            "verified": False,
            "reason": "; ".join(problems) if problems else (res.reason if res else ""),
        }
        if res and res.ok:
            # the oracle raises on capability/port/routing/timing violations;
            # a clean pass is the independent placement-legality certificate.
            # A failure must land in the artifact (verified=False drives the
            # CI gate), not abort the sweep and lose the other rows.
            try:
                check_equivalence(res.mapping)
                row["verified"] = True
            except AssertionError as exc:
                row["reason"] = f"verification failed: {exc}"
        rows.append(row)
        print(row, flush=True)
    return {
        "arch": {"name": spec.name, "spec_hash": spec.spec_hash(),
                 "rows": spec.rows, "cols": spec.cols,
                 "topology": spec.topology, "mem_ports": spec.mem_ports},
        "ok": all(r["ok"] and r["verified"] for r in rows),
        "rows": rows,
    }

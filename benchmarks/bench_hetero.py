"""Heterogeneous-architecture benchmark leg (DESIGN.md §10).

Maps the full 17-kernel Table III suite onto a named heterogeneous preset
(default: SAT-MapIt-style ``satmapit_edge_mem_4x4`` — memory only on border
PEs, 4 ports) and *independently verifies* every mapping by cycle-accurate
execution: ``execute_mapping``'s capability and memory-port assertions fire
on any op placed on an incapable PE, so a passing run certifies placement
legality beyond the mapper's own bookkeeping.

Rows are the unified ``repro.api.CompileResult`` schema plus
``arch``/``nodes``/``verified``. Emits ``BENCH_hetero.json`` so CI can gate
II/wall-time regressions on non-homogeneous targets, mirroring
``BENCH_table3.json`` for the paper grid.
"""

from __future__ import annotations

from repro.api import Compiler, CompileOptions, CompileResult, resolve_options
from repro.core.benchsuite import load_suite
from repro.core.simulate import check_equivalence


def run(
    *,
    arch: str = "satmapit_edge_mem_4x4",
    options: CompileOptions | None = None,
    budget_s: float = 60.0,
    benchmarks=None,
) -> dict:
    options = options or resolve_options()
    compiler = Compiler(arch, options.replace(time_budget_s=budget_s))
    spec = compiler.spec
    suite = load_suite(names=benchmarks)
    rows = []
    for name, dfg in suite.items():
        problems = spec.validate_for(dfg)
        if problems:
            # pre-validation failure in the SAME unified row schema: a
            # consumer reading row["phases"]/row["trace"] must never KeyError
            res = CompileResult(name=name, ok=False, failure="infeasible",
                                reason="; ".join(problems))
        else:
            res = compiler.compile(dfg)
        row = res.as_dict()
        row.update({
            "nodes": dfg.num_nodes,
            "arch": spec.name,
            "verified": False,
        })
        if res.ok:
            # the oracle raises on capability/port/routing/timing violations;
            # a clean pass is the independent placement-legality certificate.
            # A failure must land in the artifact (verified=False drives the
            # CI gate), not abort the sweep and lose the other rows.
            try:
                check_equivalence(res.mapping)
                row["verified"] = True
            except AssertionError as exc:
                row["reason"] = f"verification failed: {exc}"
        rows.append(row)
        print(row, flush=True)
    return {
        "arch": {"name": spec.name, "spec_hash": spec.spec_hash(),
                 "rows": spec.rows, "cols": spec.cols,
                 "topology": spec.topology, "mem_ports": spec.mem_ports},
        "ok": all(r["ok"] and r["verified"] for r in rows),
        "rows": rows,
    }

"""Heterogeneous-architecture benchmark leg (DESIGN.md §10).

Maps the full 17-kernel Table III suite onto a named heterogeneous preset
(default: SAT-MapIt-style ``satmapit_edge_mem_4x4`` — memory only on border
PEs, 4 ports) and *independently verifies* every mapping by cycle-accurate
execution: ``execute_mapping``'s capability and memory-port assertions fire
on any op placed on an incapable PE, so a passing run certifies placement
legality beyond the mapper's own bookkeeping.

Rows are the unified ``repro.api.CompileResult`` schema plus
``arch``/``nodes``/``verified``. A final *route-through* row maps the
``route_stress`` kernel onto the bank-split ``onehop_split_4x4`` preset with
``max_route_hops=2`` (DESIGN.md §12) — unmappable without mov insertion, so
the row only verifies when the route path actually engaged. Emits
``BENCH_hetero.json`` so CI can gate II/wall-time regressions on
non-homogeneous targets, mirroring ``BENCH_table3.json`` for the paper grid.
"""

from __future__ import annotations

from repro.api import Compiler, CompileOptions, CompileResult, resolve_options
from repro.core.benchsuite import load_suite, route_stress_dfg
from repro.core.simulate import check_equivalence

#: The route-through leg: the bank-split one-hop machine on which the demo
#: kernel is unmappable at hops=0 and must map (and verify by execution) at
#: hops<=2 — the CI-gated acceptance row for DESIGN.md §12.
ROUTE_ARCH = "onehop_split_4x4"
ROUTE_HOPS = 2


def run(
    *,
    arch: str = "satmapit_edge_mem_4x4",
    options: CompileOptions | None = None,
    budget_s: float = 60.0,
    benchmarks=None,
) -> dict:
    options = options or resolve_options()
    compiler = Compiler(arch, options.replace(time_budget_s=budget_s))
    spec = compiler.spec
    workload = dict(load_suite(names=benchmarks))
    rows = []
    for name, dfg in workload.items():
        problems = spec.validate_for(dfg)
        if problems:
            # pre-validation failure in the SAME unified row schema: a
            # consumer reading row["phases"]/row["trace"] must never KeyError
            res = CompileResult(name=name, ok=False, failure="infeasible",
                                reason="; ".join(problems))
        else:
            res = compiler.compile(dfg)
        row = res.as_dict()
        row.update({
            "nodes": dfg.num_nodes,
            "arch": spec.name,
            "verified": False,
        })
        if res.ok:
            # the oracle raises on capability/port/routing/timing violations;
            # a clean pass is the independent placement-legality certificate.
            # A failure must land in the artifact (verified=False drives the
            # CI gate), not abort the sweep and lose the other rows.
            try:
                check_equivalence(res.mapping)
                row["verified"] = True
            except AssertionError as exc:
                row["reason"] = f"verification failed: {exc}"
        rows.append(row)
        print(row, flush=True)

    # route-through leg (always included): the demo kernel on the bank-split
    # one-hop preset, mapped with mov insertion and execution-verified. Its
    # row rides the same CI gate (ok + verified) as the suite rows.
    route_comp = Compiler(
        ROUTE_ARCH,
        options.replace(time_budget_s=budget_s, max_route_hops=ROUTE_HOPS),
    )
    dfg = route_stress_dfg()
    res = route_comp.compile(dfg)
    row = res.as_dict()
    row.update({
        "nodes": dfg.num_nodes,
        "arch": route_comp.spec.name,
        "max_route_hops": ROUTE_HOPS,
        "verified": False,
    })
    if res.ok:
        try:
            check_equivalence(res.mapping)
            row["verified"] = res.route_movs > 0   # a direct map would mean
            # the preset stopped exercising the route path — fail the gate
        except AssertionError as exc:
            row["reason"] = f"verification failed: {exc}"
    rows.append(row)
    print(row, flush=True)

    return {
        "arch": {"name": spec.name, "spec_hash": spec.spec_hash(),
                 "rows": spec.rows, "cols": spec.cols,
                 "topology": spec.topology, "mem_ports": spec.mem_ports},
        "ok": all(r["ok"] and r["verified"] for r in rows),
        "rows": rows,
    }

"""Reproduction of the paper's Table III: II + compilation time, ours
(decoupled monomorphism mapper) vs the joint SAT-MapIt-style baseline, on
2x2 / 5x5 / 10x10 / 20x20 CGRAs over the 17-benchmark suite.

Timeouts are scaled down from the paper's 4000s to fit the container budget
(the metric of record is the compilation-time *ratio* CTR and II parity).

``jobs > 1`` routes the per-size sweep through the compilation service
(``repro.core.service.compile_many``), which is how the harness measures the
service layer's throughput gain; ``cache_dir`` points both paths at the
persistent mapping cache so warm re-runs are visible in the per-row
``cache_hit`` / ``disk_cache_hit`` counters.
"""

from __future__ import annotations

from repro.core.baseline import HAVE_Z3, map_dfg_joint
from repro.core.benchsuite import load_suite
from repro.core.cgra import CGRA
from repro.core.mapper import map_dfg
from repro.core.service import CompileJob, compile_many

SIZES = (2, 5, 10, 20)


def run(
    *,
    ours_budget_s: float = 60.0,
    joint_budget_s: float = 60.0,
    sizes=SIZES,
    benchmarks=None,
    run_joint: bool = True,
    jobs: int = 1,
    cache_dir: str | None = None,
) -> list[dict]:
    suite = load_suite()
    if benchmarks:
        suite = {k: v for k, v in suite.items() if k in benchmarks}
    run_joint = run_joint and HAVE_Z3   # graceful skip, same as bench_fig5
    rows = []
    for size in sizes:
        cgra = CGRA(size, size)
        if jobs > 1:
            rows.extend(_run_batch(suite, cgra, size, jobs, cache_dir,
                                   ours_budget_s))
        else:
            for name, dfg in suite.items():
                ours = map_dfg(dfg, cgra, time_budget_s=ours_budget_s,
                               cache_dir=cache_dir)
                rows.append({
                    "bench": name,
                    "size": size,
                    "nodes": dfg.num_nodes,
                    "mII": ours.stats.m_ii,
                    "ours_II": ours.mapping.ii if ours.ok else None,
                    "ours_time_s": round(ours.stats.total_s, 6),
                    "wall_s": round(ours.stats.total_s, 6),
                    "ours_time_phase_s": round(ours.stats.time_phase_s, 3),
                    "ours_space_phase_s": round(ours.stats.space_phase_s, 4),
                    "mono_failures": ours.stats.mono_failures,
                    "cache_hit": ours.stats.cache_hit,
                    "disk_cache_hit": ours.stats.disk_cache_hit,
                })
        if run_joint:
            for row in (r for r in rows if r["size"] == size):
                joint = map_dfg_joint(suite[row["bench"]], cgra,
                                      time_budget_s=joint_budget_s)
                row["joint_II"] = joint.mapping.ii if joint.ok else None
                row["joint_time_s"] = round(joint.stats.total_s, 3)
                if row["ours_II"] and joint.ok:
                    row["ctr"] = round(
                        joint.stats.total_s / max(1e-3, row["ours_time_s"]), 2)
                    row["same_ii"] = row["ours_II"] == joint.mapping.ii
        for row in (r for r in rows if r["size"] == size):
            print(row, flush=True)
    return rows


def _run_batch(suite, cgra, size, jobs, cache_dir, budget_s) -> list[dict]:
    """Per-size sweep through compile_many; rows match the sequential shape."""
    batch = [CompileJob(dfg, cgra) for dfg in suite.values()]
    report = compile_many(batch, jobs=jobs, deadline_s=budget_s,
                          cache_dir=cache_dir)
    rows = []
    for job, j in zip(batch, report.jobs):
        rows.append({
            "bench": j.name,
            "size": size,
            "nodes": job.dfg.num_nodes,
            "mII": j.m_ii,
            "ours_II": j.ii,
            "ours_time_s": round(j.wall_s, 6),
            "wall_s": round(j.wall_s, 6),
            "ours_time_phase_s": round(j.time_phase_s, 3),
            "ours_space_phase_s": round(j.space_phase_s, 4),
            "mono_failures": j.mono_failures,
            "cache_hit": j.cache_hit,
            "disk_cache_hit": j.disk_cache_hit,
            "batch_wall_s": round(report.wall_s, 3),
            "batch_workers": report.num_workers,
        })
    return rows


def cache_counters(rows: list[dict]) -> dict:
    """Aggregate hit/miss counters over a run's rows (for BENCH_table3.json)."""
    return {
        "memory_hits": sum(1 for r in rows if r.get("cache_hit")),
        "disk_hits": sum(1 for r in rows if r.get("disk_cache_hit")),
        "solved": sum(
            1 for r in rows
            if r.get("ours_II") and not r.get("cache_hit")
            and not r.get("disk_cache_hit")
        ),
        "failed": sum(1 for r in rows if not r.get("ours_II")),
    }


def summarize(rows: list[dict]) -> list[str]:
    lines = []
    for size in sorted({r["size"] for r in rows}):
        rs = [r for r in rows if r["size"] == size]
        both = [r for r in rs if r.get("ours_II") and r.get("joint_II")]
        if both:
            avg_ctr = sum(r["ctr"] for r in both) / len(both)
            same = sum(1 for r in both if r["same_ii"])
            better = sum(1 for r in both if r["ours_II"] < r["joint_II"])
            lines.append(
                f"{size}x{size}: avg CTR (joint/ours) = {avg_ctr:.2f}x over "
                f"{len(both)} co-solved cases; same II {same}, ours better {better}"
            )
        solved = sum(1 for r in rs if r.get("ours_II"))
        lines.append(f"{size}x{size}: ours solved {solved}/{len(rs)}")
    return lines

"""Reproduction of the paper's Table III: II + compilation time, ours
(decoupled monomorphism mapper) vs the joint SAT-MapIt-style baseline, on
2x2 / 5x5 / 10x10 / 20x20 CGRAs over the 17-benchmark suite.

Timeouts are scaled down from the paper's 4000s to fit the container budget
(the metric of record is the compilation-time *ratio* CTR and II parity).

Every row is the unified ``repro.api.CompileResult`` schema (DESIGN.md
§11.3) plus the bench keys (``size``, ``nodes``, joint columns). ``jobs > 1``
in the shared options routes the per-size sweep through
``Compiler.compile_batch`` (the process-pool service), which is how the
harness measures the service layer's throughput gain; ``cache_dir`` points
both paths at the persistent mapping cache so warm re-runs are visible in
the per-row ``source`` provenance.
"""

from __future__ import annotations

from repro.api import Compiler, CompileOptions, resolve_options
from repro.core.baseline import HAVE_Z3, map_dfg_joint
from repro.core.benchsuite import load_suite
from repro.core.cgra import CGRA

SIZES = (2, 5, 10, 20)


def run(
    *,
    options: CompileOptions | None = None,
    ours_budget_s: float = 60.0,
    joint_budget_s: float = 60.0,
    sizes=SIZES,
    benchmarks=None,
    run_joint: bool = True,
) -> list[dict]:
    options = options or resolve_options()
    options = options.replace(time_budget_s=ours_budget_s,
                              deadline_s=ours_budget_s)
    suite = load_suite()
    if benchmarks:
        suite = {k: v for k, v in suite.items() if k in benchmarks}
    run_joint = run_joint and HAVE_Z3   # graceful skip, same as bench_fig5
    rows = []
    for size in sizes:
        compiler = Compiler(CGRA(size, size), options)
        if (options.jobs or 0) > 1:
            batch = compiler.compile_batch(list(suite.values()))
            results = list(batch)
            extra = {"batch_wall_s": round(batch.wall_s, 3),
                     "batch_workers": batch.num_workers}
        else:
            results = [compiler.compile(dfg) for dfg in suite.values()]
            extra = {}
        for dfg, res in zip(suite.values(), results):
            rows.append({
                **res.as_dict(),
                "size": size,
                "nodes": dfg.num_nodes,
                **extra,
            })
        if run_joint:
            for row in (r for r in rows if r["size"] == size):
                joint = map_dfg_joint(suite[row["name"]], compiler.cgra,
                                      time_budget_s=joint_budget_s)
                row["joint_II"] = joint.mapping.ii if joint.ok else None
                row["joint_time_s"] = round(joint.stats.total_s, 3)
                if row["ii"] and joint.ok:
                    row["ctr"] = round(
                        joint.stats.total_s / max(1e-3, row["wall_s"]), 2)
                    row["same_ii"] = row["ii"] == joint.mapping.ii
        for row in (r for r in rows if r["size"] == size):
            print(row, flush=True)
    return rows


def cache_counters(rows: list[dict]) -> dict:
    """Aggregate hit/miss counters over a run's rows (for BENCH_table3.json)."""
    return {
        "memory_hits": sum(1 for r in rows if r.get("source") == "memory"),
        "disk_hits": sum(1 for r in rows if r.get("source") == "disk"),
        "solved": sum(1 for r in rows if r.get("source") == "solve"),
        "failed": sum(1 for r in rows if not r.get("ok")),
    }


def summarize(rows: list[dict]) -> list[str]:
    lines = []
    for size in sorted({r["size"] for r in rows}):
        rs = [r for r in rows if r["size"] == size]
        both = [r for r in rs if r.get("ii") and r.get("joint_II")]
        if both:
            avg_ctr = sum(r["ctr"] for r in both) / len(both)
            same = sum(1 for r in both if r["same_ii"])
            better = sum(1 for r in both if r["ii"] < r["joint_II"])
            lines.append(
                f"{size}x{size}: avg CTR (joint/ours) = {avg_ctr:.2f}x over "
                f"{len(both)} co-solved cases; same II {same}, ours better {better}"
            )
        solved = sum(1 for r in rs if r.get("ii"))
        lines.append(f"{size}x{size}: ours solved {solved}/{len(rs)}")
    return lines

"""Reproduction of the paper's Table III: II + compilation time, ours
(decoupled monomorphism mapper) vs the joint SAT-MapIt-style baseline, on
2x2 / 5x5 / 10x10 / 20x20 CGRAs over the 17-benchmark suite.

Timeouts are scaled down from the paper's 4000s to fit the container budget
(the metric of record is the compilation-time *ratio* CTR and II parity).
"""

from __future__ import annotations

import time

from repro.core.baseline import HAVE_Z3, map_dfg_joint
from repro.core.benchsuite import load_suite
from repro.core.cgra import CGRA
from repro.core.mapper import map_dfg

SIZES = (2, 5, 10, 20)


def run(
    *,
    ours_budget_s: float = 60.0,
    joint_budget_s: float = 60.0,
    sizes=SIZES,
    benchmarks=None,
    run_joint: bool = True,
) -> list[dict]:
    suite = load_suite()
    if benchmarks:
        suite = {k: v for k, v in suite.items() if k in benchmarks}
    run_joint = run_joint and HAVE_Z3   # graceful skip, same as bench_fig5
    rows = []
    for size in sizes:
        cgra = CGRA(size, size)
        for name, dfg in suite.items():
            ours = map_dfg(dfg, cgra, time_budget_s=ours_budget_s)
            row = {
                "bench": name,
                "size": size,
                "nodes": dfg.num_nodes,
                "mII": ours.stats.m_ii,
                "ours_II": ours.mapping.ii if ours.ok else None,
                "ours_time_s": round(ours.stats.total_s, 3),
                "ours_time_phase_s": round(ours.stats.time_phase_s, 3),
                "ours_space_phase_s": round(ours.stats.space_phase_s, 4),
                "mono_failures": ours.stats.mono_failures,
            }
            if run_joint:
                joint = map_dfg_joint(dfg, cgra, time_budget_s=joint_budget_s)
                row["joint_II"] = joint.mapping.ii if joint.ok else None
                row["joint_time_s"] = round(joint.stats.total_s, 3)
                if ours.ok and joint.ok:
                    row["ctr"] = round(joint.stats.total_s / max(1e-3, ours.stats.total_s), 2)
                    row["same_ii"] = ours.mapping.ii == joint.mapping.ii
            rows.append(row)
            print(row, flush=True)
    return rows


def summarize(rows: list[dict]) -> list[str]:
    lines = []
    for size in sorted({r["size"] for r in rows}):
        rs = [r for r in rows if r["size"] == size]
        both = [r for r in rs if r.get("ours_II") and r.get("joint_II")]
        if both:
            avg_ctr = sum(r["ctr"] for r in both) / len(both)
            same = sum(1 for r in both if r["same_ii"])
            better = sum(1 for r in both if r["ours_II"] < r["joint_II"])
            lines.append(
                f"{size}x{size}: avg CTR (joint/ours) = {avg_ctr:.2f}x over "
                f"{len(both)} co-solved cases; same II {same}, ours better {better}"
            )
        solved = sum(1 for r in rs if r.get("ours_II"))
        lines.append(f"{size}x{size}: ours solved {solved}/{len(rs)}")
    return lines

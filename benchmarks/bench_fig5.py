"""Reproduction of the paper's Fig. 5: compilation time vs CGRA size for the
`aes` benchmark — ours stays near-flat, the joint baseline grows with grid
size (and is skipped gracefully when z3 is absent).

Emits ``BENCH_fig5.json`` with per-size rows plus the 20x20 / 4x4 ratio the
scaling acceptance gate checks (near-flat means the decoupling removed the
|PEs| x II factor from the search, paper §V-B).
"""

from __future__ import annotations

import json
import os

from repro.api import Compiler, CompileOptions, resolve_options
from repro.core.baseline import HAVE_Z3, map_dfg_joint
from repro.core.benchsuite import load_suite
from repro.core.cgra import CGRA

DEFAULT_SIZES = (2, 4, 6, 8, 10, 14, 20)


def run(*, options: CompileOptions | None = None, sizes=DEFAULT_SIZES,
        joint_budget_s: float = 60.0, run_joint: bool = True,
        out_path: str = "BENCH_fig5.json") -> list[dict]:
    options = options or resolve_options()
    # the scaling gate times fresh solves: a fixed budget, no cache reuse
    options = options.replace(time_budget_s=30.0, use_cache=False)
    dfg = load_suite()["aes"]
    rows = []
    for size in sizes:
        cgra = CGRA(size, size)
        ours = Compiler(cgra, options).compile(dfg)
        row = {
            "size": size,
            "ours_time_s": round(ours.phases.total_s, 4),
            "ours_II": ours.ii,
            "ours_backend": ours.backend,
            "time_phase_s": round(ours.phases.time_s, 4),
            "space_phase_s": round(ours.phases.space_s, 4),
        }
        if run_joint and HAVE_Z3:
            joint = map_dfg_joint(dfg, cgra, time_budget_s=joint_budget_s)
            row["joint_time_s"] = round(joint.stats.total_s, 3)
            row["joint_II"] = joint.mapping.ii if joint.ok else None
        rows.append(row)
        print(row, flush=True)
    if out_path:
        write_json(rows, out_path)
    return rows


def write_json(rows: list[dict], out_path: str) -> None:
    by_size = {r["size"]: r for r in rows}
    summary: dict = {"bench": "aes", "rows": rows}
    if 20 in by_size and 4 in by_size:
        # 0.05s noise floor: sub-50ms compiles are flat by any standard
        base = max(by_size[4]["ours_time_s"], 0.05)
        summary["flatness_20_over_4"] = round(
            max(by_size[20]["ours_time_s"], 0.05) / base, 3
        )
        # fast failures are flat too: the gate requires actual mappings
        summary["near_flat"] = (
            summary["flatness_20_over_4"] <= 5.0
            and by_size[4]["ours_II"] is not None
            and by_size[20]["ours_II"] is not None
        )
    summary["smallest_size"] = min(by_size)
    with open(out_path, "w") as f:
        json.dump(summary, f, indent=2)
    print(f"wrote {os.path.abspath(out_path)}", flush=True)

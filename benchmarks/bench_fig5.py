"""Reproduction of the paper's Fig. 5: compilation time vs CGRA size for the
`aes` benchmark — ours stays flat, the joint baseline grows with grid size."""

from __future__ import annotations

from repro.core.baseline import map_dfg_joint
from repro.core.benchsuite import load_suite
from repro.core.cgra import CGRA
from repro.core.mapper import map_dfg


def run(*, sizes=(2, 4, 6, 8, 10, 14, 20), joint_budget_s: float = 60.0,
        run_joint: bool = True) -> list[dict]:
    dfg = load_suite()["aes"]
    rows = []
    for size in sizes:
        cgra = CGRA(size, size)
        ours = map_dfg(dfg, cgra, time_budget_s=30)
        row = {
            "size": size,
            "ours_time_s": round(ours.stats.total_s, 3),
            "ours_II": ours.mapping.ii if ours.ok else None,
        }
        if run_joint:
            joint = map_dfg_joint(dfg, cgra, time_budget_s=joint_budget_s)
            row["joint_time_s"] = round(joint.stats.total_s, 3)
            row["joint_II"] = joint.mapping.ii if joint.ok else None
        rows.append(row)
        print(row, flush=True)
    return rows

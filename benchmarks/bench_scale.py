"""Fabric-scale benchmark leg: exact vs anneal space backends (DESIGN.md §13).

Maps one mid-size suite kernel (``heartwall``, 35 nodes — dense enough that
tight-II partitions are hard to embed) onto square meshes from the paper's
4×4 up to 100×100, once per space backend, under the same wall budget. Every
successful mapping is independently verified by cycle-accurate execution
(``check_equivalence``) and measured with ``simulate.utilization_report``.

The row pair at each size is the acceptance evidence for the annealing
backend: on 50×50/100×100 fabrics the exact bitset engine exhausts its
per-window budget on the tight-II partitions and settles for a higher II
(or fails outright), while the clustered annealer keeps placing them —
same portfolio, same budget, better II at scale. ``ok`` gates on the
anneal rows at 50×50/100×100 being execution-verified, which is what CI
enforces alongside the hetero gate. Emits ``BENCH_scale.json``.
"""

from __future__ import annotations

import time

from repro.core import CGRA, map_dfg
from repro.core.benchsuite import load_suite
from repro.core.simulate import check_equivalence, utilization_report

#: The scale sweep: paper grid, auto-threshold boundary, and the two large
#: meshes the anneal backend opens up (mesh_50x50 / mesh_100x100 presets).
SIZES = (4, 20, 50, 100)
KERNEL = "heartwall"


def run(
    *,
    kernel: str = KERNEL,
    sizes=SIZES,
    budget_s: float = 30.0,
    options=None,
) -> dict:
    dfg = load_suite(names=[kernel])[kernel]
    base = {} if options is None else options.mapper_kwargs()
    base.pop("space_backend", None)     # the sweep owns this axis
    base["time_budget_s"] = budget_s
    rows = []
    for size in sizes:
        cgra = CGRA(size, size)
        for eng in ("exact", "anneal"):
            t0 = time.perf_counter()
            res = map_dfg(dfg, cgra, space_backend=eng, **base)
            wall = time.perf_counter() - t0
            row = {
                "name": kernel,
                "size": size,
                "space_backend": eng,
                "ok": res.ok,
                "ii": res.mapping.ii if res.ok else None,
                "mII": res.stats.m_ii,
                "wall_s": round(wall, 4),
                "verified": False,
                "utilization": None,
                "reason": res.reason,
            }
            if res.ok:
                # execution is the legality certificate — an anneal placement
                # that merely *looks* adjacent must never pass this gate
                try:
                    check_equivalence(res.mapping)
                    row["verified"] = True
                except AssertionError as exc:
                    row["reason"] = f"verification failed: {exc}"
                row["utilization"] = utilization_report(res.mapping)
            rows.append(row)
            print(
                {k: row[k] for k in
                 ("name", "size", "space_backend", "ok", "ii", "wall_s",
                  "verified")},
                flush=True,
            )
    gate = [r for r in rows if r["space_backend"] == "anneal"
            and r["size"] >= 50]
    return {
        "kernel": kernel,
        "budget_s": budget_s,
        "ok": bool(gate) and all(r["ok"] and r["verified"] for r in gate),
        "rows": rows,
    }

"""Kernel micro-benchmarks (CPU interpret mode = correctness-path timing; the
numbers of record on real TPU come from the same harness with interpret=False).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time_call(fn, *args, iters=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run() -> list[dict]:
    from repro.api import Compiler, resolve_options
    from repro.core import CGRA, running_example
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ops import cgra_run, compile_program
    from repro.kernels.ref import cgra_sim_reference, reference_attention

    rows = []

    # cgra_sim: mapped running example, batch sweep
    comp = Compiler(CGRA(2, 2), resolve_options("fast", time_budget_s=30.0))
    res = comp.compile(running_example())
    prog = compile_program(res.mapping)
    rng = np.random.default_rng(0)
    for batch in (64, 256):
        inputs = {
            v: rng.uniform(-2, 2, (8, batch)).astype(np.float32)
            for v in res.mapping.dfg.nodes
            if res.mapping.dfg.ops[v] == "input"
        }
        us = _time_call(lambda: cgra_run(prog, inputs, 8, batch_tile=64)[0])
        rows.append({"name": f"cgra_sim_pallas_b{batch}", "us_per_call": round(us, 1),
                     "derived": f"II={prog.ii},ring={prog.ring}"})
        us_ref = _time_call(lambda: cgra_sim_reference(prog, inputs, 8)[0])
        rows.append({"name": f"cgra_sim_ref_b{batch}", "us_per_call": round(us_ref, 1),
                     "derived": ""})

    # flash attention vs reference
    q = jnp.asarray(rng.standard_normal((1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    us = _time_call(lambda: flash_attention(q, k, v, interpret=True))
    rows.append({"name": "flash_attention_interp_s256", "us_per_call": round(us, 1),
                 "derived": "b1,h4/2,d64"})
    us = _time_call(lambda: reference_attention(q, k, v))
    rows.append({"name": "attention_reference_s256", "us_per_call": round(us, 1),
                 "derived": ""})
    for r in rows:
        print(r, flush=True)
    return rows

"""Benchmark harness: one module per paper table/figure.

  table3   — II + compile time, decoupled vs joint mapper (paper Tab. III)
  fig5     — compile time vs CGRA size for `aes` (paper Fig. 5)
  kernels  — Pallas kernel micro-benchmarks
  hetero   — the suite on a heterogeneous arch preset (--arch), with
             execute_mapping capability verification (DESIGN.md §10)
  scale    — one kernel at 4x4..100x100 per space backend (exact vs
             anneal), execution-verified, with utilization (DESIGN.md §13)
  service  — compile-daemon load test: zipf/bursty/mixed-tenant trace over
             the unix socket, warm p50/p99 latency, admission-control sheds,
             speculative-premapping lift (DESIGN.md §16)

Each section also emits a ``BENCH_<name>.json`` artifact (consumed by CI and
by the Fig. 5 near-flat acceptance gate) and prints a
``name,us_per_call,derived`` CSV at the end. ``BENCH_table3.json`` carries
per-kernel rows in the unified ``repro.api.CompileResult`` schema plus
aggregate cache hit/miss counters, so service-layer gains — batch
parallelism, warm persistent cache — show up in the tracked artifacts.

Compiler flags (``--jobs``, ``--cache-dir``, ``--profile``, ``--arch``, ...)
are the shared :func:`repro.api.add_cli_args` set — resolved through the
same ``resolve_options`` path as every other CLI (DESIGN.md §11.1).

Full sweep:   ``PYTHONPATH=src python -m benchmarks.run``
CI smoke:     ``PYTHONPATH=src python -m benchmarks.run --smoke``
Service mode: ``PYTHONPATH=src python -m benchmarks.run --jobs 4 --cache-dir /tmp/maps``
"""

from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> None:
    from repro.api import add_cli_args, options_from_args

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small subset, short timeouts")
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI job: quick subset, no joint baseline, JSON artifacts only",
    )
    ap.add_argument("--skip-joint", action="store_true")
    ap.add_argument("--only",
                    choices=["table3", "fig5", "kernels", "hetero", "scale",
                             "service"])
    add_cli_args(ap)          # --jobs/--cache-dir/--profile/--arch/... (api)
    args = ap.parse_args(argv)
    if args.smoke:
        args.quick = True
        args.skip_joint = True
    options = options_from_args(args)
    from repro import obs

    # structured tracing (DESIGN.md §15): --trace records every section's
    # compile spans into one Perfetto-loadable trace file
    with obs.session(getattr(args, "trace_out", None), enable=options.trace):
        _run_sections(args, options)


def _run_sections(args, options) -> None:
    # the hetero section needs a heterogeneous target even when the shared
    # --arch flag is unset; table3/fig5 build their own homogeneous grids
    hetero_arch = options.arch or "satmapit_edge_mem_4x4"

    from benchmarks import (
        bench_fig5,
        bench_hetero,
        bench_kernels,
        bench_scale,
        bench_table3,
    )

    csv_rows: list[tuple[str, float, str]] = []

    if args.only in (None, "table3"):
        # exact-check on: every row carries ii_opt + a machine-checkable
        # optimality certificate (DESIGN.md §14), which tools/
        # check_certificates.py re-verifies and CI gates regressions on.
        # The quick subset pivots on the 4x4 paper grid (the acceptance
        # fabric) with the full 17-kernel suite.
        kw = dict(options=options.replace(exact_check=True),
                  run_joint=not args.skip_joint)
        if args.quick:
            kw.update(sizes=(2, 4), ours_budget_s=20, joint_budget_s=20)
        else:
            kw.update(ours_budget_s=60, joint_budget_s=60)
        rows = bench_table3.run(**kw)
        for line in bench_table3.summarize(rows):
            print("TABLE3:", line)
        with open("BENCH_table3.json", "w") as f:
            json.dump(
                {
                    "jobs": options.jobs,
                    "cache": bench_table3.cache_counters(rows),
                    "rows": rows,
                },
                f, indent=2,
            )
        for r in rows:
            csv_rows.append(
                (
                    f"table3_{r['name']}_{r['size']}x{r['size']}",
                    r["wall_s"] * 1e6,
                    f"II={r.get('ii')};mII={r['mII']};CTR={r.get('ctr', '')}",
                )
            )

    if args.only in (None, "fig5"):
        # always span 4x4..20x20: the near-flat gate compares those endpoints
        sizes = (4, 10, 20) if args.quick else (2, 4, 6, 8, 10, 14, 20)
        rows = bench_fig5.run(options=options, sizes=sizes,
                              run_joint=not args.skip_joint,
                              joint_budget_s=20 if args.quick else 60)
        for r in rows:
            csv_rows.append(
                (
                    f"fig5_aes_{r['size']}x{r['size']}",
                    r["ours_time_s"] * 1e6,
                    f"joint_s={r.get('joint_time_s', '')}",
                )
            )

    if args.only in (None, "hetero"):
        kw = dict(arch=hetero_arch, options=options.replace(exact_check=True))
        if args.quick:
            kw.update(budget_s=20,
                      benchmarks=["bitcount", "fft", "gsm", "susan", "aes"])
        hrep = bench_hetero.run(**kw)
        with open("BENCH_hetero.json", "w") as f:
            json.dump(hrep, f, indent=2)
        for r in hrep["rows"]:
            csv_rows.append(
                (
                    f"hetero_{r['name']}_{r['arch']}",
                    r["wall_s"] * 1e6,
                    f"II={r['ii']};mII={r['mII']};verified={r['verified']}",
                )
            )

    if args.only in (None, "scale"):
        srep = bench_scale.run(options=options,
                               budget_s=15 if args.quick else 30)
        with open("BENCH_scale.json", "w") as f:
            json.dump(srep, f, indent=2)
        for r in srep["rows"]:
            occ = (r["utilization"] or {}).get("occupancy", "")
            csv_rows.append(
                (
                    f"scale_{r['name']}_{r['size']}x{r['size']}_{r['space_backend']}",
                    r["wall_s"] * 1e6,
                    f"II={r['ii']};verified={r['verified']};occupancy={occ}",
                )
            )

    if args.only in (None, "service"):
        from benchmarks import bench_service

        vrep = bench_service.run(options=options, smoke=args.quick)
        with open("BENCH_service.json", "w") as f:
            json.dump(vrep, f, indent=2)
        for line in bench_service.summarize(vrep):
            print("SERVICE:", line)
        csv_rows.append(
            ("service_warm_p99", vrep["warm_p99_ms"] * 1e3,
             f"p50_ms={vrep['warm_p50_ms']};shed_rate={vrep['shed_rate']};"
             f"spec_hits={vrep['speculate']['cold']['speculative_hits']}"))

    if args.only in (None, "kernels"):
        krows = bench_kernels.run()
        with open("BENCH_kernels.json", "w") as f:
            json.dump({"rows": krows}, f, indent=2)
        for r in krows:
            csv_rows.append((r["name"], r["us_per_call"], r["derived"]))

    print("\nname,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us},{derived}")


if __name__ == "__main__":
    main()

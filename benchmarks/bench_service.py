"""Compile-daemon load test: zipf-skewed, bursty, mixed-tenant replay.

Exercises the whole serving stack of DESIGN.md §16 — unix-socket NDJSON
transport, admission control, coalescing, both cache layers, and speculative
premapping — and emits the CI-gated ``BENCH_service.json`` artifact:

* **warm p50/p99 compile latency** over a fully warmed replay of the trace
  (client-observed: socket round trip + queue + cache hit). CI gates
  ``warm_p99_ms <= 50``.
* **speculative premapping lift** — the same cold trace replayed through two
  fresh daemons (speculation on vs off, fresh cache dirs, memory LRU cleared
  between runs); the hops-variant half of the trace hits warm only when the
  idle-time speculator premapped it, so CI gates
  ``speculate.warm_hit_rate > no_speculate.warm_hit_rate`` and at least one
  attributed speculative hit.
* **admission-control sheds** — a dedicated overload probe (1 worker, queue
  limit 1, a burst of distinct cold requests) must shed with the
  machine-readable ``overloaded`` code, answer every request (ok or
  overloaded, no hangs), and leave the daemon alive.

The trace: kernel popularity is zipf-skewed over fast suite kernels
(``bitcount``, ``fft``, ``crc32`` — cold-solvable in milliseconds, so the
bench runs in CI time), arrivals come in bursts with idle gaps between them
(the gaps are what gives the speculator its window, exactly as on a real
daemon), requests carry rotating tenant labels, and the second half mixes in
``max_route_hops=1`` variants of the same kernels — the neighbor axis the
speculator premaps.

Profile note: ``--profile deterministic-ci`` configures a *mapper* that
bypasses both cache layers (deterministic mode trades caches for step-budget
reproducibility, DESIGN.md §6.3) — a cache-serving daemon cannot run that
way. The harness therefore maps the profile onto the reproducible-but-cached
equivalent: the cp time backend, fixed seed, ``deterministic=False``,
``use_cache=True`` with per-run fresh cache dirs. Replays are trace-
deterministic (fixed RNG seed); latencies are wall-clock measurements.
"""

from __future__ import annotations

import json
import os
import random
import tempfile
import threading
import time


def percentile(values: list[float], p: float) -> float:
    """Nearest-rank percentile (p in [0, 100]); 0.0 on an empty list."""
    if not values:
        return 0.0
    xs = sorted(values)
    rank = max(0, min(len(xs) - 1, int(round(p / 100.0 * (len(xs) - 1)))))
    return xs[rank]


def _bench_options(options):
    """Resolve CLI options into what a cache-serving daemon can run.

    Deterministic mode bypasses both mapping-cache layers inside the mapper,
    and ``use_cache=False`` disables them outright — either would make warm/
    speculative hit rates structurally zero. Keep the reproducible parts
    (cp backend, fixed seed) and force the caches on; each daemon session
    gets its own fresh disk-cache dir from the caller.
    """
    if options.deterministic or not options.use_cache:
        options = options.replace(
            deterministic=False,
            use_cache=True,
            backend="cp" if options.backend == "auto" else options.backend,
        )
    # the trace kernels solve in milliseconds; a short budget keeps a
    # pathological solver stall from wedging a CI lane
    if options.time_budget_s > 30.0:
        options = options.replace(time_budget_s=30.0)
    return options


def build_trace(n_requests: int, *, seed: int = 0) -> list[dict]:
    """The deterministic replay trace: a list of request descriptors.

    ``{"kernel", "hops", "tenant", "burst"}`` per request. Kernel choice is
    zipf-skewed (weight 1/rank), tenants rotate, arrivals are grouped into
    bursts of 2..6, and the second half of the trace draws ``hops=1``
    variants with probability 1/2 (the speculator's neighbor axis).
    """
    kernels = ["bitcount", "fft", "crc32"]   # zipf ranks 1..3
    weights = [1.0 / r for r in range(1, len(kernels) + 1)]
    tenants = ["tenant-a", "tenant-b", "tenant-c"]
    rng = random.Random(seed)
    trace: list[dict] = []
    burst = 0
    burst_left = rng.randint(2, 6)
    for i in range(n_requests):
        if burst_left == 0:
            burst += 1
            burst_left = rng.randint(2, 6)
        burst_left -= 1
        hops = 1 if (i >= n_requests // 2 and rng.random() < 0.5) else 0
        trace.append({
            "kernel": rng.choices(kernels, weights=weights)[0],
            "hops": hops,
            "tenant": tenants[i % len(tenants)],
            "burst": burst,
        })
    return trace


def _replay(socket_path: str, trace: list[dict], dfgs: dict, *,
            lanes: int = 4, burst_gap_s: float = 0.0) -> dict:
    """Replay ``trace`` through the daemon socket, bursts concurrent.

    Each burst's requests run concurrently across ``lanes`` persistent
    client connections (requests on one lane serialize, like a real client
    process); ``burst_gap_s`` idles between bursts — the speculator's
    window. Returns client-observed latencies and failure counts.
    """
    from repro.core.daemon import DaemonClient

    clients = [DaemonClient(socket_path) for _ in range(lanes)]
    latencies_ms: list[float] = []
    rows: list[dict] = []
    lock = threading.Lock()
    failures = 0

    def lane_run(client, items):
        nonlocal failures
        for it in items:
            t0 = time.perf_counter()
            row = client.compile(
                dfgs[it["kernel"]], tenant=it["tenant"],
                options={"max_route_hops": it["hops"]} if it["hops"] else None)
            dt_ms = (time.perf_counter() - t0) * 1e3
            with lock:
                latencies_ms.append(dt_ms)
                rows.append(row)
                if not row["ok"]:
                    failures += 1

    try:
        bursts: list[list[dict]] = []
        for it in trace:
            if not bursts or bursts[-1][0]["burst"] != it["burst"]:
                bursts.append([])
            bursts[-1].append(it)
        for burst in bursts:
            threads = []
            for lane in range(min(lanes, len(burst))):
                items = burst[lane::lanes]
                t = threading.Thread(
                    target=lane_run, args=(clients[lane], items))
                t.start()
                threads.append(t)
            for t in threads:
                t.join()
            if burst_gap_s:
                time.sleep(burst_gap_s)
    finally:
        for c in clients:
            c.close()

    sources = {"memory": 0, "disk": 0, "solve": 0}
    speculative_hits = 0
    for row in rows:
        if row["ok"]:
            sources[row["source"]] = sources.get(row["source"], 0) + 1
            if row["service"].get("speculative"):
                speculative_hits += 1
    n_ok = len(rows) - failures
    warm = sources["memory"] + sources["disk"]
    return {
        "requests": len(rows),
        "failures": failures,
        "p50_ms": round(percentile(latencies_ms, 50), 3),
        "p99_ms": round(percentile(latencies_ms, 99), 3),
        "max_ms": round(max(latencies_ms), 3) if latencies_ms else 0.0,
        "sources": sources,
        "warm_hit_rate": round(warm / n_ok, 6) if n_ok else None,
        "speculative_hits": speculative_hits,
    }


def _run_session(options, trace, dfgs, tmp, *, speculate: bool,
                 burst_gap_s: float, warm_replay: bool) -> dict:
    """One daemon session: cold replay, optional warm replay, stats."""
    from repro.core.cgra import CGRA
    from repro.core.daemon import CompileDaemon, DaemonServer
    from repro.core.mapper import clear_mapping_cache

    tag = "speculate" if speculate else "no_speculate"
    cache_dir = os.path.join(tmp, f"cache-{tag}")
    socket_path = os.path.join(tmp, f"{tag}.sock")
    # fresh caches per session or the A/B comparison measures the other
    # session's leftovers: new disk dir + cleared process-wide memory LRU
    clear_mapping_cache()
    daemon = CompileDaemon(
        CGRA(4, 4), options, workers=2, queue_limit=256,
        speculate=speculate, cache_dir=cache_dir)
    server = DaemonServer(daemon, socket_path)
    server.start()
    try:
        cold = _replay(socket_path, trace, dfgs, burst_gap_s=burst_gap_s)
        out = {"cold": cold}
        if warm_replay:
            # every key is now cached (by the cold replay or the speculator):
            # this replay IS the warm-latency measurement CI gates
            out["warm"] = _replay(socket_path, trace, dfgs, burst_gap_s=0.0)
        out["daemon"] = daemon.stats_dict()
        return out
    finally:
        server.stop()


def _overload_probe(options, dfgs, tmp) -> dict:
    """Deterministic admission-control probe: 1 worker, queue limit 1, one
    concurrent burst of distinct cold requests — the overflow must shed as
    ``overloaded``, everything must answer, the daemon must survive."""
    from repro.core.cgra import CGRA
    from repro.core.daemon import CompileDaemon, DaemonClient, DaemonServer
    from repro.core.mapper import clear_mapping_cache

    clear_mapping_cache()
    socket_path = os.path.join(tmp, "overload.sock")
    daemon = CompileDaemon(
        CGRA(4, 4), options, workers=1, queue_limit=1, speculate=False,
        cache_dir=os.path.join(tmp, "cache-overload"))
    server = DaemonServer(daemon, socket_path)
    server.start()
    # distinct (kernel, hops) combos -> distinct coalesce keys, all cold
    probes = [(k, h) for k in ("crc32", "fft") for h in range(4)]
    results: list[dict] = []
    lock = threading.Lock()

    def one(kernel: str, hops: int):
        with DaemonClient(socket_path) as c:
            row = c.compile(dfgs[kernel],
                            options={"max_route_hops": hops} if hops else None)
        with lock:
            results.append(row)

    try:
        threads = [threading.Thread(target=one, args=p) for p in probes]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        hung = any(t.is_alive() for t in threads)
        shed = sum(r["failure"] == "overloaded" for r in results)
        ok = sum(r["ok"] for r in results)
        with DaemonClient(socket_path) as c:
            alive_after = c.ping()
        return {
            "total": len(probes),
            "answered": len(results),
            "ok": ok,
            "shed": shed,
            "shed_rate": round(shed / len(probes), 6),
            "other_failures": len(results) - ok - shed,
            "hung": hung,
            "alive_after": alive_after,
        }
    finally:
        server.stop()


def run(options=None, *, smoke: bool = False) -> dict:
    """The whole service bench; returns the ``BENCH_service.json`` payload."""
    from repro.api import resolve_options
    from repro.core.benchsuite import load_suite

    options = _bench_options(options if options is not None
                             else resolve_options("fast"))
    dfgs = load_suite(names=["bitcount", "fft", "crc32"])
    n_requests = 60 if smoke else 240
    trace = build_trace(n_requests, seed=0)
    # the idle gap between bursts is the speculator's window; 150 ms covers
    # a few neighbor warms of millisecond-scale kernels with margin
    burst_gap_s = 0.15

    with tempfile.TemporaryDirectory(prefix="repro-bench-service-") as tmp:
        spec = _run_session(options, trace, dfgs, tmp, speculate=True,
                            burst_gap_s=burst_gap_s, warm_replay=True)
        nospec = _run_session(options, trace, dfgs, tmp, speculate=False,
                              burst_gap_s=burst_gap_s, warm_replay=False)
        overload = _overload_probe(options, dfgs, tmp)

    warm = spec["warm"]
    spec_rate = spec["cold"]["warm_hit_rate"] or 0.0
    nospec_rate = nospec["cold"]["warm_hit_rate"] or 0.0
    gates = {
        # CI acceptance gates (ci.yml bench-smoke); keep keys stable
        "warm_p99_ms_le_50": warm["p99_ms"] <= 50.0,
        "speculative_lift": (
            spec["cold"]["speculative_hits"] >= 1
            and spec_rate > nospec_rate
        ),
        "shed_overloaded": (
            overload["shed"] >= 1
            and overload["other_failures"] == 0
            and overload["answered"] == overload["total"]
            and not overload["hung"]
            and overload["alive_after"]
        ),
        "no_failures": (spec["cold"]["failures"] == 0
                        and warm["failures"] == 0
                        and nospec["cold"]["failures"] == 0),
    }
    return {
        "smoke": smoke,
        "profile": options.profile,
        "options": options.as_dict(),
        "trace": {
            "requests": n_requests,
            "kernels": sorted(dfgs),
            "tenants": sorted({t["tenant"] for t in trace}),
            "bursts": trace[-1]["burst"] + 1,
            "hops_variants": sorted({t["hops"] for t in trace}),
            "burst_gap_s": burst_gap_s,
            "seed": 0,
        },
        "warm_p50_ms": warm["p50_ms"],
        "warm_p99_ms": warm["p99_ms"],
        "shed_rate": overload["shed_rate"],
        "speculate": spec,
        "no_speculate": nospec,
        "overload": overload,
        "gates": gates,
    }


def summarize(report: dict) -> list[str]:
    spec, nospec = report["speculate"], report["no_speculate"]
    lines = [
        f"trace: {report['trace']['requests']} requests, "
        f"{report['trace']['bursts']} bursts, "
        f"kernels {','.join(report['trace']['kernels'])}",
        f"warm latency: p50 {report['warm_p50_ms']:.2f}ms "
        f"p99 {report['warm_p99_ms']:.2f}ms",
        f"cold hit rate: {spec['cold']['warm_hit_rate']} with speculation "
        f"({spec['cold']['speculative_hits']} speculative hits) vs "
        f"{nospec['cold']['warm_hit_rate']} without",
        f"overload probe: {report['overload']['shed']}/"
        f"{report['overload']['total']} shed as overloaded, "
        f"alive_after={report['overload']['alive_after']}",
        "gates: " + ", ".join(
            f"{k}={'PASS' if v else 'FAIL'}"
            for k, v in report["gates"].items()),
    ]
    return lines


def main(argv=None) -> int:
    import argparse

    from repro.api import add_cli_args, options_from_args

    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.bench_service",
        description="Compile-daemon load test (emits BENCH_service.json).")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized trace (60 requests instead of 240)")
    ap.add_argument("--out", default="BENCH_service.json",
                    help="artifact path (default BENCH_service.json)")
    add_cli_args(ap)
    args = ap.parse_args(argv)
    report = run(options_from_args(args), smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    for line in summarize(report):
        print("SERVICE:", line)
    print(f"wrote {os.path.abspath(args.out)}")
    return 0 if all(report["gates"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Markdown link checker for the CI docs job (no third-party deps).

Checks, for every markdown file given on the command line:

* relative links `[text](path)` and `[text](path#anchor)` resolve to an
  existing file/directory (anchors are checked against the target's
  headings, GitHub-style slugs);
* intra-document anchors `[text](#anchor)` match a heading;
* section references like "DESIGN.md §8" name a section that exists in
  DESIGN.md (keeps prose citations honest, not just hyperlinks).

External (http/https/mailto) links are not fetched — CI must not depend on
the network.

Usage: python tools/check_docs.py README.md DESIGN.md CHANGES.md
"""

from __future__ import annotations

import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
SECTION_REF_RE = re.compile(r"(\w[\w.]*\.md)\s+§(\d+)")
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def github_slug(heading: str) -> str:
    s = heading.strip().lower()
    s = re.sub(r"[^\w\s§-]", "", s, flags=re.UNICODE)
    return re.sub(r"\s+", "-", s).strip("-")


def headings_of(path: str) -> list[str]:
    with open(path, "r", encoding="utf-8") as f:
        text = CODE_FENCE_RE.sub("", f.read())
    return [m.group(1).strip() for m in HEADING_RE.finditer(text)]


def check_file(path: str) -> list[str]:
    errs: list[str] = []
    base = os.path.dirname(os.path.abspath(path))
    with open(path, "r", encoding="utf-8") as f:
        raw = f.read()
    text = CODE_FENCE_RE.sub("", raw)

    own_slugs = {github_slug(h) for h in headings_of(path)}
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("#"):
            if target[1:] not in own_slugs:
                errs.append(f"{path}: dangling anchor {target}")
            continue
        rel, _, anchor = target.partition("#")
        full = os.path.normpath(os.path.join(base, rel))
        if not os.path.exists(full):
            errs.append(f"{path}: broken link {target} -> {full}")
            continue
        if anchor:
            slugs = {github_slug(h) for h in headings_of(full)}
            if anchor not in slugs:
                errs.append(f"{path}: dangling anchor {target}")

    for m in SECTION_REF_RE.finditer(text):
        doc, sec = m.group(1), m.group(2)
        full = os.path.normpath(os.path.join(base, doc))
        if not os.path.exists(full):
            errs.append(f"{path}: section reference to missing file {doc}")
            continue
        pattern = re.compile(rf"^#{{1,6}}\s+§{sec}\b", re.MULTILINE)
        with open(full, "r", encoding="utf-8") as f:
            if not pattern.search(f.read()):
                errs.append(f"{path}: {doc} §{sec} not found")
    return errs


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_docs.py FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    errors: list[str] = []
    for path in argv:
        if not os.path.exists(path):
            errors.append(f"missing file: {path}")
            continue
        errors.extend(check_file(path))
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    if not errors:
        print(f"docs OK: {len(argv)} files checked")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

#!/usr/bin/env python3
"""Summarize a Chrome trace-event JSON file emitted by ``repro.compile --trace``.

    python tools/trace_report.py trace.json            # human summary
    python tools/trace_report.py trace.json --top 15   # wider self-time table
    python tools/trace_report.py trace.json --check    # schema validation only

The summary has two parts (DESIGN.md §15):

* **Top-N self-time table** — per span *name*, total time minus time spent
  in child spans on the same (pid, tid) track, so leaf work (solver probes,
  space dives) isn't double-counted under its parents.
* **Per-window breakdown** — spans carrying ``ii``/``slack`` args grouped
  by (II, slack) window, showing where the portfolio spent its budget.

``--check`` validates the Perfetto-loadable schema (well-formed JSON,
``traceEvents`` list, required keys per phase type, non-negative
durations) and exits non-zero on the first violation — CI runs this
against the deterministic 4x4 suite trace.

Stdlib-only; does not import ``repro``.
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

_REQUIRED = {
    "X": ("name", "ph", "ts", "dur", "pid", "tid"),
    "i": ("name", "ph", "ts", "pid", "tid"),
    "M": ("name", "ph", "pid"),
}


def check(doc) -> "list[str]":
    """Return a list of schema violations (empty == valid)."""
    errors = []
    if not isinstance(doc, dict):
        return ["top-level document is not a JSON object"]
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["missing or non-list 'traceEvents'"]
    if not events:
        errors.append("'traceEvents' is empty")
    saw_complete = False
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event #{i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _REQUIRED:
            errors.append(f"event #{i}: unknown ph {ph!r}")
            continue
        for key in _REQUIRED[ph]:
            if key not in ev:
                errors.append(f"event #{i} ({ev.get('name')!r}): missing {key!r}")
        if ph == "X":
            saw_complete = True
            if not (isinstance(ev.get("dur"), (int, float)) and ev["dur"] >= 0):
                errors.append(f"event #{i} ({ev.get('name')!r}): bad dur {ev.get('dur')!r}")
            if not isinstance(ev.get("ts"), (int, float)):
                errors.append(f"event #{i} ({ev.get('name')!r}): bad ts {ev.get('ts')!r}")
        if "args" in ev and not isinstance(ev["args"], dict):
            errors.append(f"event #{i} ({ev.get('name')!r}): args not an object")
        if len(errors) >= 20:
            errors.append("... (truncated)")
            break
    if not errors and not saw_complete:
        errors.append("no complete ('X') span events in trace")
    return errors


def _self_times(events):
    """Self time per span name: dur minus direct-children dur, per track."""
    tracks = defaultdict(list)
    for ev in events:
        if ev.get("ph") == "X":
            tracks[(ev.get("pid"), ev.get("tid"))].append(ev)
    total = defaultdict(float)
    self_t = defaultdict(float)
    count = defaultdict(int)
    for evs in tracks.values():
        # parents first: earlier start, then longer duration
        evs.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack = []  # (end_ts, name, child_dur_accum index into selfacc)
        selfacc = []
        for ev in evs:
            ts, dur, name = ev["ts"], ev["dur"], ev["name"]
            total[name] += dur
            count[name] += 1
            while stack and ts >= stack[-1][0] - 1e-6:
                stack.pop()
            if stack:
                selfacc[stack[-1][2]] += dur  # credit child time to parent
            selfacc.append(0.0)
            stack.append((ts + dur, name, len(selfacc) - 1))
        for ev, child_dur in zip(evs, selfacc):
            self_t[ev["name"]] += max(0.0, ev["dur"] - child_dur)
    return total, self_t, count


def summarize(doc, top: int = 10) -> "list[str]":
    events = doc.get("traceEvents", [])
    spans = [e for e in events if e.get("ph") == "X"]
    instants = [e for e in events if e.get("ph") == "i"]
    pids = sorted({e["pid"] for e in spans})
    lines = [
        f"{len(spans)} spans, {len(instants)} instant events, "
        f"{len(pids)} process(es): {pids}",
    ]

    total, self_t, count = _self_times(events)
    lines.append("")
    lines.append(f"top {top} by self time:")
    lines.append(f"  {'name':28s} {'count':>6s} {'total_ms':>10s} {'self_ms':>10s}")
    ranked = sorted(self_t.items(), key=lambda kv: -kv[1])[:top]
    for name, st in ranked:
        lines.append(
            f"  {name:28s} {count[name]:6d} {total[name] / 1e3:10.2f} "
            f"{st / 1e3:10.2f}"
        )

    # per-(II, slack) window breakdown from span args
    windows = defaultdict(lambda: [0, 0.0])  # (ii, slack) -> [spans, total_us]
    for ev in spans:
        args = ev.get("args") or {}
        if "ii" in args:
            key = (args["ii"], args.get("slack"))
            windows[key][0] += 1
            windows[key][1] += ev["dur"]
    if windows:
        lines.append("")
        lines.append("per-window breakdown (spans carrying ii/slack args):")
        lines.append(f"  {'II':>4s} {'slack':>6s} {'spans':>6s} {'total_ms':>10s}")
        for (ii, slack), (n, us) in sorted(windows.items(),
                                           key=lambda kv: -kv[1][1]):
            s = "-" if slack is None else str(slack)
            lines.append(f"  {ii!s:>4s} {s:>6s} {n:6d} {us / 1e3:10.2f}")

    counters = (doc.get("otherData") or {}).get("counters") or {}
    if counters:
        lines.append("")
        lines.append("counters:")
        for k in sorted(counters):
            lines.append(f"  {k:40s} {counters[k]}")
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace-event JSON file")
    ap.add_argument("--top", type=int, default=10,
                    help="rows in the self-time table (default 10)")
    ap.add_argument("--check", action="store_true",
                    help="validate schema only; exit non-zero if invalid")
    args = ap.parse_args(argv)

    try:
        with open(args.trace) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"error: cannot load {args.trace}: {e}", file=sys.stderr)
        return 2

    errors = check(doc)
    if args.check:
        if errors:
            for e in errors:
                print(f"SCHEMA: {e}", file=sys.stderr)
            return 1
        n = sum(1 for e in doc["traceEvents"] if e.get("ph") == "X")
        print(f"OK: {args.trace} valid ({n} spans)")
        return 0

    if errors:
        for e in errors:
            print(f"warning: {e}", file=sys.stderr)
    for line in summarize(doc, top=args.top):
        print(line)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

#!/usr/bin/env python
"""Re-verify the optimality certificates embedded in BENCH_*.json artifacts.

Certificates (DESIGN.md §14) are claims, not facts: this tool is the
independent checker that makes them trustworthy. For every bench row that
carries a ``certificate`` it rebuilds the kernel and target from the row's
own identifiers (never from the certificate — the certificate is what is
being audited), then runs :func:`repro.core.exact_backends.verify_certificate`,
which recomputes the res/rec/mII bound, re-walks the probe coverage,
re-validates the embedded mapping, and re-executes it cycle-accurately.

Two gate modes ride on top (both used by CI):

* ``--baseline OLD.json`` — regression gate: any fresh row whose kernel has
  a recorded ``optimal`` certificate in the baseline must achieve an II no
  worse than that certified optimum. A regression means the portfolio lost
  ground it had *proven* reachable, which is always a bug, never noise.
* ``--min-certified N --at-size S`` — acceptance floor: at least N rows at
  fabric size S must carry a decided (non-timeout) certificate.

Usage::

    PYTHONPATH=src python tools/check_certificates.py BENCH_table3.json \
        BENCH_hetero.json [--baseline OLD.json] [--min-certified 12 --at-size 4]

Exit status 0 = every certificate verified and every gate held.
"""

from __future__ import annotations

import argparse
import json
import sys


def _load_rows(path: str) -> list[dict]:
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    rows = doc["rows"] if isinstance(doc, dict) else doc
    if not isinstance(rows, list):
        raise SystemExit(f"{path}: no row list found")
    return rows


def _kernel_for(row: dict):
    """The DFG a row compiled, rebuilt from the row's own name."""
    from repro.core.benchsuite import load_suite, route_stress_dfg

    name = row.get("name")
    if name == "route_stress":
        return route_stress_dfg()
    suite = load_suite()
    if name not in suite:
        raise KeyError(f"unknown bench kernel {name!r}")
    return suite[name]


def _cgra_for(row: dict):
    """The target machine, from ``size`` (homogeneous) or ``arch`` (preset)."""
    from repro.core.arch import resolve_arch
    from repro.core.cgra import CGRA

    if "arch" in row:
        return resolve_arch(row["arch"]).cgra()
    size = int(row["size"])
    return CGRA(size, size)


def _row_key(row: dict) -> tuple:
    return (row.get("name"), row.get("size"), row.get("arch"))


def check_rows(rows: list[dict], label: str, *, execute: bool = True) -> list[str]:
    """Verify every certificate-bearing row; returns human-readable failures."""
    from repro.core.exact_backends import verify_certificate

    failures: list[str] = []
    checked = 0
    for row in rows:
        cert = row.get("certificate")
        if cert is None:
            continue
        checked += 1
        tag = f"{label}:{row.get('name')}@{row.get('arch') or row.get('size')}"
        try:
            dfg = _kernel_for(row)
            cgra = _cgra_for(row)
        except Exception as exc:
            failures.append(f"{tag}: cannot rebuild problem: {exc}")
            continue
        problems = verify_certificate(cert, dfg, cgra, check_execution=execute)
        failures.extend(f"{tag}: {p}" for p in problems)
        # the row's headline columns must agree with the audited certificate
        if row.get("ii") != cert.get("ii"):
            failures.append(
                f"{tag}: row ii={row.get('ii')} != certificate ii={cert.get('ii')}"
            )
        if row.get("ii_opt") != cert.get("ii_opt"):
            failures.append(
                f"{tag}: row ii_opt={row.get('ii_opt')} != certificate "
                f"ii_opt={cert.get('ii_opt')}"
            )
    print(f"{label}: {checked} certificate(s) checked, "
          f"{len(failures)} problem(s)")
    return failures


def gate_regressions(fresh: list[dict], baseline: list[dict]) -> list[str]:
    """Fresh rows may never do worse than a baseline-certified optimum."""
    failures: list[str] = []
    certified = {
        _row_key(r): r["certificate"]
        for r in baseline
        if r.get("certificate", {}) and r["certificate"].get("status") == "optimal"
    }
    compared = 0
    for row in fresh:
        cert = certified.get(_row_key(row))
        if cert is None or row.get("ii") is None:
            continue
        compared += 1
        if row["ii"] > cert["ii_opt"]:
            failures.append(
                f"regression: {row.get('name')}@"
                f"{row.get('arch') or row.get('size')} achieved II={row['ii']} "
                f"but II={cert['ii_opt']} is certified optimal in the baseline"
            )
    print(f"regression gate: {compared} row(s) compared against recorded "
          f"optimal certificates, {len(failures)} regression(s)")
    return failures


def gate_floor(rows: list[dict], min_certified: int, at_size: int | None) -> list[str]:
    decided = [
        r for r in rows
        if (at_size is None or r.get("size") == at_size)
        and (r.get("certificate") or {}).get("status") in ("optimal", "better-found")
    ]
    where = f" at size {at_size}" if at_size is not None else ""
    print(f"certified floor: {len(decided)} decided certificate(s){where} "
          f"(need >= {min_certified})")
    if len(decided) < min_certified:
        return [
            f"only {len(decided)} rows{where} carry a decided certificate, "
            f"required {min_certified}"
        ]
    return []


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("artifacts", nargs="+", help="BENCH_*.json files to audit")
    ap.add_argument("--baseline", help="prior artifact for the regression gate")
    ap.add_argument("--min-certified", type=int, default=None,
                    help="require at least N decided certificates")
    ap.add_argument("--at-size", type=int, default=None,
                    help="restrict --min-certified to rows of this fabric size")
    ap.add_argument("--no-execute", action="store_true",
                    help="skip cycle-accurate re-execution (bounds/probes only)")
    args = ap.parse_args(argv)

    failures: list[str] = []
    all_rows: list[dict] = []
    for path in args.artifacts:
        rows = _load_rows(path)
        all_rows.extend(rows)
        failures.extend(check_rows(rows, path, execute=not args.no_execute))
    if args.baseline:
        failures.extend(gate_regressions(all_rows, _load_rows(args.baseline)))
    if args.min_certified is not None:
        failures.extend(gate_floor(all_rows, args.min_certified, args.at_size))

    for f in failures:
        print(f"FAIL: {f}", file=sys.stderr)
    if not failures:
        print("all certificates verified")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
